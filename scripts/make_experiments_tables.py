"""Generate the EXPERIMENTS.md roofline tables from results/dryrun[*]/."""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import roofline_from_cell  # noqa: E402


def load(dirname, mesh):
    rows = {}
    for path in sorted(glob.glob(os.path.join(dirname, f"*__{mesh}.json"))):
        with open(path) as f:
            cell = json.load(f)
        key = (cell["arch"], cell["shape"])
        if cell.get("status") == "skipped":
            rows[key] = {"status": "skipped"}
            continue
        rep = roofline_from_cell(cell)
        if rep is None:
            rows[key] = {"status": cell.get("status", "?")}
            continue
        rows[key] = {"status": "ok", "rep": rep, "cell": cell}
    return rows


def fmt_row(arch, shape, r, base=None):
    if r["status"] != "ok":
        return f"| {arch} | {shape} | — | — | — | — | skip | — | — |"
    rep = r["rep"]
    t = (rep.t_compute, rep.t_memory, rep.t_collective)
    dom = rep.dominant[:4]
    hbm = r["cell"]["memory_analysis"]["peak_gb_per_device"]
    delta = ""
    if base is not None and base.get("status") == "ok":
        b = base["rep"]
        tb = max(b.t_compute, b.t_memory, b.t_collective)
        tn = max(t)
        delta = f" ({tb/tn:.1f}x)" if tb/tn > 1.04 or tb/tn < 0.96 else " (=)"
    return (f"| {arch} | {shape} | {t[0]:.2f} | {t[1]:.2f} | {t[2]:.2f} "
            f"| {dom} | {rep.roofline_frac:.3f}{delta} "
            f"| {rep.useful_flops_ratio:.2f} | {hbm:.1f} |")


def main():
    opt = load("results/dryrun", "single")
    base = load("results/dryrun_baseline_snapshot", "single")
    print("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | dom "
          "| roofline frac (gain) | useful | HBM GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for (arch, shape), r in sorted(opt.items()):
        print(fmt_row(arch, shape, r, base.get((arch, shape))))

    print()
    print("multi-pod (2x16x16 = 512 chips) — compile/fit proof:")
    multi = load("results/dryrun", "multi")
    print("| arch | shape | status | HBM GB/dev | t_dom (s) |")
    print("|---|---|---|---|---|")
    for (arch, shape), r in sorted(multi.items()):
        if r["status"] != "ok":
            print(f"| {arch} | {shape} | {r['status']} | — | — |")
            continue
        rep = r["rep"]
        hbm = r["cell"]["memory_analysis"]["peak_gb_per_device"]
        tdom = max(rep.t_compute, rep.t_memory, rep.t_collective)
        print(f"| {arch} | {shape} | ok | {hbm:.1f} | {tdom:.2f} |")


if __name__ == "__main__":
    main()
