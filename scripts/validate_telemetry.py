"""Validate telemetry artifacts (CI smoke): a Chrome trace JSON and a
metrics snapshot JSON written by the ``--trace-out``/``--metrics-out``
launcher flags.

    python scripts/validate_telemetry.py TRACE.json METRICS.json \
        [--expect-span NAME ...] [--expect-counter PREFIX ...]

Checks that the trace parses as the Chrome trace-event format perfetto
loads (``traceEvents`` list; every event carries name/ph/ts/pid/tid;
``X`` events carry ``dur``) and contains the expected span names, and
that the metrics snapshot parses with non-empty counters/gauges sections
containing the expected series prefixes.  Exit code 0 = valid.
"""
import argparse
import json
import sys


def check_trace(path: str, expect_spans: list[str]) -> list[str]:
    with open(path) as f:
        doc = json.load(f)
    errs = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return [f"{path}: traceEvents missing or empty"]
    for e in evs:
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in e:
                errs.append(f"{path}: event missing {field!r}: {e}")
                break
        if e.get("ph") == "X" and "dur" not in e:
            errs.append(f"{path}: X event missing dur: {e}")
    names = {e["name"] for e in evs if "name" in e}
    for want in expect_spans:
        if want not in names:
            errs.append(f"{path}: expected span/event {want!r}; "
                        f"have {sorted(names)}")
    n_spans = sum(1 for e in evs if e.get("ph") == "X")
    if n_spans == 0:
        errs.append(f"{path}: no complete ('X') spans recorded")
    return errs


def check_metrics(path: str, expect_counters: list[str]) -> list[str]:
    with open(path) as f:
        snap = json.load(f)
    errs = []
    for section in ("counters", "gauges", "histograms"):
        if section not in snap:
            errs.append(f"{path}: missing section {section!r}")
    series = list(snap.get("counters", {})) + list(snap.get("gauges", {}))
    for want in expect_counters:
        if not any(k.startswith(want) for k in series):
            errs.append(f"{path}: no series starting with {want!r}")
    return errs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace")
    ap.add_argument("metrics")
    ap.add_argument("--expect-span", action="append", default=[],
                    metavar="NAME")
    ap.add_argument("--expect-counter", action="append", default=[],
                    metavar="PREFIX")
    args = ap.parse_args()
    errs = check_trace(args.trace, args.expect_span)
    errs += check_metrics(args.metrics, args.expect_counter)
    for e in errs:
        print(f"[validate_telemetry] FAIL {e}", file=sys.stderr)
    if errs:
        raise SystemExit(1)
    print(f"[validate_telemetry] OK {args.trace} {args.metrics}")


if __name__ == "__main__":
    main()
