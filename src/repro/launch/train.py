"""Training launcher: a self-healing train loop over the full runtime.

The loop is an explicit recovery state machine — every transition below is
exercised by injected faults (``repro.runtime.chaos``) in tests and CI,
not assumed::

            +--------------------- RUN ----------------------+
            | step -> heartbeat -> monitor.check -> guard    |
            +--+----------------+----------------------+-----+
               | host dead /    | guard: "rollback"    | guard: "skip"
               | straggler      | (skip budget blown   | (nonfinite grad;
               v                |  or loss spike)      |  params untouched
            REMESH              v                      |  by the in-jit
            plan_elastic_    RESTORE                   |  finite guard)
            remesh over      newest INTACT checkpoint  |
            survivors  --->  (CRC-verified, falls  ----+--> back to RUN
            re-shard         back past corrupt steps),
            data + params    rewind step counter

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 20 --ckpt-dir /tmp/ckpt
    # fault drills: die at step 12, NaN burst at 5, corrupt the step-10 save
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 20 \
        --ckpt-dir /tmp/ckpt --ckpt-every 5 --chaos kill@12 --chaos nan@5

Integrates host-sharded synthetic data with prefetch (step-indexed, so a
restart or an elastic re-shard replays the exact global batches), a jit'd
train step with the production shardings and an all-reduced finite flag,
async CRC-committed checkpointing with restart discovery, and a simulated
multi-host fleet (``n_hosts``): peer heartbeats are driven synthetically
on a per-step virtual clock so silence/straggler chaos is deterministic,
while host 0's compute is real.  In a real pod the peers are processes and
the mesh is rebuilt from survivors; here the device set is this
container's and ``sharding_fn`` re-places restored state onto it — the
elastic interfaces (plan, re-shard, step-indexed data resume) are the same.

Worker mode (``--process-id R --num-processes W``, launched by
``repro.launch.supervisor``): this process is rank R of a real W-process
fleet.  Each rank computes the identical full global batch (deterministic
redundancy — no cross-process collectives, so a CPU fleet works and
params stay bit-identical across ranks, which the result files prove via
``tree_fingerprint``), publishes per-step heartbeat files the supervisor
watches, dies with exit status 43 on an injected kill, and on a gang
restart optionally restores STRIPED: each rank reads 1/W of the shard
bytes and all-gathers the rest from peers over loopback TCP
(``--stripe-ports``).  ``--total-steps`` gives the run's global horizon
so a restarted worker resumes from its checkpoint and stops at the same
step the uninterrupted run would — the bit-identical-resume contract.
``--distributed jax`` additionally brings up ``jax.distributed`` via the
version-compat shim (optional: coordinator rejoin after a mid-run worker
restart is not reliable across jax versions, so supervision never
depends on it).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.obs as obs
from repro.checkpoint import CheckpointManager
from repro.configs import get_bundle
from repro.data import DataConfig, make_train_iterator
from repro.launch.mesh import (make_local_mesh, make_production_mesh,
                               make_worker_mesh)
from repro.optim import AdamWConfig, adamw_init
from repro.parallel.sharding import param_specs
from repro.runtime import (ChaosInjector, ChaosKilled, FleetWorker,
                           HeartbeatMonitor, StragglerPolicy, compat,
                           plan_elastic_remesh, tree_fingerprint)
from repro.training import GradGuard, GuardPolicy, TrainHyper, make_train_step


def run(arch: str, *, smoke: bool = True, steps: int = 20,
        seq_len: int = 128, global_batch: int = 8, mesh_kind: str = "local",
        ckpt_dir: str | None = None, ckpt_every: int = 10,
        microbatches: int = 1, lr: float = 3e-4,
        log_every: int = 1, chaos=None, chaos_seed: int = 0,
        n_hosts: int = 1, hb_timeout_steps: float | None = None,
        straggler_factor: float | None = None,
        straggler_patience: int | None = None,
        guard_policy: GuardPolicy | None = None,
        max_recoveries: int = 8, trace_out: str | None = None,
        metrics_out: str | None = None, telemetry=None,
        fleet: FleetWorker | None = None,
        total_steps: int | None = None) -> dict:
    if chaos is not None and not isinstance(chaos, ChaosInjector):
        chaos = ChaosInjector(chaos, seed=chaos_seed)
    if fleet is not None and fleet.distributed == "jax" and fleet.coordinator:
        # must run before any other jax call (backend init is sticky)
        fleet.dist_ok = compat.distributed_initialize(
            fleet.coordinator, fleet.num_processes, fleet.process_id)
    bundle = get_bundle(arch, smoke=smoke)
    if fleet is not None:
        mesh = make_worker_mesh()
    else:
        mesh = {"local": make_local_mesh,
                "single": make_production_mesh,
                "multi": lambda: make_production_mesh(multi_pod=True)
                }[mesh_kind]()
    # captured while the (optional) distributed backend is known-alive;
    # with jax.distributed up these are GLOBAL counts (process_count == 1
    # means the barrier never formed; device_count additionally scales
    # with any forced host-platform device multiplicity)
    n_devices = jax.device_count()
    n_procs = jax.process_count()

    key = jax.random.PRNGKey(0)
    params = bundle.init_params(key)
    opt = adamw_init(params)

    pspecs = param_specs(bundle.kind, params, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    tree_sh = {"params": psh,
               "opt": {"mu": psh, "nu": psh,
                       "step": NamedSharding(mesh, P())}}

    def sharding_fn(tree):
        """Elastic re-shard: place a restored host tree onto whatever mesh
        this process currently drives."""
        return jax.device_put(tree, tree_sh)

    params = jax.device_put(params, psh)
    opt = jax.device_put(opt, tree_sh["opt"])

    vocab = getattr(bundle.cfg, "vocab")
    data_cfg = DataConfig(vocab=vocab, seq_len=seq_len,
                          global_batch=global_batch)

    start_step = 0
    mgr = None
    exchange = None
    # with replicated fleet compute every rank holds identical state, so
    # rank 0 alone writes checkpoints (it is host 0, the manifest writer);
    # every rank restores from the shared dir
    can_save = fleet is None or fleet.process_id == 0
    if ckpt_dir:
        mgr = CheckpointManager(
            ckpt_dir,
            fault_hook=chaos.checkpoint_write_hook if chaos is not None
            and can_save else None)
        stripe = None
        if fleet is not None and fleet.striped_restore:
            # collective striped restore: valid only on a gang start where
            # every rank reaches this point (the supervisor guarantees it
            # by passing --striped-restore to whole gangs only)
            exchange = fleet.make_exchange()
            if exchange is not None:
                stripe = (fleet.process_id, fleet.num_processes, exchange)
        restored = mgr.restore({"params": params, "opt": opt},
                               sharding_fn=sharding_fn, stripe=stripe)
        if restored is not None:
            start_step, tree = restored
            params, opt = tree["params"], tree["opt"]
            print(f"[train] restored step {start_step} from {ckpt_dir}"
                  f"{' (striped)' if stripe else ''}")

    # the LR schedule spans the run's GLOBAL horizon (restored start +
    # remaining steps), so a crash-restarted run rebuilds the exact
    # schedule the uninterrupted run used — bit-identical resume depends
    # on it (a schedule over "steps remaining" would diverge post-warmup).
    # `total_steps` (the supervisor's fixed horizon) pins that endpoint
    # explicitly so a restarted worker stops where the uninterrupted run
    # would, instead of running `steps` more from wherever it restored.
    end_step = max(total_steps, start_step) if total_steps is not None \
        else start_step + steps
    hyper = TrainHyper(optimizer=AdamWConfig(
        lr=lr, warmup_steps=5, total_steps=max(end_step, 10)),
        microbatches=microbatches)
    step_fn = make_train_step(bundle.forward, hyper)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    # -- simulated fleet: host 0 is this process; peers heartbeat on a
    # per-step virtual clock so chaos silence/slowness is deterministic
    host_id, rank, n_data_hosts = 0, 0, n_hosts
    assert global_batch % n_hosts == 0, (global_batch, n_hosts)
    vclock = [0.0]
    # telemetry traces the recovery state machine ON THE VIRTUAL CLOCK, so
    # a chaos scenario replays with bit-identical span timestamps (the
    # determinism test diffs two exported traces); installed globally so
    # GradGuard/checkpoint/kernel events land in the same registry
    tel = telemetry
    if tel is None:
        if trace_out or metrics_out:
            tel = obs.enable(clock=lambda: vclock[0], process_name="train")
        else:
            tel = obs.get_telemetry()
    monitor = HeartbeatMonitor(
        list(range(n_hosts)),
        StragglerPolicy.from_env(
            heartbeat_timeout_s=hb_timeout_steps,
            straggler_factor=straggler_factor,
            patience=straggler_patience,
            default=StragglerPolicy(heartbeat_timeout_s=4.0,
                                    straggler_factor=2.0, patience=3)),
        clock=lambda: vclock[0])
    guard = GradGuard(guard_policy or GuardPolicy())

    def make_extras(per_host_batch: int) -> dict:
        extras = {}
        if bundle.kind == "audio":
            extras["frames"] = np.zeros(
                (per_host_batch, bundle.cfg.n_audio_ctx, bundle.cfg.d_model),
                np.float32)
        if bundle.kind == "vlm":
            extras["vision"] = np.zeros(
                (per_host_batch, bundle.cfg.vision_tokens,
                 bundle.cfg.d_model), np.float32)
        return extras

    it = make_train_iterator(data_cfg, host_id=rank, n_hosts=n_data_hosts,
                             start_step=start_step)
    extras = make_extras(global_batch // n_data_hosts)

    history, step_log, events = [], [], []
    i = start_step
    recoveries = 0
    last_saved = start_step if mgr else None

    def ckpt_wait(at_step: int) -> bool:
        """Land the in-flight async save; a FAILED WRITE (e.g. chaos
        diskfull -> ENOSPC) is an event, never a crash — a full disk
        costs recovery-point age, not the run."""
        try:
            mgr.wait()
            return True
        except OSError as e:
            events.append({"kind": "ckpt_save_failed", "step": at_step,
                           "error": str(e)})
            print(f"[train] checkpoint save failed ({e}); continuing")
            return False

    def restore_or_keep(reason: str, at_step: int) -> int:
        """RESTORE state: rewind to the newest intact checkpoint (the
        manager walks past corrupt ones); with nothing restorable, keep
        the current (guarded) state and continue forward."""
        nonlocal params, opt
        with tel.span("RESTORE", step=at_step, reason=reason):
            if mgr is None:
                events.append({"kind": "rollback_unavailable",
                               "step": at_step, "reason": reason})
                return at_step
            ckpt_wait(at_step)
            restored = mgr.restore({"params": params, "opt": opt},
                                   sharding_fn=sharding_fn)
            if restored is None:
                events.append({"kind": "rollback_unavailable",
                               "step": at_step, "reason": reason})
                return at_step
            rstep, tree = restored
            params, opt = tree["params"], tree["opt"]
            events.append({"kind": "restore", "step": at_step,
                           "restored_step": rstep, "reason": reason})
            print(f"[train] {reason} at step {at_step}: restored checkpoint "
                  f"step {rstep}")
            return rstep

    fired_seen = len(chaos.fired) if chaos is not None else 0

    def drain_chaos_instants(at_step: int) -> None:
        """Mirror newly-fired chaos events into the trace as instants."""
        nonlocal fired_seen
        if chaos is None or not tel.enabled:
            return
        for ev in chaos.fired[fired_seen:]:
            tel.instant("chaos", cat="chaos", event=str(ev), step=at_step)
        fired_seen = len(chaos.fired)

    def reopen_data(at_step: int) -> None:
        nonlocal it, extras
        it.close()
        it = make_train_iterator(data_cfg, host_id=rank,
                                 n_hosts=n_data_hosts, start_step=at_step)
        extras = make_extras(global_batch // n_data_hosts)

    run_span = tel.begin("RUN", cat="state", step=i) if tel.enabled else None
    try:
        with compat.set_mesh(mesh):
            while i < end_step:
                vclock[0] += 1.0
                if fleet is not None and not (
                        chaos is not None
                        and chaos.partitioned(i, fleet.process_id)):
                    fleet.heartbeat(i)
                if chaos is not None:
                    try:
                        # raises ChaosKilled (exit 43); fleet workers die
                        # only when the spec targets their rank
                        chaos.maybe_kill(
                            i, rank=fleet.process_id if fleet else None)
                    except ChaosKilled:
                        # preemption grace (SIGTERM-style): an in-flight
                        # async save lands before death, so "the last
                        # completed checkpoint" is a deterministic notion.
                        # NOTHING here may displace the kill — a pending
                        # save error surfacing now would turn exit 43
                        # into exit 1 and the supervisor would misread
                        # chaos as a crash
                        if mgr:
                            try:
                                mgr.wait()
                            except Exception:
                                pass
                        raise

                t0 = time.time()
                idx, batch = it.next()
                assert idx == i, (idx, i)
                batch = {**batch, **extras}
                gs = np.float32(chaos.grad_scale(i)) if chaos is not None \
                    else np.float32(1.0)
                params, opt, metrics = jit_step(params, opt, batch, gs)
                loss = float(metrics["loss"])
                finite = bool(float(metrics["finite"]) > 0.0)
                dt = time.time() - t0

                # heartbeats: ours is real; simulated peers echo our step
                # time unless chaos silences or slows them
                for h in monitor.alive_hosts():
                    if chaos is not None:
                        if chaos.heartbeat_silenced(h, i):
                            continue
                        monitor.heartbeat(
                            h, dt * chaos.step_time_factor(h, i))
                    else:
                        monitor.heartbeat(h, dt)
                failed = monitor.check()
                action = guard.update(loss, finite)
                drain_chaos_instants(i)
                if tel.enabled:
                    tel.metrics.observe("train_step_s", dt)

                history.append(loss)
                step_log.append(i)
                if i % log_every == 0:
                    flag = "" if finite else "  [nonfinite->skipped]"
                    print(f"[train] step {i} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms){flag}")

                if failed:
                    # FAULT -> RESTORE -> REMESH: stop, restore the newest
                    # intact checkpoint, re-plan the mesh over survivors,
                    # re-shard params/opt and the step-indexed data stream
                    recoveries += 1
                    if recoveries > max_recoveries:
                        raise RuntimeError("recovery limit exceeded")
                    tel.finish(run_span, end_step=i, reason="host_failure")
                    run_span = None
                    with tel.span("REMESH", cat="state", step=i,
                                  failed=str(failed)):
                        survivors = monitor.alive_hosts()
                        if host_id not in survivors:
                            raise RuntimeError(
                                f"host {host_id} was evicted")
                        plan = plan_elastic_remesh(survivors,
                                                   chips_per_host=1,
                                                   model_parallel=1)
                        rank = plan.host_ranks[host_id]
                        n_data_hosts = plan.n_hosts
                        assert global_batch % n_data_hosts == 0, \
                            (global_batch, n_data_hosts)
                        events.append({"kind": "remesh", "step": i,
                                       "failed": failed,
                                       "survivors": survivors,
                                       "plan": dataclasses.asdict(plan)})
                        print(f"[train] hosts {failed} failed at step {i}; "
                              f"remesh over {survivors} "
                              f"(dp={plan.data_parallel})")
                    i = restore_or_keep("host failure", i)
                    reopen_data(i)
                    guard.reset()
                    if tel.enabled:
                        run_span = tel.begin("RUN", cat="state", step=i)
                    continue

                if action == "rollback":
                    recoveries += 1
                    if recoveries > max_recoveries:
                        raise RuntimeError("recovery limit exceeded")
                    print(f"[guard] step {i}: rollback "
                          f"(trigger={guard.last_trigger})")
                    tel.instant("guard_rollback", cat="guard", step=i,
                                trigger=guard.last_trigger)
                    tel.finish(run_span, end_step=i, reason="divergence")
                    run_span = None
                    i = restore_or_keep("divergence", i)
                    reopen_data(i)
                    guard.reset()
                    if tel.enabled:
                        run_span = tel.begin("RUN", cat="state", step=i)
                    continue

                if action == "skip":
                    print(f"[guard] step {i}: skip "
                          f"(trigger={guard.last_trigger}, consecutive="
                          f"{guard.consecutive_skips})")
                    tel.instant("guard_skip", cat="guard", step=i,
                                trigger=guard.last_trigger)
                    events.append({"kind": "skip", "step": i})

                if mgr and can_save and (i + 1) % ckpt_every == 0:
                    ckpt_wait(i)   # surface a prior failed write first
                    mgr.save_async(i + 1, {"params": params, "opt": opt})
                    last_saved = i + 1
                    if chaos is not None and chaos.wants_corrupt(i + 1):
                        if ckpt_wait(i + 1):   # land it, then damage it
                            chaos.maybe_corrupt(ckpt_dir, i + 1)
                i += 1
            if mgr and can_save:
                final_ok = ckpt_wait(end_step)
                if last_saved != end_step or not final_ok:
                    mgr.save_async(end_step,
                                   {"params": params, "opt": opt})
                    ckpt_wait(end_step)
    finally:
        # teardown must never displace an in-flight ChaosKilled (exit 43 is
        # the supervisor's restart signal) — every item is individually
        # contained
        for teardown in (it.close,
                         lambda: drain_chaos_instants(i),
                         lambda: tel.finish(run_span, end_step=i),
                         # artifacts land even when a chaos kill unwinds
                         # the loop — the restart inspects the dead run's
                         # trace
                         lambda: trace_out and tel.write_trace(trace_out),
                         lambda: metrics_out
                         and tel.write_metrics(metrics_out),
                         lambda: exchange and exchange.close(),
                         lambda: fleet is not None and fleet.dist_ok
                         and compat.distributed_shutdown()):
            try:
                teardown()
            except Exception as e:
                print(f"[train] teardown error (ignored): {e!r}")
    if fleet is not None:
        fleet.write_result({
            "params_crc": tree_fingerprint({"params": params, "opt": opt}),
            "first_loss": history[0] if history else None,
            "final_loss": history[-1] if history else None,
            "start_step": start_step, "end_step": end_step,
            "dist_ok": fleet.dist_ok,
            "device_count": n_devices,
            "process_count": n_procs,
        })
    return {"losses": history, "steps": step_log, "events": events,
            "params": params, "opt": opt,
            "telemetry": tel.snapshot() if tel.enabled else None}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--chaos", action="append", default=None,
                    metavar="SPEC",
                    help="inject a fault (repeatable): kill@N, nan@N, "
                         "silence@N:host=H, slow@N:host=H,factor=F, "
                         "corrupt@N:mode=flip|truncate, diskfull@N, "
                         "partition@N:host=H (sigkill@N:host=H is "
                         "supervisor-side; see repro.launch.supervisor)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--n-hosts", type=int, default=1,
                    help="simulated fleet size (peers heartbeat "
                         "synthetically; host 0 is this process)")
    ap.add_argument("--hb-timeout-steps", type=float, default=None,
                    help="heartbeat timeout in virtual steps (default 4; "
                         "env REPRO_HEARTBEAT_TIMEOUT)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace JSON (perfetto-loadable) "
                         "of the RUN/REMESH/RESTORE state machine")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot as JSON")
    # -- real-fleet worker mode (passed by repro.launch.supervisor) --------
    ap.add_argument("--process-id", type=int, default=0, metavar="R")
    ap.add_argument("--num-processes", type=int, default=None, metavar="W",
                    help="run as rank R of a W-process fleet")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT")
    ap.add_argument("--fleet-dir", default=None, metavar="DIR",
                    help="shared dir for heartbeat files")
    ap.add_argument("--fleet-tag", type=int, default=None,
                    help="stable worker id across re-mesh renumbering")
    ap.add_argument("--stripe-ports", default=None, metavar="P0,P1,...",
                    help="per-rank TCP ports for striped restore")
    ap.add_argument("--striped-restore", action="store_true")
    ap.add_argument("--distributed", default="none",
                    choices=["none", "jax"])
    ap.add_argument("--result-out", default=None, metavar="PATH")
    ap.add_argument("--total-steps", type=int, default=None,
                    help="global step horizon (restart-safe endpoint); "
                         "overrides --steps counting from the restore")
    a = ap.parse_args()
    fleet = None
    if a.num_processes is not None:
        ports = tuple(int(p) for p in a.stripe_ports.split(",")) \
            if a.stripe_ports else ()
        fleet = FleetWorker(process_id=a.process_id,
                            num_processes=a.num_processes,
                            fleet_dir=a.fleet_dir, tag=a.fleet_tag,
                            coordinator=a.coordinator, stripe_ports=ports,
                            striped_restore=a.striped_restore,
                            distributed=a.distributed,
                            result_out=a.result_out)
    try:
        out = run(a.arch, smoke=a.smoke, steps=a.steps, seq_len=a.seq_len,
                  global_batch=a.global_batch, mesh_kind=a.mesh,
                  ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every,
                  microbatches=a.microbatches, lr=a.lr, chaos=a.chaos,
                  chaos_seed=a.chaos_seed, n_hosts=a.n_hosts,
                  hb_timeout_steps=a.hb_timeout_steps,
                  trace_out=a.trace_out, metrics_out=a.metrics_out,
                  fleet=fleet, total_steps=a.total_steps)
    except ChaosKilled as e:
        # belt-and-braces: ChaosKilled IS a SystemExit(43), but anything
        # that re-wrapped it on the way up must not change the status the
        # supervisor keys its restart policy on
        raise SystemExit(e.code)
    losses = out["losses"]
    if losses:
        print(f"[train] done: first loss {losses[0]:.4f}, "
              f"last loss {losses[-1]:.4f}, "
              f"{len(out['events'])} fault events")
    else:
        # a restarted worker can restore AT the horizon: nothing to do
        # is success, not a crash
        print("[train] done: horizon already reached at restore; no steps")


if __name__ == "__main__":
    main()
