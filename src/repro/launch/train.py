"""Training launcher: data pipeline -> sharded train loop -> checkpoints.

Integrates the full runtime: host-sharded synthetic data with prefetch,
jit'd train step with the production shardings (scaled down automatically on
this CPU container via --mesh local), async checkpointing with restart
discovery, heartbeat/straggler bookkeeping, and elastic re-shard on restore.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_bundle
from repro.data import DataConfig, make_train_iterator
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.optim import AdamWConfig, adamw_init
from repro.parallel.sharding import param_specs
from repro.runtime import HeartbeatMonitor, compat
from repro.training import TrainHyper, make_train_step


def run(arch: str, *, smoke: bool = True, steps: int = 20,
        seq_len: int = 128, global_batch: int = 8, mesh_kind: str = "local",
        ckpt_dir: str | None = None, ckpt_every: int = 10,
        microbatches: int = 1, lr: float = 3e-4,
        log_every: int = 1) -> dict:
    bundle = get_bundle(arch, smoke=smoke)
    mesh = {"local": make_local_mesh,
            "single": make_production_mesh,
            "multi": lambda: make_production_mesh(multi_pod=True)}[mesh_kind]()

    hyper = TrainHyper(optimizer=AdamWConfig(lr=lr, warmup_steps=5,
                                             total_steps=max(steps, 10)),
                       microbatches=microbatches)
    step_fn = make_train_step(bundle.forward, hyper)

    key = jax.random.PRNGKey(0)
    params = bundle.init_params(key)
    opt = adamw_init(params)

    pspecs = param_specs(bundle.kind, params, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, psh)
    opt = {"mu": jax.device_put(opt["mu"], psh),
           "nu": jax.device_put(opt["nu"], psh),
           "step": jax.device_put(opt["step"], NamedSharding(mesh, P()))}

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    vocab = getattr(bundle.cfg, "vocab")
    data_cfg = DataConfig(vocab=vocab, seq_len=seq_len,
                          global_batch=global_batch)

    start_step = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir)
        restored = mgr.restore({"params": params, "opt": opt})
        if restored is not None:
            start_step, tree = restored
            params, opt = tree["params"], tree["opt"]
            print(f"[train] restored step {start_step} from {ckpt_dir}")

    it = make_train_iterator(data_cfg, start_step=start_step)
    monitor = HeartbeatMonitor([0])
    history = []
    extras = {}
    if bundle.kind == "audio":
        extras["frames"] = np.zeros(
            (global_batch, bundle.cfg.n_audio_ctx, bundle.cfg.d_model),
            np.float32)
    if bundle.kind == "vlm":
        extras["vision"] = np.zeros(
            (global_batch, bundle.cfg.vision_tokens, bundle.cfg.d_model),
            np.float32)

    try:
        with compat.set_mesh(mesh):
            for i in range(start_step, start_step + steps):
                t0 = time.time()
                idx, batch = it.next()
                batch = {**batch, **extras}
                params, opt, metrics = jit_step(params, opt, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                monitor.heartbeat(0, dt)
                history.append(loss)
                if i % log_every == 0:
                    print(f"[train] step {i} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms)")
                if mgr and (i + 1) % ckpt_every == 0:
                    mgr.save_async(i + 1, {"params": params, "opt": opt})
            if mgr:
                mgr.save_async(start_step + steps,
                               {"params": params, "opt": opt})
                mgr.wait()
    finally:
        it.close()
    return {"losses": history, "params": params, "opt": opt}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="local",
                    choices=["local", "single", "multi"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    a = ap.parse_args()
    out = run(a.arch, smoke=a.smoke, steps=a.steps, seq_len=a.seq_len,
              global_batch=a.global_batch, mesh_kind=a.mesh,
              ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every,
              microbatches=a.microbatches, lr=a.lr)
    losses = out["losses"]
    print(f"[train] done: first loss {losses[0]:.4f}, "
          f"last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
