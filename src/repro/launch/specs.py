"""In-sharding construction for every step function (dry-run + launchers).

Rules (DESIGN.md §6): batch on the data axes (pod+data), features on
`model`, vocab on `model` (configs pad vocab to a multiple of 256), caches
batch-on-data + (kv-heads | head-dim | seq) on `model` by divisibility, and
the long-context batch=1 shapes shard the SEQUENCE on data (SP).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import param_specs


def data_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else axes[0]


def _dsize(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _msize(mesh: Mesh) -> int:
    return mesh.shape["model"]


def batch_spec_tree(specs: dict, mesh: Mesh, *, long: bool) -> dict:
    """Shardings for the input batch dict (tokens/labels/vision/frames)."""
    d = data_axes(mesh)
    out = {}
    for k, v in specs.items():
        if k == "cache":
            continue
        if long:
            # batch=1: replicate tokens (1,1); shard long seq dims on data
            spec = [None] * v.ndim
            for i, s in enumerate(v.shape[1:], start=1):
                if s % _dsize(mesh) == 0 and s > 1:
                    spec[i] = d
                    break
            out[k] = P(*spec)
        else:
            spec = [None] * v.ndim
            if v.shape[0] % _dsize(mesh) == 0:
                spec[0] = d
            out[k] = P(*spec)
    return out


def cache_spec_tree(cache_shapes: Any, mesh: Mesh, *, long: bool) -> Any:
    """Shardings for a cache pytree, keyed by leaf name + divisibility."""
    d = data_axes(mesh)
    ms = _msize(mesh)
    ds = _dsize(mesh)

    def leaf_spec(path, leaf):
        key = getattr(path[-1], "key", str(path[-1]))
        shape = leaf.shape
        nd = len(shape)
        if key == "length":
            return P(d) if shape[0] % ds == 0 else P(None)
        spec = [None] * nd
        if key in ("k", "v", "xk", "xv"):
            # (L|G, B, S, Kv, Dh)
            if not long and shape[1] % ds == 0:
                spec[1] = d
            if long and shape[2] % ds == 0:
                spec[2] = d          # SP: shard cache sequence
            if shape[3] % ms == 0:
                spec[3] = "model"    # kv heads
            elif shape[4] % ms == 0:
                spec[4] = "model"    # head dim
            elif not long and shape[2] % ms == 0:
                spec[2] = "model"    # cache sequence on model
            return P(*spec)
        if key in ("k_scale", "v_scale"):
            # (L, B, S, Kv)
            if not long and shape[1] % ds == 0:
                spec[1] = d
            if long and shape[2] % ds == 0:
                spec[2] = d
            if shape[3] % ms == 0:
                spec[3] = "model"
            elif not long and shape[2] % ms == 0:
                spec[2] = "model"
            return P(*spec)
        if key in ("conv", "ssm"):
            # mamba: (L, B, w, dxbc) / (L, B, H, Pd, N)
            if shape[1] % ds == 0:
                spec[1] = d
            if shape[-1] % ms == 0 and key == "conv":
                spec[-1] = "model"
            if key == "ssm" and shape[2] % ms == 0:
                spec[2] = "model"
            return P(*spec)
        if key in ("conv_g", "lru_g", "conv_t", "lru_t"):
            # rg: (G, 2, B, w, W) / (G, 2, B, W) / (Tr, B, w, W) / (Tr, B, W)
            bidx = 2 if key.endswith("_g") else 1
            if shape[bidx] % ds == 0:
                spec[bidx] = d
            if shape[-1] % ms == 0:
                spec[-1] = "model"
            return P(*spec)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


def step_in_shardings(bundle, shape_name: str, mesh: Mesh):
    """(abstract_args, in_shardings, step_fn, donate) for one cell."""
    from repro.training import TrainHyper, make_train_step
    from repro.optim import adamw_init

    kind = bundle.step_kind(shape_name)
    long = shape_name == "long_500k"
    specs = bundle.input_specs(shape_name)
    aparams = bundle.abstract_params()
    pspecs = param_specs(bundle.kind, aparams, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))

    if kind == "train":
        hyper = TrainHyper()
        step = make_train_step(bundle.forward, hyper)
        aopt = jax.eval_shape(adamw_init, aparams)
        opt_sh = {
            "mu": psh, "nu": jax.tree.map(lambda x: x, psh),
            "step": NamedSharding(mesh, P()),
        }
        bspec = batch_spec_tree(specs, mesh, long=long)
        bsh = {k: NamedSharding(mesh, s) for k, s in bspec.items()}
        args = (aparams, aopt, specs)
        shardings = (psh, opt_sh, bsh)
        return args, shardings, step, (0, 1)   # donate params + opt state

    if kind == "prefill":
        cache_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            cache_spec_tree(specs["cache"], mesh, long=long),
            is_leaf=lambda x: isinstance(x, P))
        bspec = batch_spec_tree(specs, mesh, long=long)

        extras_keys = [k for k in specs if k not in ("tokens", "cache")]

        def step(params, tokens, cache, extras):
            return bundle.prefill(params, tokens, cache, extras)

        args = (aparams, specs["tokens"], specs["cache"],
                {k: specs[k] for k in extras_keys})
        shardings = (psh, NamedSharding(mesh, bspec["tokens"]), cache_sh,
                     {k: NamedSharding(mesh, bspec[k]) for k in extras_keys})
        return args, shardings, step, (2,)      # donate the cache

    # decode
    cache_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        cache_spec_tree(specs["cache"], mesh, long=long),
        is_leaf=lambda x: isinstance(x, P))
    bspec = batch_spec_tree(specs, mesh, long=long)

    def step(params, tokens, cache):
        return bundle.decode_step(params, tokens, cache)

    args = (aparams, specs["tokens"], specs["cache"])
    shardings = (psh, NamedSharding(mesh, bspec["tokens"]), cache_sh)
    return args, shardings, step, (2,)          # donate the cache
