"""Serving launcher: continuous-batching engine (dense or paged KV) over a
bundle.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 6
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --kv-mode paged --page-size 16

Fleet modes (the serving fleet of ``serving/fleet.py``):

    # N REAL serve worker processes under runtime/supervisor.py; a worker
    # killed by --chaos die@T:host=H exits 43 and is restarted
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --kv-mode paged --fleet 2 --chaos die@4:host=1

    # one worker process (the supervisor builds this argv itself)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --kv-mode paged --worker --process-id 0 --num-processes 2 ...

Every fleet member regenerates the same seeded request trace and serves
the slice ``rid % world == rank``, so the merged results are comparable
request-by-request against a single-engine run of the same trace.
:func:`build_fleet` is the in-process flavour (a
:class:`~repro.serving.LocalFleet` over engines sharing one bundle +
params) that tests and benchmarks drive.

Paged modes need a transformer-family arch (attention KV); SSM/audio
families serve on the dense path.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from repro.configs import get_bundle
from repro.serving import ServeConfig, ServingEngine


class _BundleAdapter:
    """Adapts an ArchBundle to the ServingEngine interface (binds extras,
    forwards the serving-capability surface)."""

    def __init__(self, bundle, extras=None):
        self.bundle = bundle
        self.extras = extras or {}
        self.cfg = bundle.cfg
        self.kind = bundle.kind
        self.supports_paged_kv = bundle.supports_paged_kv
        self.prefill_supports_true_lengths = \
            bundle.prefill_supports_true_lengths

    def init_cache(self, batch, max_len):
        return self.bundle.init_cache(batch, max_len)

    def prefill(self, params, tokens, cache, true_lengths=None):
        return self.bundle.prefill(params, tokens, cache,
                                   batch_extras=self._sized(tokens.shape[0]),
                                   true_lengths=true_lengths)

    def _sized(self, b):
        return {k: v[:b] for k, v in self.extras.items()} or None

    def decode_step(self, params, tokens, cache):
        return self.bundle.decode_step(params, tokens, cache)

    def cache_batch_axes(self, cache):
        return self.bundle.cache_batch_axes(cache)

    def init_paged_pool(self, num_pages, page_size, kv_dtype=None):
        return self.bundle.init_paged_pool(num_pages, page_size,
                                           kv_dtype=kv_dtype)

    def paged_step(self, params, tokens, pool, page_table, lengths, counts):
        return self.bundle.paged_step(params, tokens, pool, page_table,
                                      lengths, counts)


def build_engine(arch: str, *, smoke: bool = True, slots: int = 4,
                 max_len: int = 64, max_new: int = 8, kv_mode: str = "dense",
                 page_size: int = 16, num_pages: int | None = None,
                 prefill_chunk: int = 32, prefix_cache: bool = True,
                 seed: int = 0, mesh=None,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0, telemetry=None, **degrade):
    """(engine, vocab) ready for submit()/run() — shared by the launcher,
    tests and benchmarks so every caller serves through the same stack.
    ``mesh`` (a concrete Mesh) shards the paged pool per
    ``parallel.sharding.paged_pool_specs``.  ``temperature``/``top_k``/
    ``sample_seed`` select seeded sampled decode (greedy by default).
    Extra keywords flow into :class:`ServeConfig` — the graceful-
    degradation knobs (``max_admission_retries``, ``admission_backoff``,
    ``shed_pressure``, ``shed_patience``, ``shed_min_priority``)."""
    bundle = get_bundle(arch, smoke=smoke)
    params = bundle.init_params(jax.random.PRNGKey(seed))
    extras = {}
    if bundle.kind == "audio":
        extras["frames"] = np.zeros(
            (slots, bundle.cfg.n_audio_ctx, bundle.cfg.d_model), np.float32)
    if bundle.kind == "vlm":
        extras["vision"] = np.zeros(
            (slots, bundle.cfg.vision_tokens, bundle.cfg.d_model), np.float32)
    engine = ServingEngine(
        _BundleAdapter(bundle, extras), params,
        ServeConfig(batch=slots, max_len=max_len, max_new_tokens=max_new,
                    kv_mode=kv_mode, page_size=page_size,
                    num_pages=num_pages, prefill_chunk=prefill_chunk,
                    prefix_cache=prefix_cache,
                    temperature=temperature, top_k=top_k,
                    sample_seed=sample_seed, **degrade),
        mesh=mesh, telemetry=telemetry)
    return engine, bundle.cfg.vocab


def build_fleet(arch: str, n_hosts: int, *, smoke: bool = True,
                slots: int = 2, max_len: int = 64, max_new: int = 8,
                kv_mode: str = "paged", page_size: int = 16,
                num_pages: int | None = None, prefill_chunk: int = 32,
                seed: int = 0, fleet_cfg=None, chaos=None,
                telemetry=None, **degrade):
    """(fleet, vocab): ``n_hosts`` in-process serving engines sharing ONE
    bundle + params — the fleet determinism contract (identical weights
    on every host is what makes fleet tokens == single-engine tokens) —
    behind the :class:`~repro.serving.LocalFleet` router.  ``chaos`` is a
    ChaosInjector consulted on the fleet tick clock (die / netsplit /
    pagecorrupt)."""
    from repro.serving import FleetConfig, LocalFleet
    bundle = get_bundle(arch, smoke=smoke)
    params = bundle.init_params(jax.random.PRNGKey(seed))
    adapter = _BundleAdapter(bundle, {})
    cfg = ServeConfig(batch=slots, max_len=max_len, max_new_tokens=max_new,
                      kv_mode=kv_mode, page_size=page_size,
                      num_pages=num_pages, prefill_chunk=prefill_chunk,
                      **degrade)
    engines = [ServingEngine(adapter, params, cfg, telemetry=telemetry)
               for _ in range(n_hosts)]
    fleet = LocalFleet(engines, fleet_cfg or None, chaos=chaos,
                       telemetry=telemetry)
    return fleet, bundle.cfg.vocab


def fleet_trace(vocab: int, *, n_requests: int, prompt_len: int = 12,
                prefix_share: float = 0.0, seed: int = 0):
    """The canonical seeded request trace — the supervisor parent, every
    worker process, and the single-engine baseline regenerate it
    identically, so per-request outputs are comparable across all
    three."""
    rng = np.random.default_rng(seed)
    common = rng.integers(0, vocab, size=max(1, prompt_len // 2))
    prompts = []
    for i in range(n_requests):
        p = rng.integers(0, vocab, size=prompt_len).astype(np.int32)
        if prefix_share > 0 and i % max(1, round(1 / prefix_share)) == 0:
            p[:len(common)] = common
        prompts.append(p)
    return prompts


def run_worker(a) -> None:
    """One serve worker process under the supervisor: serve the trace
    slice ``rid % world == rank``, heartbeat per tick, die on an active
    ``die`` chaos spec (exit 43 -> supervised restart without chaos)."""
    from repro.runtime.chaos import ChaosInjector
    from repro.runtime.fleet import FleetWorker
    worker = FleetWorker(process_id=a.process_id,
                         num_processes=a.num_processes,
                         fleet_dir=a.fleet_dir, tag=a.tag,
                         result_out=a.result_out)
    chaos = ChaosInjector(a.chaos or (), seed=a.seed)
    engine, vocab = build_engine(
        a.arch, slots=a.slots, max_len=a.max_len, max_new=a.max_new,
        kv_mode=a.kv_mode, page_size=a.page_size, seed=a.seed)
    prompts = fleet_trace(vocab, n_requests=a.requests,
                          prompt_len=a.prompt_len,
                          prefix_share=a.prefix_share, seed=a.seed)
    rids = {}
    for i, p in enumerate(prompts):
        if i % a.num_processes == a.process_id:
            rids[i] = engine.submit(p)
    tick = 0
    while engine.pending():
        tick += 1
        chaos.maybe_die(tick, worker.tag)   # ChaosKilled -> exit 43
        engine.step()
        worker.heartbeat(tick)
    worker.heartbeat(tick)
    worker.write_result({
        "results": {str(i): [int(t) for t in engine.results[r]]
                    for i, r in rids.items()},
        "outcomes": {str(i): engine.outcomes[r] for i, r in rids.items()},
        "ticks": tick})
    print(f"[serve-worker {a.process_id}/{a.num_processes}] "
          f"{len(rids)} requests in {tick} ticks")


def run_fleet_supervised(a) -> dict:
    """``--fleet N``: N real serve worker processes under the process
    supervisor.  A worker killed by ``die`` chaos exits 43, restarts
    WITHOUT chaos (the supervisor strips the flags), and re-serves its
    slice; the parent merges the per-rank result JSONs."""
    import tempfile

    from repro.runtime.chaos import split_spec_strings
    from repro.runtime.supervisor import RestartPolicy, Supervisor
    fleet_dir = a.fleet_dir or tempfile.mkdtemp(prefix="serve_fleet_")
    results_dir = os.path.join(fleet_dir, "results")
    os.makedirs(results_dir, exist_ok=True)
    _, worker_chaos = split_spec_strings(a.chaos or ())

    def cmd(spec):
        argv = [sys.executable, "-m", "repro.launch.serve",
                "--arch", a.arch, "--worker",
                "--process-id", str(spec.rank),
                "--num-processes", str(spec.world),
                "--tag", str(spec.tag),
                "--fleet-dir", fleet_dir,
                "--requests", str(a.requests),
                "--prompt-len", str(a.prompt_len),
                "--prefix-share", str(a.prefix_share),
                "--kv-mode", a.kv_mode,
                "--page-size", str(a.page_size),
                "--slots", str(a.slots),
                "--max-len", str(a.max_len),
                "--max-new", str(a.max_new),
                "--seed", str(a.seed),
                "--result-out",
                os.path.join(results_dir, f"rank_{spec.tag}.json")]
        if spec.with_chaos:
            for c in worker_chaos:
                argv += ["--chaos", c]
        return argv

    sup = Supervisor(a.fleet, cmd, fleet_dir=fleet_dir,
                     policy=RestartPolicy(hang_timeout_s=120.0,
                                          max_wall_s=a.max_wall_s),
                     chaos_specs=a.chaos or (), chaos_seed=a.seed)
    report = sup.run()
    merged: dict[str, list[int]] = {}
    outcomes: dict[str, str] = {}
    for tag in range(a.fleet):
        path = os.path.join(results_dir, f"rank_{tag}.json")
        try:
            with open(path) as f:
                res = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        merged.update(res.get("results", {}))
        outcomes.update(res.get("outcomes", {}))
    print(f"[serve-fleet] outcome={report['outcome']} "
          f"failures={report['total_failures']} "
          f"served={len(merged)}/{a.requests} "
          f"wall={report['wall_s']:.1f}s dir={fleet_dir}")
    return {"report": report, "results": merged, "outcomes": outcomes}


def run(arch: str, *, smoke: bool = True, n_requests: int = 6,
        slots: int = 4, prompt_len: int = 12, max_new: int = 8,
        max_len: int = 64, seed: int = 0, kv_mode: str = "dense",
        page_size: int = 16, num_pages: int | None = None,
        prefix_cache: bool = True, prefix_share: float = 0.0,
        temperature: float = 0.0, top_k: int = 0,
        stream: bool = False, trace_out: str | None = None,
        metrics_out: str | None = None) -> dict:
    """Serve ``n_requests`` random prompts and return {rid: tokens}.

    ``prefix_share`` > 0 gives that fraction of the requests a common
    prompt prefix (half the prompt length) — the radix cache prefills it
    once and maps it read-only for every later arrival, which the printed
    ``prefix_hits``/``pages_shared`` counters make visible.  ``stream``
    consumes request 0 through the per-token generator API instead of the
    batch ``run()`` (the other requests still complete — streams drive
    the same continuous-batching ticks)."""
    tel = None
    if trace_out or metrics_out:
        import repro.obs as obs
        tel = obs.enable(process_name=f"serve:{kv_mode}")
    engine, vocab = build_engine(
        arch, smoke=smoke, slots=slots, max_len=max_len, max_new=max_new,
        kv_mode=kv_mode, page_size=page_size, num_pages=num_pages,
        prefix_cache=prefix_cache, seed=seed, temperature=temperature,
        top_k=top_k, sample_seed=seed, telemetry=tel)
    rng = np.random.default_rng(seed)
    common = rng.integers(0, vocab, size=max(1, prompt_len // 2))
    for i in range(n_requests):
        prompt = rng.integers(0, vocab, size=prompt_len).astype(np.int32)
        if prefix_share > 0 and i % max(1, round(1 / prefix_share)) == 0:
            prompt[:len(common)] = common
        engine.submit(prompt)
    t0 = time.time()
    if stream:
        first = [tok for tok in engine.stream(0)]
        print(f"[serve:{kv_mode}] streamed req 0: {first}")
    results = engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    stats = engine.kv_stats()
    line = (f"[serve:{kv_mode}] {n_requests} requests, {total_tokens} "
            f"tokens in {dt:.2f}s ({total_tokens/dt:.1f} tok/s, "
            f"kv_resident={stats['bytes_resident']/1e6:.2f}MB)")
    pstats = engine.prefix_stats() if kv_mode != "dense" else {}
    if pstats:
        line += (f" prefix_hits={pstats['hits']}/{pstats['lookups']} "
                 f"matched_tokens={pstats['matched_tokens']} "
                 f"cow={pstats['cow_copies']}")
    print(line)
    if tel is not None:
        snap = engine.telemetry()   # pull kv/prefix/traffic into registry
        if trace_out:
            print(f"[serve:{kv_mode}] trace -> "
                  f"{tel.write_trace(trace_out)}")
        if metrics_out:
            print(f"[serve:{kv_mode}] metrics -> "
                  f"{tel.write_metrics(metrics_out, extra={'serve': snap})}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--kv-mode", default="dense",
                    choices=("dense", "paged", "paged_int8"))
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None)
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="radix prefix sharing across requests (default on)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--prefix-share", type=float, default=0.0,
                    help="fraction of requests given a common prompt prefix")
    ap.add_argument("--stream", action="store_true",
                    help="consume request 0 via the token-streaming API")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples from softmax(logits/T)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k highest logits")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace JSON (perfetto-loadable) of "
                         "the serve: admission/prefix-match/prefill/decode "
                         "spans, request instants")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics snapshot (+ engine.telemetry()) "
                         "as JSON")
    # fleet modes (serving/fleet.py; see the module docstring)
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="run N real serve worker processes under the "
                         "process supervisor (0 = single engine)")
    ap.add_argument("--worker", action="store_true",
                    help="run as one supervised serve worker (internal; "
                         "the supervisor builds this argv)")
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--tag", type=int, default=None,
                    help="stable worker id across re-mesh renumbering")
    ap.add_argument("--fleet-dir", default=None)
    ap.add_argument("--result-out", default=None)
    ap.add_argument("--chaos", action="append", default=[],
                    metavar="SPEC", help="fault spec, e.g. die@4:host=1 "
                    "(repeatable; see runtime/chaos.py)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-wall-s", type=float, default=600.0,
                    help="fleet mode: whole-run wall-clock ceiling")
    a = ap.parse_args()
    if a.tag is None:
        a.tag = a.process_id
    if a.worker:
        run_worker(a)
        return
    if a.fleet > 1:
        run_fleet_supervised(a)
        return
    results = run(a.arch, n_requests=a.requests, slots=a.slots,
                  max_new=a.max_new, kv_mode=a.kv_mode,
                  page_size=a.page_size, num_pages=a.num_pages,
                  prefix_cache=a.prefix_cache, prefix_share=a.prefix_share,
                  stream=a.stream,
                  temperature=a.temperature, top_k=a.top_k,
                  trace_out=a.trace_out, metrics_out=a.metrics_out)
    for rid, toks in sorted(results.items()):
        print(f"  req {rid}: {toks}")


if __name__ == "__main__":
    main()
