"""Serving launcher: slot-based continuous-batching engine over a bundle.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 6
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import numpy as np

from repro.configs import get_bundle
from repro.serving import ServeConfig, ServingEngine


class _BundleAdapter:
    """Adapts an ArchBundle to the ServingEngine interface (binds extras)."""

    def __init__(self, bundle, extras=None):
        self.bundle = bundle
        self.extras = extras or {}

    def init_cache(self, batch, max_len):
        return self.bundle.init_cache(batch, max_len)

    def prefill(self, params, tokens, cache):
        return self.bundle.prefill(params, tokens, cache,
                                   batch_extras=self._sized(tokens.shape[0]))

    def _sized(self, b):
        return {k: v[:b] for k, v in self.extras.items()} or None

    def decode_step(self, params, tokens, cache):
        return self.bundle.decode_step(params, tokens, cache)


def run(arch: str, *, smoke: bool = True, n_requests: int = 6,
        slots: int = 4, prompt_len: int = 12, max_new: int = 8,
        max_len: int = 64, seed: int = 0) -> dict:
    bundle = get_bundle(arch, smoke=smoke)
    vocab = bundle.cfg.vocab
    params = bundle.init_params(jax.random.PRNGKey(seed))

    extras = {}
    if bundle.kind == "audio":
        extras["frames"] = np.zeros(
            (slots, bundle.cfg.n_audio_ctx, bundle.cfg.d_model), np.float32)
    if bundle.kind == "vlm":
        extras["vision"] = np.zeros(
            (slots, bundle.cfg.vision_tokens, bundle.cfg.d_model), np.float32)

    engine = ServingEngine(_BundleAdapter(bundle, extras), params,
                           ServeConfig(batch=slots, max_len=max_len,
                                       max_new_tokens=max_new))
    rng = np.random.default_rng(seed)
    rids = []
    for _ in range(n_requests):
        prompt = rng.integers(0, vocab, size=prompt_len).astype(np.int32)
        rids.append(engine.submit(prompt))
    t0 = time.time()
    results = engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    print(f"[serve] {n_requests} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    a = ap.parse_args()
    results = run(a.arch, n_requests=a.requests, slots=a.slots,
                  max_new=a.max_new)
    for rid, toks in sorted(results.items()):
        print(f"  req {rid}: {toks}")


if __name__ == "__main__":
    main()
