"""Serving launcher: continuous-batching engine (dense or paged KV) over a
bundle.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 6
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --kv-mode paged --page-size 16

Paged modes need a transformer-family arch (attention KV); SSM/audio
families serve on the dense path.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_bundle
from repro.serving import ServeConfig, ServingEngine


class _BundleAdapter:
    """Adapts an ArchBundle to the ServingEngine interface (binds extras,
    forwards the serving-capability surface)."""

    def __init__(self, bundle, extras=None):
        self.bundle = bundle
        self.extras = extras or {}
        self.cfg = bundle.cfg
        self.kind = bundle.kind
        self.supports_paged_kv = bundle.supports_paged_kv
        self.prefill_supports_true_lengths = \
            bundle.prefill_supports_true_lengths

    def init_cache(self, batch, max_len):
        return self.bundle.init_cache(batch, max_len)

    def prefill(self, params, tokens, cache, true_lengths=None):
        return self.bundle.prefill(params, tokens, cache,
                                   batch_extras=self._sized(tokens.shape[0]),
                                   true_lengths=true_lengths)

    def _sized(self, b):
        return {k: v[:b] for k, v in self.extras.items()} or None

    def decode_step(self, params, tokens, cache):
        return self.bundle.decode_step(params, tokens, cache)

    def cache_batch_axes(self, cache):
        return self.bundle.cache_batch_axes(cache)

    def init_paged_pool(self, num_pages, page_size, kv_dtype=None):
        return self.bundle.init_paged_pool(num_pages, page_size,
                                           kv_dtype=kv_dtype)

    def paged_step(self, params, tokens, pool, page_table, lengths, counts):
        return self.bundle.paged_step(params, tokens, pool, page_table,
                                      lengths, counts)


def build_engine(arch: str, *, smoke: bool = True, slots: int = 4,
                 max_len: int = 64, max_new: int = 8, kv_mode: str = "dense",
                 page_size: int = 16, num_pages: int | None = None,
                 prefill_chunk: int = 32, prefix_cache: bool = True,
                 seed: int = 0, mesh=None,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0, telemetry=None, **degrade):
    """(engine, vocab) ready for submit()/run() — shared by the launcher,
    tests and benchmarks so every caller serves through the same stack.
    ``mesh`` (a concrete Mesh) shards the paged pool per
    ``parallel.sharding.paged_pool_specs``.  ``temperature``/``top_k``/
    ``sample_seed`` select seeded sampled decode (greedy by default).
    Extra keywords flow into :class:`ServeConfig` — the graceful-
    degradation knobs (``max_admission_retries``, ``admission_backoff``,
    ``shed_pressure``, ``shed_patience``, ``shed_min_priority``)."""
    bundle = get_bundle(arch, smoke=smoke)
    params = bundle.init_params(jax.random.PRNGKey(seed))
    extras = {}
    if bundle.kind == "audio":
        extras["frames"] = np.zeros(
            (slots, bundle.cfg.n_audio_ctx, bundle.cfg.d_model), np.float32)
    if bundle.kind == "vlm":
        extras["vision"] = np.zeros(
            (slots, bundle.cfg.vision_tokens, bundle.cfg.d_model), np.float32)
    engine = ServingEngine(
        _BundleAdapter(bundle, extras), params,
        ServeConfig(batch=slots, max_len=max_len, max_new_tokens=max_new,
                    kv_mode=kv_mode, page_size=page_size,
                    num_pages=num_pages, prefill_chunk=prefill_chunk,
                    prefix_cache=prefix_cache,
                    temperature=temperature, top_k=top_k,
                    sample_seed=sample_seed, **degrade),
        mesh=mesh, telemetry=telemetry)
    return engine, bundle.cfg.vocab


def run(arch: str, *, smoke: bool = True, n_requests: int = 6,
        slots: int = 4, prompt_len: int = 12, max_new: int = 8,
        max_len: int = 64, seed: int = 0, kv_mode: str = "dense",
        page_size: int = 16, num_pages: int | None = None,
        prefix_cache: bool = True, prefix_share: float = 0.0,
        temperature: float = 0.0, top_k: int = 0,
        stream: bool = False, trace_out: str | None = None,
        metrics_out: str | None = None) -> dict:
    """Serve ``n_requests`` random prompts and return {rid: tokens}.

    ``prefix_share`` > 0 gives that fraction of the requests a common
    prompt prefix (half the prompt length) — the radix cache prefills it
    once and maps it read-only for every later arrival, which the printed
    ``prefix_hits``/``pages_shared`` counters make visible.  ``stream``
    consumes request 0 through the per-token generator API instead of the
    batch ``run()`` (the other requests still complete — streams drive
    the same continuous-batching ticks)."""
    tel = None
    if trace_out or metrics_out:
        import repro.obs as obs
        tel = obs.enable(process_name=f"serve:{kv_mode}")
    engine, vocab = build_engine(
        arch, smoke=smoke, slots=slots, max_len=max_len, max_new=max_new,
        kv_mode=kv_mode, page_size=page_size, num_pages=num_pages,
        prefix_cache=prefix_cache, seed=seed, temperature=temperature,
        top_k=top_k, sample_seed=seed, telemetry=tel)
    rng = np.random.default_rng(seed)
    common = rng.integers(0, vocab, size=max(1, prompt_len // 2))
    for i in range(n_requests):
        prompt = rng.integers(0, vocab, size=prompt_len).astype(np.int32)
        if prefix_share > 0 and i % max(1, round(1 / prefix_share)) == 0:
            prompt[:len(common)] = common
        engine.submit(prompt)
    t0 = time.time()
    if stream:
        first = [tok for tok in engine.stream(0)]
        print(f"[serve:{kv_mode}] streamed req 0: {first}")
    results = engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    stats = engine.kv_stats()
    line = (f"[serve:{kv_mode}] {n_requests} requests, {total_tokens} "
            f"tokens in {dt:.2f}s ({total_tokens/dt:.1f} tok/s, "
            f"kv_resident={stats['bytes_resident']/1e6:.2f}MB)")
    pstats = engine.prefix_stats() if kv_mode != "dense" else {}
    if pstats:
        line += (f" prefix_hits={pstats['hits']}/{pstats['lookups']} "
                 f"matched_tokens={pstats['matched_tokens']} "
                 f"cow={pstats['cow_copies']}")
    print(line)
    if tel is not None:
        snap = engine.telemetry()   # pull kv/prefix/traffic into registry
        if trace_out:
            print(f"[serve:{kv_mode}] trace -> "
                  f"{tel.write_trace(trace_out)}")
        if metrics_out:
            print(f"[serve:{kv_mode}] metrics -> "
                  f"{tel.write_metrics(metrics_out, extra={'serve': snap})}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--kv-mode", default="dense",
                    choices=("dense", "paged", "paged_int8"))
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None)
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="radix prefix sharing across requests (default on)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--prefix-share", type=float, default=0.0,
                    help="fraction of requests given a common prompt prefix")
    ap.add_argument("--stream", action="store_true",
                    help="consume request 0 via the token-streaming API")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples from softmax(logits/T)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k highest logits")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace JSON (perfetto-loadable) of "
                         "the serve: admission/prefix-match/prefill/decode "
                         "spans, request instants")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics snapshot (+ engine.telemetry()) "
                         "as JSON")
    a = ap.parse_args()
    results = run(a.arch, n_requests=a.requests, slots=a.slots,
                  max_new=a.max_new, kv_mode=a.kv_mode,
                  page_size=a.page_size, num_pages=a.num_pages,
                  prefix_cache=a.prefix_cache, prefix_share=a.prefix_share,
                  stream=a.stream,
                  temperature=a.temperature, top_k=a.top_k,
                  trace_out=a.trace_out, metrics_out=a.metrics_out)
    for rid, toks in sorted(results.items()):
        print(f"  req {rid}: {toks}")


if __name__ == "__main__":
    main()
