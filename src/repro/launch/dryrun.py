import os
# merge, don't clobber: callers that already forced a device count
# (benchmark workers, tests) keep theirs, callers with unrelated XLA_FLAGS
# still get the 512-device forcing — jax only reads this at init.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=512").strip()
del _flags

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: jax.jit(step, in_shardings).lower(*ShapeDtypeStructs)
.compile(), then record memory_analysis (bytes/device — proves it fits),
cost_analysis (FLOPs/bytes for §Roofline) and the collective-bytes parse of
the optimized HLO. Results stream into results/dryrun/<cell>.json so an
interrupted sweep resumes where it stopped.

Usage:
    python -m repro.launch.dryrun [--arch ID] [--shape NAME]
        [--mesh single|multi|both] [--force]
"""
import argparse
import json
import time
import traceback

import jax

from repro.analysis.hlo_cost import module_cost
from repro.analysis.roofline import (RooflineReport, collective_bytes,
                                     model_flops_decode, model_flops_train)
from repro.configs import ARCH_IDS, SHAPES, get_bundle
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import step_in_shardings
from repro.runtime import compat

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _cell_path(arch, shape, mesh_name, ring=None):
    safe = arch.replace(".", "_")
    # ring-pinned cells cache separately (and out of bench_dryrun's
    # `*__<mesh>.json` glob) so mode comparisons never read stale cells
    # traced under a different attention mode.
    suffix = f"__ring-{ring}" if ring else ""
    return os.path.join(RESULTS_DIR,
                        f"{safe}__{shape}__{mesh_name}{suffix}.json")


def run_cell(arch: str, shape: str, mesh_name: str, *, force: bool = False,
             ring: str | None = None) -> dict:
    """Lower + compile one cell.  ``ring`` pins the context-parallel
    attention mode for this cell ('ring' | 'replicated' | 'off' | 'auto')
    via the REPRO_RING_ATTN policy env read at trace time; None keeps the
    ambient policy."""
    path = _cell_path(arch, shape, mesh_name, ring)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    from repro.obs import get_telemetry
    tel = get_telemetry()
    bundle = get_bundle(arch)
    t0 = time.time()
    result = {"arch": arch, "shape": shape, "mesh": mesh_name}
    if ring:
        result["ring"] = ring
    ok, why = bundle.supports(shape)
    if not ok:
        result.update(status="skipped", reason=why)
    else:
        prev_ring = os.environ.get("REPRO_RING_ATTN")
        try:
            if ring:
                os.environ["REPRO_RING_ATTN"] = ring
            mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
            chips = mesh.devices.size
            args, shardings, step, donate = step_in_shardings(
                bundle, shape, mesh)
            with compat.set_mesh(mesh), \
                    tel.span("compile", cat="dryrun", arch=arch,
                             shape=shape, mesh=mesh_name):
                lowered = jax.jit(step, in_shardings=shardings,
                                  donate_argnums=donate).lower(*args)
                compiled = lowered.compile()
            mem = compat.memory_stats(compiled)
            xla_cost = compat.cost_analysis(compiled)
            # scan-aware per-device costs (XLA's cost_analysis counts while
            # bodies once — see analysis/hlo_cost.py); x chips = global.
            hlo_txt = compiled.as_text()
            pc = module_cost(hlo_txt)
            chips_ = mesh.devices.size
            cost = {"flops": pc.flops * chips_,
                    "bytes accessed": pc.bytes * chips_}
            coll = {k: v * chips_ for k, v in pc.collectives.items()}
            sh = SHAPES[shape]
            tokens = sh["seq_len"] * sh["global_batch"] if \
                sh["kind"] == "train" else sh["global_batch"]
            if sh["kind"] == "train":
                mflops = model_flops_train(bundle.active_param_count(),
                                           tokens)
            else:
                mflops = model_flops_decode(bundle.active_param_count(),
                                            tokens)
                if sh["kind"] == "prefill":
                    mflops = model_flops_train(
                        bundle.active_param_count(),
                        sh["seq_len"] * sh["global_batch"]) / 3.0  # fwd only
            result.update(
                status="ok",
                chips=chips,
                compile_s=round(time.time() - t0, 1),
                flops=cost.get("flops", 0.0),
                hlo_bytes=cost.get("bytes accessed", 0.0),
                collective_bytes=sum(coll.values()),
                collectives=coll,
                xla_flops_unscaled=xla_cost.get("flops", 0.0),
                model_flops=mflops,
                model_bytes=bundle.min_hbm_bytes(shape),
                memory_analysis={
                    "argument_size_gb": mem["argument_bytes"] / 1e9,
                    "output_size_gb": mem["output_bytes"] / 1e9,
                    "temp_size_gb": mem["temp_bytes"] / 1e9,
                    # peak_bytes = args + temps: donated outputs
                    # (params/opt/cache) alias their inputs on TPU (the
                    # CPU backend ignores donation, hence not args+temp+out)
                    "peak_gb_per_device": mem["peak_bytes"] / 1e9,
                },
            )
            tel.metrics.absorb(
                {"flops": result["flops"], "hlo_bytes": result["hlo_bytes"],
                 "collective_bytes": result["collective_bytes"],
                 "peak_bytes": mem["peak_bytes"]},
                prefix="dryrun.", arch=arch, shape=shape, mesh=mesh_name)
            print(f"[dryrun] {arch} x {shape} x {mesh_name}: OK "
                  f"({result['compile_s']}s, "
                  f"{result['memory_analysis']['peak_gb_per_device']:.2f} "
                  f"GB/dev)")
        except Exception as e:  # noqa: BLE001 — recorded, sweep continues
            result.update(status="error", error=f"{type(e).__name__}: {e}",
                          traceback=traceback.format_exc()[-2000:])
            print(f"[dryrun] {arch} x {shape} x {mesh_name}: "
                  f"FAIL {type(e).__name__}: {e}")
        finally:
            if ring:
                if prev_ring is None:
                    os.environ.pop("REPRO_RING_ATTN", None)
                else:
                    os.environ["REPRO_RING_ATTN"] = prev_ring

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def roofline_from_cell(cell: dict) -> RooflineReport | None:
    if cell.get("status") != "ok":
        return None
    return RooflineReport(
        arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"],
        chips=cell["chips"], hlo_flops=cell["flops"],
        hlo_bytes=cell["hlo_bytes"], coll_bytes=cell["collective_bytes"],
        coll_breakdown=cell["collectives"], model_flops=cell["model_flops"],
        bytes_per_device=cell["memory_analysis"]["peak_gb_per_device"] * 1e9,
        model_bytes=cell.get("model_bytes", 0.0))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--ring", default=None,
                    choices=["auto", "ring", "replicated", "off"],
                    help="pin the context-parallel attention mode for "
                         "every cell (default: ambient REPRO_RING_ATTN "
                         "policy)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace JSON of per-cell compile "
                         "spans + kernel dispatch instants")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics snapshot (per-cell flops/bytes "
                         "gauges) as JSON")
    args = ap.parse_args()

    tel = None
    if args.trace_out or args.metrics_out:
        import repro.obs as obs
        tel = obs.enable(process_name="dryrun")

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                r = run_cell(arch, shape, mesh_name, force=args.force,
                             ring=args.ring)
                s = r["status"]
                n_ok += s == "ok"
                n_skip += s == "skipped"
                n_err += s == "error"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if tel is not None:
        if args.trace_out:
            print(f"[dryrun] trace -> {tel.write_trace(args.trace_out)}")
        if args.metrics_out:
            print(f"[dryrun] metrics -> "
                  f"{tel.write_metrics(args.metrics_out)}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
