"""Fleet launcher: spawn and supervise N real training worker processes.

    # 4 workers, chaos-kill the step-50 heartbeat of rank 1, self-heal
    PYTHONPATH=src python -m repro.launch.supervisor --nprocs 4 \
        --arch qwen3-4b --steps 100 --ckpt-dir /tmp/fleet-ckpt \
        --chaos kill@50

Each worker is ``repro.launch.train --process-id R --num-processes W``
running the SAME global horizon (``--total-steps``), so every rank holds
bit-identical params (proven by the per-rank ``params_crc`` result
files).  The supervisor restarts chaos-killed/crashed workers with
backoff, evicts repeat offenders and re-meshes the gang over survivors,
and gives up cleanly — newest committed checkpoint reported — when the
global failure budget is blown.  See ``repro.runtime.supervisor`` for
the policy machine and ``docs/ARCHITECTURE.md`` ("Fleet runtime") for
the state diagram.

This process never imports jax on its supervision path (workers do); the
optional final checkpoint audit is the one lazy exception.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

from repro.runtime.chaos import split_spec_strings
from repro.runtime.fleet import allocate_ports
from repro.runtime.supervisor import (LaunchSpec, RestartPolicy, Supervisor,
                                      write_report)


def make_cmd_builder(a, fleet_dir: str, worker_chaos: list[str],
                     coordinator: str | None):
    """argv factory handed to the Supervisor: maps a LaunchSpec to a
    ``repro.launch.train`` worker invocation."""

    def build(spec: LaunchSpec) -> list[str]:
        argv = [sys.executable, "-m", "repro.launch.train",
                "--arch", a.arch,
                "--steps", str(a.steps),
                "--total-steps", str(a.steps),
                "--seq-len", str(a.seq_len),
                "--global-batch", str(a.global_batch),
                "--ckpt-every", str(a.ckpt_every),
                "--process-id", str(spec.rank),
                "--num-processes", str(spec.world),
                "--fleet-dir", fleet_dir,
                "--fleet-tag", str(spec.tag),
                "--result-out",
                os.path.join(fleet_dir, f"result_rank{spec.tag}.json"),
                "--metrics-out",
                os.path.join(fleet_dir, f"metrics_rank{spec.tag}.json")]
        if a.ckpt_dir:
            argv += ["--ckpt-dir", a.ckpt_dir]
        if not a.smoke:
            argv += ["--full"]
        if spec.with_chaos and worker_chaos:
            for c in worker_chaos:
                argv += ["--chaos", c]
            argv += ["--chaos-seed", str(a.chaos_seed)]
        if spec.striped and spec.stripe_ports:
            argv += ["--striped-restore", "--stripe-ports",
                     ",".join(str(p) for p in spec.stripe_ports)]
        if a.distributed == "jax" and coordinator:
            argv += ["--distributed", "jax", "--coordinator", coordinator]
        return argv

    return build


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="process supervisor for a real multi-worker fleet")
    ap.add_argument("--nprocs", type=int, default=2)
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20,
                    help="global step horizon for every worker")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--chaos", action="append", default=[], metavar="SPEC",
                    help="worker faults (kill@N, nan@N, diskfull@N, "
                         "partition@N:host=H, ...) plus the supervisor-"
                         "side sigkill@N:host=H; restarted workers get "
                         "no chaos")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--distributed", default="none",
                    choices=["none", "jax"],
                    help="'jax' additionally brings up jax.distributed "
                         "in the workers (supervision never depends on "
                         "it; rejoin-after-restart may downgrade)")
    ap.add_argument("--striped-restore", default="auto",
                    choices=["auto", "always", "never"],
                    help="gang restores stripe shard reads across ranks "
                         "(auto: when a checkpoint exists and world > 1)")
    ap.add_argument("--fleet-dir", default=None,
                    help="heartbeats/logs/results dir (default: tmp)")
    ap.add_argument("--report-out", default=None, metavar="PATH")
    # restart policy
    ap.add_argument("--max-restarts-per-rank", type=int, default=2)
    ap.add_argument("--max-total-failures", type=int, default=6)
    ap.add_argument("--backoff-base-s", type=float, default=0.25)
    ap.add_argument("--backoff-max-s", type=float, default=8.0)
    ap.add_argument("--hang-timeout-s", type=float, default=30.0)
    a = ap.parse_args(argv)

    fleet_dir = a.fleet_dir or tempfile.mkdtemp(prefix="repro-fleet-")
    os.makedirs(fleet_dir, exist_ok=True)
    _, worker_chaos = split_spec_strings(a.chaos)
    coordinator = None
    if a.distributed == "jax":
        coordinator = f"127.0.0.1:{allocate_ports(1)[0]}"
    policy = RestartPolicy(max_restarts_per_rank=a.max_restarts_per_rank,
                           max_total_failures=a.max_total_failures,
                           backoff_base_s=a.backoff_base_s,
                           backoff_max_s=a.backoff_max_s,
                           hang_timeout_s=a.hang_timeout_s)
    sup = Supervisor(a.nprocs,
                     make_cmd_builder(a, fleet_dir, worker_chaos,
                                      coordinator),
                     fleet_dir=fleet_dir, policy=policy,
                     chaos_specs=a.chaos, chaos_seed=a.chaos_seed,
                     ckpt_dir=a.ckpt_dir,
                     striped_restore=a.striped_restore)
    report = sup.run()
    report["fleet_dir"] = fleet_dir
    if a.report_out:
        write_report(a.report_out, report)
    print(json.dumps({k: report[k] for k in
                      ("outcome", "total_failures", "wall_s",
                       "final_checkpoint_step")}, indent=2))
    return 0 if report["outcome"] in ("completed", "degraded") else 1


if __name__ == "__main__":
    raise SystemExit(main())
