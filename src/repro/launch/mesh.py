"""Production meshes. Functions, not module constants — importing this file
never touches jax device state."""
from __future__ import annotations

from repro.runtime import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU tests/benches)."""
    return compat.make_mesh((1, 1), ("data", "model"))
