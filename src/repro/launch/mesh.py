"""Production meshes. Functions, not module constants — importing this file
never touches jax device state."""
from __future__ import annotations

from repro.runtime import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU tests/benches)."""
    return compat.make_mesh((1, 1), ("data", "model"))


def make_worker_mesh():
    """1-device mesh over THIS process's first local device.

    A fleet worker must not use ``make_local_mesh``: once
    ``jax.distributed.initialize`` has run, ``jax.devices()`` is global
    and a (1, 1) device mesh would place every rank's compute on process
    0's device.  Built from ``jax.local_devices()`` the mesh stays on the
    rank's own device whether or not the coordinator is up."""
    import jax
    import numpy as np
    dev = np.asarray(jax.local_devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(dev, ("data", "model"))
