"""Architecture models for the paper's evaluation (§III-B).

Three architectures, parameterized exactly as the paper's simulation setup:
200 MHz, 6.4 GB/s DRAM (2x DDR4-1600 x16), 25.6 GB/s global buffer; per-PE
local/global buffer allocations of (0 / 0.3 / 0.6) KB and
(1.0*N_PE / 0.5*N_PE / 2) KB for TPU / Eyeriss / VectorMesh, matching the
PE-to-memory ratio of the source publications. Area factors from Table II.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_pe: int
    freq_hz: float = 200e6
    dram_bw: float = 6.4e9        # bytes/s
    glb_bw: float = 25.6e9       # bytes/s
    bytes_per_elem: int = 2      # 16-bit words
    psum_bytes: int = 4

    # local (per execution unit) organization
    pes_per_unit: int = 1
    unit_input_buffer: int = 0   # bytes per unit available for input tiles
    unit_psum_buffer: int = 0    # bytes per unit available for PSums
    mesh: tuple[int, int] = (1, 1)  # arrangement of units

    # data movement style between GLB and units
    #   fifo      — VectorMesh: share along both mesh axes, no duplication
    #   multicast — Eyeriss: share along one axis (horizontal multicast),
    #               duplicated in local buffers (capacity already tiny)
    #   systolic  — TPU: no local tiling buffers; weight-stationary array
    sharing: str = "fifo"

    glb_bytes: int = 0           # global buffer capacity
    area_factor: float = 1.0

    # systolic array shape (TPU only): (rows=reduction, cols=output-channels)
    array: tuple[int, int] = (0, 0)

    @property
    def peak_macs_per_s(self) -> float:
        return self.n_pe * self.freq_hz

    @property
    def n_units(self) -> int:
        return self.n_pe // self.pes_per_unit


def tpu(n_pe: int) -> ArchConfig:
    # 128 PE -> 8x16 array; 512 PE -> 16x32 (paper §III-B).
    array = (8, 16) if n_pe == 128 else (16, 32)
    assert array[0] * array[1] == n_pe
    return ArchConfig(
        name=f"tpu-{n_pe}",
        n_pe=n_pe,
        pes_per_unit=n_pe,
        unit_input_buffer=0,
        unit_psum_buffer=0,
        mesh=(1, 1),
        sharing="systolic",
        glb_bytes=int(1.0 * 1024) * n_pe,
        area_factor=0.46,
        array=array,
    )


def eyeriss(n_pe: int) -> ArchConfig:
    mesh = (8, 16) if n_pe == 128 else (16, 32)
    return ArchConfig(
        name=f"eyeriss-{n_pe}",
        n_pe=n_pe,
        pes_per_unit=1,
        # 0.3 KB local per PE, split input/psum (row-stationary keeps a filter
        # row + input sliver + a psum row).
        unit_input_buffer=int(0.2 * 1024),
        unit_psum_buffer=int(0.1 * 1024),
        mesh=mesh,
        sharing="multicast",
        glb_bytes=int(0.5 * 1024) * n_pe,
        area_factor=1.00,
    )


def vectormesh(n_pe: int) -> ArchConfig:
    # 128 PE -> 2x2 TEUs of 32 PEs; 512 -> 4x4 (paper §III-B).
    mesh = (2, 2) if n_pe == 128 else (4, 4)
    assert mesh[0] * mesh[1] * 32 == n_pe
    return ArchConfig(
        name=f"vectormesh-{n_pe}",
        n_pe=n_pe,
        pes_per_unit=32,
        unit_input_buffer=2 * 16 * 1024,   # two 16 KB input buffers
        unit_psum_buffer=5 * 1024,         # 5 KB PSum buffer
        mesh=mesh,
        sharing="fifo",
        glb_bytes=2 * 1024,                # does not grow with N_PE (§III-B)
        area_factor=1.04,
    )


ARCHS = {
    "tpu": tpu,
    "eyeriss": eyeriss,
    "vectormesh": vectormesh,
}
