"""Cycle-approximate dataflow simulator (paper §III-B/C).

Counts GLB and DRAM traffic and models execution time for the three
architectures on any ``TensorOp`` workload, reusing the SAME tiling/exchange
machinery from ``repro.core`` that drives the Pallas kernels — the paper's
Table III and Figs. 3-4 fall out of this model rather than being hard-coded.

Conventions (matching the paper's Table III semantics):
  * GLB bytes  = input words units read from the global buffer (+ PSum spills
    through the GLB, where the dataflow forces them);
  * DRAM bytes = unique input fetches from DRAM (with a GLB-capacity refetch
    factor when the working set exceeds the GLB) + one write per output.
  * normalized access = bytes per 1,000 MACs (Table III).
  * time = max(compute, GLB-bandwidth, DRAM-bandwidth) — bandwidth/compute
    overlap, so the binding resource sets the time (roofline-consistent).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.ndrange import TensorOp
from repro.core.tiling import BufferSpec, search_tiles
from repro.core.exchange import plan_mesh_exchange, order_grid_for_sharing, \
    grid_fetch_bytes
from .archs import ArchConfig
from .workloads import Workload


@dataclasses.dataclass(frozen=True)
class SimResult:
    workload: str
    arch: str
    macs: int
    glb_bytes: int
    dram_bytes: int
    time_s: float
    gmacs: float                 # achieved GMAC/s (paper's "performance P")
    roofline_gmacs: float        # paper's black line
    norm_glb: float              # bytes / 1000 MACs (Table III)
    norm_dram: float

    @property
    def roofline_frac(self) -> float:
        return self.gmacs / max(1e-12, self.roofline_gmacs)


def _unique_bytes(op: TensorOp) -> int:
    full = op.full_tile()
    b = sum(v.footprint_bytes(full) for v in op.inputs)
    return b + op.output.footprint_bytes(full)


def roofline_gmacs(arch: ArchConfig, op: TensorOp) -> float:
    """min(PE rate, DRAM bw / unique-data intensity) — paper's roofline."""
    peak = arch.peak_macs_per_s
    intensity = op.total_macs() / _unique_bytes(op)  # MACs per DRAM byte
    return min(peak, arch.dram_bw * intensity) / 1e9


def _glb_level_dram(op: TensorOp, arch: ArchConfig, glb_inflow: int) -> int:
    """DRAM input bytes given GLB capacity (refetch when working set spills).

    If the GLB can hold a tile footprint, each GLB-tile is fetched once per
    sweep dictated by the best grid order; if the GLB is a pass-through
    (VectorMesh's 2 KB), DRAM inflow equals GLB inflow.

    Both the GLB-level tile search and the grid-order search go through the
    memoized engine (``repro.core.autotune``), so every repeated
    (glb_bytes, op) query across archs, PE counts and benchmark files after
    the first is a cache hit rather than a fresh lattice scan.
    """
    unique_in = sum(v.footprint_bytes(op.full_tile()) for v in op.inputs)
    if unique_in <= arch.glb_bytes:
        return unique_in  # everything cached after first fetch
    try:
        glb_tile = search_tiles(
            op, BufferSpec(input_bytes=max(1, int(arch.glb_bytes * 0.75)),
                           psum_bytes=max(1, int(arch.glb_bytes * 0.25))))
    except ValueError:
        return glb_inflow  # pass-through GLB: no reuse capture
    order = order_grid_for_sharing(op, glb_tile.tile)
    dram_in = grid_fetch_bytes(op, glb_tile.tile, order.order)
    # The GLB can never cause MORE traffic than the stream it serves, nor less
    # than one fetch of the unique data.
    return max(min(dram_in, glb_inflow), unique_in)


# ---------------------------------------------------------------------------
# Tiled architectures: VectorMesh (fifo) and Eyeriss (multicast).
# ---------------------------------------------------------------------------

def _simulate_tiled(arch: ArchConfig, op: TensorOp) -> tuple[int, int, float]:
    # The unit-level search here and the GLB-level search inside
    # _glb_level_dram are the simulator's two hot lattice scans per
    # (arch, workload); both resolve through the memoized autotune engine,
    # so sweeping PE counts or re-running a benchmark pays for each distinct
    # (BufferSpec, op) pair exactly once.
    buf = BufferSpec(input_bytes=arch.unit_input_buffer,
                     psum_bytes=arch.unit_psum_buffer,
                     lanes=arch.pes_per_unit)
    sched = search_tiles(op, buf)
    # Eyeriss' horizontal multicast shares input rows along one full array
    # axis; its second-axis reuse comes from inter-PE PSum accumulation, whose
    # span is physically the filter height (kh PEs chain one column of partial
    # sums) — and the shared data is still DUPLICATED into each PE's local
    # buffer, so the effective tile stays tiny (0.3 KB). VectorMesh shares
    # along both mesh axes without duplication (full 37 KB TEU tile).
    if arch.sharing == "fifo":
        col_cap = None
    else:
        kh = next((d.size for d in op.temporal_dims if d.name == "m"), 1)
        col_cap = max(1, kh)
    plan = plan_mesh_exchange(
        op, sched.tile, arch.mesh,
        share_rows=True,
        share_cols=True,
        col_span_cap=col_cap,
    )
    out_bytes = op.output.footprint_bytes(op.full_tile())
    glb_bytes = plan.fetch_bytes                       # inputs read from GLB
    dram_in = _glb_level_dram(op, arch, plan.fetch_bytes)
    dram_bytes = dram_in + out_bytes

    # compute time: waves of units; lane under-utilization when the tile's
    # parallel extent is below the unit's vector width; row-stationary mapping
    # inefficiency when the filter height does not pack the array rows.
    par_pts = math.prod(sched.tile[d.name] for d in op.parallel_dims)
    lane_util = min(1.0, par_pts / arch.pes_per_unit)
    n_units = arch.mesh[0] * arch.mesh[1]
    tiles = sched.num_tiles
    occupancy = tiles / (math.ceil(tiles / n_units) * n_units)
    map_util = 1.0
    if arch.sharing == "multicast":
        kh = next((d.size for d in op.temporal_dims if d.name == "m"), 1)
        rows = arch.mesh[0]
        if kh <= rows:
            map_util = (rows // kh) * kh / rows
        else:
            map_util = kh / (math.ceil(kh / rows) * rows)
    eff = max(1e-3, lane_util * occupancy * map_util * PE_EFFICIENCY)
    compute_t = op.total_macs() / (arch.peak_macs_per_s * eff)
    return glb_bytes, dram_bytes, compute_t


# ---------------------------------------------------------------------------
# TPU: weight-stationary systolic array, no local tiling buffers.
# ---------------------------------------------------------------------------

def _split_systolic(op: TensorOp):
    """Map an op onto (stationary, moving) operands and (K_red, Co, T) sizes.

    The stationary operand is the one with the smaller footprint (weights for
    conv/GEMM). Its parallel dims feed the array columns; the reduction feeds
    the rows; remaining parallel dims are streamed output points T.
    """
    full = op.full_tile()
    ins = sorted(op.inputs, key=lambda v: v.footprint_bytes(full))
    stationary, moving = ins[0], ins[-1]
    k_red = math.prod(d.size for d in op.temporal_dims) or 1
    stat_par = [d for d in op.parallel_dims
                if any(e.depends_on(d.name) for e in stationary.index_exprs)]
    co = math.prod(d.size for d in stat_par) or 1
    t = math.prod(d.size for d in op.parallel_dims) // co or 1
    return stationary, moving, k_red, co, t


def _simulate_systolic(arch: ArchConfig, op: TensorOp) -> tuple[int, int, float]:
    R, C = arch.array
    stationary, moving, k_red, co, t = _split_systolic(op)
    bpe = arch.bytes_per_elem
    k_passes = math.ceil(k_red / R)
    c_passes = math.ceil(co / C)
    full = op.full_tile()

    w_bytes = stationary.footprint_bytes(full)            # loaded once/tile
    mov_unique = moving.footprint_bytes(full)
    mov_stream = t * k_red * bpe                          # one c-pass stream
    mov_bytes = mov_stream * c_passes                     # restreamed per c-pass
    # PSums leave the array every pass; accumulation across k-passes spills
    # through the GLB accumulators (read+write per revisit).
    psum_spill = 2 * t * co * arch.psum_bytes * max(0, k_passes - 1)
    out_bytes = op.output.footprint_bytes(full)
    glb_bytes = w_bytes + mov_bytes + psum_spill

    # DRAM: weight tiles stream from DRAM once (each used for its whole pass);
    # if the moving operand's working window fits the GLB it is fetched once,
    # otherwise the on-the-fly expansion (im2col for conv) must re-stream the
    # overlapping window from DRAM — the full t*k_red stream, per column-pass.
    if mov_unique <= arch.glb_bytes:
        dram_mov = mov_unique
    else:
        dram_mov = mov_stream * c_passes
    dram_bytes = w_bytes + dram_mov + out_bytes

    # time: each pass streams T points + pipeline fill/drain (R + C cycles);
    # array utilization suffers when K_red < R or Co < C (paper §III: bubbles
    # when running smaller tiles in larger TPUs).
    cycles = k_passes * c_passes * (t + R + C)
    compute_t = cycles / arch.freq_hz / PE_EFFICIENCY
    return glb_bytes, dram_bytes, compute_t


# ---------------------------------------------------------------------------

# The paper evaluates DRAM with ramulator (real DDR4 timing); sustained DDR4
# efficiency under mixed-stride streams is ~65-75% of nominal. We use 0.7 and
# model imperfect compute/IO overlap with the standard "max + epsilon*min"
# serialization term (double-buffering hides most but not all transfers).
DRAM_EFFICIENCY = 0.70
SERIALIZATION = 0.15
# Pipeline stalls, ragged edge tiles, and control overhead in the cycle-level
# design — calibrated so VectorMesh's absolute GMAC/s matches the paper's
# Table III (20 / 68 GOPS at 128 / 512 PEs).
PE_EFFICIENCY = 0.80


def simulate(arch: ArchConfig, wl: Workload) -> SimResult:
    op = wl.op
    if arch.sharing == "systolic":
        glb, dram, compute_t = _simulate_systolic(arch, op)
    else:
        glb, dram, compute_t = _simulate_tiled(arch, op)
    glb_t = glb / arch.glb_bw
    dram_t = dram / (arch.dram_bw * DRAM_EFFICIENCY)
    time_s = max(compute_t, glb_t, dram_t) + SERIALIZATION * min(
        compute_t, max(glb_t, dram_t))
    macs = op.total_macs()
    return SimResult(
        workload=wl.name,
        arch=arch.name,
        macs=macs,
        glb_bytes=glb,
        dram_bytes=dram,
        time_s=time_s,
        gmacs=macs / time_s / 1e9,
        roofline_gmacs=roofline_gmacs(arch, op),
        norm_glb=glb * 1000 / macs,
        norm_dram=dram * 1000 / macs,
    )


def summarize(results: list[SimResult]) -> dict[str, float]:
    """Aggregate Table III row for one architecture (sum-bytes / sum-MACs)."""
    macs = sum(r.macs for r in results)
    time = sum(r.time_s for r in results)
    return {
        "norm_glb": sum(r.glb_bytes for r in results) * 1000 / macs,
        "norm_dram": sum(r.dram_bytes for r in results) * 1000 / macs,
        "gmacs": macs / time / 1e9,
        "roofline_frac": (
            sum(r.roofline_frac for r in results) / len(results)),
    }
