"""Faithful reproduction of the paper's evaluation: cycle-approximate models
of VectorMesh / TPU / Eyeriss on the paper's workloads (Table I + modern +
spatial matching), producing Table III traffic numbers and Fig. 3/4
rooflines from the same core scheduling machinery the TPU kernels use."""
from . import archs, simulator, workloads
from .archs import ArchConfig, eyeriss, tpu, vectormesh
from .simulator import SimResult, roofline_gmacs, simulate, summarize
from .workloads import ALL, CLASSIC, GEMM, MODERN, SPATIAL, Workload, by_name

__all__ = [
    "archs", "simulator", "workloads",
    "ArchConfig", "eyeriss", "tpu", "vectormesh",
    "SimResult", "roofline_gmacs", "simulate", "summarize",
    "ALL", "CLASSIC", "GEMM", "MODERN", "SPATIAL", "Workload", "by_name",
]
