"""Benchmark workloads from the paper (§III-A, Table I + modern + spatial).

Spatial input resolutions are not given in the paper; we use the standard
resolutions of the source networks (AlexNet 227, TinyYOLO 416 with 2x pooling
between convs, Inception-v4 17x17 grid, SRCNN 33x33 patches, DeepLab output
stride 16 on 513, ESPCN on 1080p/3, MobileNet 224) and record them here so the
benchmark is reproducible.
"""
from __future__ import annotations

import dataclasses

from repro.core.ndrange import (
    TensorOp, conv2d_op, correlation_op, depthwise_conv2d_op, matmul_op)


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    op: TensorOp
    family: str  # classic | modern | spatial | gemm


def _conv(name, Ci, Co, k_h, k_w, oh, ow, stride=1, dilation=1) -> Workload:
    return Workload(
        name,
        conv2d_op(Co, Ci, oh, ow, k_h, k_w, stride=stride, dilation=dilation,
                  name=name),
        "classic",
    )


# --- Table I: classic CNN workloads -------------------------------------
CLASSIC: tuple[Workload, ...] = (
    _conv("AL_CONV1", 3, 48, 11, 11, 55, 55, stride=4),
    _conv("AL_CONV2", 48, 128, 5, 5, 27, 27),
    _conv("AL_CONV3", 128, 192, 3, 3, 13, 13),
    _conv("AL_CONV4", 192, 192, 3, 3, 13, 13),
    _conv("AL_CONV5", 192, 128, 3, 3, 13, 13),
    _conv("TY_CONV1", 3, 16, 3, 3, 416, 416),
    _conv("TY_CONV2", 16, 32, 3, 3, 208, 208),
    _conv("TY_CONV3", 32, 64, 3, 3, 104, 104),
    _conv("TY_CONV4", 64, 128, 3, 3, 52, 52),
    _conv("TY_CONV5", 128, 256, 3, 3, 26, 26),
    _conv("TY_CONV6", 256, 512, 3, 3, 13, 13),
    _conv("TY_CONV8", 1024, 125, 1, 1, 13, 13),
    _conv("IN_1x7", 64, 64, 1, 7, 17, 17),
    _conv("IN_7x1", 64, 64, 7, 1, 17, 17),
    _conv("SR_CONV1", 3, 64, 9, 9, 33, 33),
)

# --- Modern CNN workloads (§III-A: DeepLab, ESPCN, MobileNet) ------------
MODERN: tuple[Workload, ...] = (
    Workload("DL_ATROUS2",
             conv2d_op(256, 256, 65, 65, 3, 3, dilation=2, name="DL_ATROUS2"),
             "modern"),
    Workload("DL_ATROUS4",
             conv2d_op(256, 256, 65, 65, 3, 3, dilation=4, name="DL_ATROUS4"),
             "modern"),
    Workload("ESPCN_CONV2",
             conv2d_op(32, 64, 360, 640, 3, 3, name="ESPCN_CONV2"), "modern"),
    Workload("ESPCN_SUBPIX",
             conv2d_op(27, 32, 360, 640, 3, 3, name="ESPCN_SUBPIX"), "modern"),
    Workload("MBN_DW_S1",
             depthwise_conv2d_op(128, 56, 56, 3, 3, name="MBN_DW_S1"), "modern"),
    Workload("MBN_PW",
             conv2d_op(128, 128, 56, 56, 1, 1, name="MBN_PW"), "modern"),
)

# --- Spatial matching workloads (FlowNet correlation, EVA2 matching) -----
SPATIAL: tuple[Workload, ...] = (
    # FlowNetC correlation: 1/8-res features 48x64, 256 ch, 21x21 search.
    Workload("FLOWNET_CORR",
             correlation_op(21, 21, 64, 48, 256, name="FLOWNET_CORR"),
             "spatial"),
    # EVA2-style block matching: 17x17 search over 26x26 blocks, 64 ch.
    Workload("EVA2_MATCH",
             correlation_op(17, 17, 26, 26, 64, name="EVA2_MATCH"), "spatial"),
)

# --- GEMM (paper Fig. 3 also includes MM/GEMM workloads) ------------------
GEMM: tuple[Workload, ...] = (
    Workload("GEMM_1K", matmul_op(1024, 1024, 1024, name="GEMM_1K"), "gemm"),
    Workload("GEMM_FC", matmul_op(1, 4096, 9216, name="GEMM_FC"), "gemm"),
)

ALL: tuple[Workload, ...] = CLASSIC + MODERN + SPATIAL + GEMM


def by_name(name: str) -> Workload:
    for w in ALL:
        if w.name == name:
            return w
    raise KeyError(name)
