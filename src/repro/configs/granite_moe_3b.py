"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) vocab=49408,
MoE 40 experts top-8, expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
import jax.numpy as jnp

from repro.models import MoEConfig, TransformerConfig, transformer
from .base import ArchBundle

ARCH_ID = "granite-moe-3b-a800m"


def full_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID, n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49408,
        moe=MoEConfig(n_experts=40, top_k=8, d_ff=512), rope_theta=1e6)
    return ArchBundle(ARCH_ID, "moe", cfg, transformer,
                      extras={"true_vocab": 49155})


def smoke_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=48, n_heads=3,
        n_kv_heads=1, d_ff=64, vocab=256,
        moe=MoEConfig(n_experts=5, top_k=2, d_ff=64, capacity_factor=8.0), dtype=jnp.float32)
    return ArchBundle(ARCH_ID, "moe", cfg, transformer)
