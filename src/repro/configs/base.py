"""Architecture bundles: one uniform interface over all model families.

A bundle wires a model family (transformer / mamba2 / recurrentgemma /
whisper) to the launcher, dry-run, trainer and server:

  * ``abstract_params()``        — ShapeDtypeStruct tree (no allocation)
  * ``forward(params, batch)``   — training forward, (logits, aux)
  * ``prefill/decode_step``      — serving steps
  * ``input_specs(shape)``       — ShapeDtypeStruct batch for a named shape
  * ``step_kind(shape)``         — which step function the shape lowers
  * ``supports(shape)``          — long_500k only for sub-quadratic archs

SHAPES (assignment): train_4k (4096 x 256, train_step), prefill_32k
(32768 x 32, prefill), decode_32k (one token, 32k cache, batch 128),
long_500k (one token, 524288 context, batch 1; SSM/hybrid only).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


# ---------------------------------------------------------------------------
# Context-parallel ring-attention policy (§Perf B6)
#
# This replaces the old mutable ``models.layers.RING_PPERMUTE`` module
# global: callers resolve a policy here (explicit argument > REPRO_RING_ATTN
# env > default) instead of monkeypatching module state, so tests and
# benchmarks can pick a path per call or per process.
# ---------------------------------------------------------------------------

RING_MODES = ("auto", "ring", "replicated", "off")


@dataclasses.dataclass(frozen=True)
class RingAttnPolicy:
    """How ``models.layers.attention`` distributes long sequences over the
    ``model`` mesh axis.

    mode:
      * ``auto``       — ppermute ring (memory-flat custom VJP) for long
        sequences, replicated-k/v shard_map below ``seq_threshold`` (the
        XLA fallback: short sequences don't amortize the hop latency);
      * ``ring``       — always the ring when shapes divide;
      * ``replicated`` — always the replicated-k/v shard_map (§Perf B5);
      * ``off``        — neither; GSPMD constraint-based layout only.

    ``max_seq_per_device`` caps the ring shard: above it the per-hop
    (S/m x S/m) score tile outgrows the blocked XLA path's q-chunked
    tiles, so ``auto`` falls back to the replicated path."""
    mode: str = "auto"
    seq_threshold: int = 4096
    max_seq_per_device: int = 4096


DEFAULT_RING_POLICY = RingAttnPolicy()


def ring_attn_policy(mode_override: str | None = None) -> RingAttnPolicy:
    """Resolve the active ring policy.  Precedence: explicit
    ``mode_override`` (e.g. ``TransformerConfig.ring_attn`` or a test's
    keyword) > ``REPRO_RING_ATTN`` env var > ``DEFAULT_RING_POLICY``.
    ``REPRO_RING_ATTN_THRESHOLD`` / ``REPRO_RING_ATTN_MAX_SHARD`` tune the
    ``auto`` thresholds from the environment."""
    mode = (mode_override or os.environ.get("REPRO_RING_ATTN")
            or DEFAULT_RING_POLICY.mode)
    if mode not in RING_MODES:
        raise ValueError(f"ring-attention mode {mode!r} not in {RING_MODES}")
    thr = int(os.environ.get("REPRO_RING_ATTN_THRESHOLD",
                             DEFAULT_RING_POLICY.seq_threshold))
    cap = int(os.environ.get("REPRO_RING_ATTN_MAX_SHARD",
                             DEFAULT_RING_POLICY.max_seq_per_device))
    return RingAttnPolicy(mode=mode, seq_threshold=thr,
                          max_seq_per_device=cap)


def decide_ring(policy: RingAttnPolicy, *, seq_len: int,
                ring_size: int) -> str:
    """Pick the context-parallel mode for a global sequence of
    ``seq_len`` on a ``ring_size``-wide model axis: 'ring', 'replicated'
    or 'off'."""
    if policy.mode != "auto":
        return policy.mode
    if (seq_len >= policy.seq_threshold
            and seq_len // ring_size <= policy.max_seq_per_device):
        return "ring"
    return "replicated"


# ---------------------------------------------------------------------------
# Trainable flash-attention policy (the fused Pallas fwd+bwd kernels)
#
# Mirrors RingAttnPolicy: callers resolve a policy (explicit argument >
# REPRO_FLASH_ATTN env > default) instead of flag-flipping module state.
# The ring policy decides HOW long sequences distribute over the mesh;
# this one decides WHICH score-tile engine runs the local fold — the
# Pallas trainable kernel (custom-VJP fwd+bwd, pruned grid) or the XLA
# einsum paths.
# ---------------------------------------------------------------------------

FLASH_MODES = ("auto", "pallas", "xla")


@dataclasses.dataclass(frozen=True)
class FlashAttnPolicy:
    """Which attention engine ``models.layers.attention`` dispatches to.

    mode:
      * ``auto``   — the trainable Pallas kernel on TPU for sequences at
        least ``min_seq`` long (below it the XLA full-mask path wins on
        launch overhead); the XLA paths on CPU/GPU backends, where Pallas
        would run in interpret mode — an emulator, not an engine.
      * ``pallas`` — always the trainable kernel (interpret mode off-TPU;
        what the grad-equality tests and the microbench pin).
      * ``xla``    — never; the pre-existing einsum/blocked paths.
    """
    mode: str = "auto"
    min_seq: int = 1024


DEFAULT_FLASH_POLICY = FlashAttnPolicy()


def flash_attn_policy(mode_override: str | None = None) -> FlashAttnPolicy:
    """Resolve the active flash-attention policy.  Precedence: explicit
    ``mode_override`` (e.g. ``TransformerConfig.attn_impl``) >
    ``REPRO_FLASH_ATTN`` env var > default; ``REPRO_FLASH_ATTN_MIN_SEQ``
    tunes the ``auto`` threshold."""
    mode = (mode_override or os.environ.get("REPRO_FLASH_ATTN")
            or DEFAULT_FLASH_POLICY.mode)
    if mode not in FLASH_MODES:
        raise ValueError(f"flash-attention mode {mode!r} not in "
                         f"{FLASH_MODES}")
    ms = int(os.environ.get("REPRO_FLASH_ATTN_MIN_SEQ",
                            DEFAULT_FLASH_POLICY.min_seq))
    return FlashAttnPolicy(mode=mode, min_seq=ms)


def decide_flash(policy: FlashAttnPolicy, *, seq_len: int, kv_len: int,
                 on_tpu: bool) -> str:
    """'pallas' (the trainable fused kernel) or 'xla' for one attention
    call.  ``auto`` requires a real Mosaic backend and a sequence long
    enough to amortize kernel launch + pair-table prefetch."""
    if policy.mode != "auto":
        return policy.mode
    if on_tpu and max(seq_len, kv_len) >= policy.min_seq:
        return "pallas"
    return "xla"


@dataclasses.dataclass
class ArchBundle:
    arch_id: str
    kind: str                   # dense | moe | vlm | ssm | audio | hybrid
    cfg: Any
    family: Any                 # model module
    sub_quadratic: bool = False
    kv_dtype_decode: Any = None  # e.g. jnp.int8 for big dense decode
    extras: dict = dataclasses.field(default_factory=dict)

    # -- params ------------------------------------------------------------
    def init_params(self, key: jax.Array):
        return self.family.init_params(self.cfg, key)

    def abstract_params(self):
        return jax.eval_shape(
            lambda k: self.family.init_params(self.cfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))

    def param_count(self) -> int:
        return self.cfg.param_count()

    def active_param_count(self) -> int:
        if hasattr(self.cfg, "active_param_count"):
            return self.cfg.active_param_count()
        return self.param_count()

    # -- steps ---------------------------------------------------------------
    def forward(self, params, batch):
        if self.kind == "audio":
            return self.family.forward(self.cfg, params, batch["tokens"],
                                       batch["frames"])
        if self.kind == "vlm":
            return self.family.forward(self.cfg, params, batch["tokens"],
                                       vision_embeds=batch["vision"])
        return self.family.forward(self.cfg, params, batch["tokens"])

    def init_cache(self, batch: int, max_len: int, kv_dtype=None):
        return self.family.init_cache(self.cfg, batch, max_len,
                                      kv_dtype=kv_dtype)

    def prefill(self, params, tokens, cache, batch_extras=None,
                true_lengths=None):
        if true_lengths is not None and not self.prefill_supports_true_lengths:
            raise ValueError(
                f"{self.arch_id}: family does not support bucketed "
                "(true_lengths) prefill")
        if self.kind == "audio":
            return self.family.prefill(self.cfg, params, tokens, cache,
                                       (batch_extras or {})["frames"])
        if self.kind == "vlm":
            kw = {}
            if true_lengths is not None:
                # the vision prefix is prepended inside prefill, so true
                # sequence lengths shift by the (fixed) prefix size
                vis = (batch_extras or {}).get("vision")
                off = vis.shape[1] if vis is not None else 0
                kw["true_lengths"] = true_lengths + off
            return self.family.prefill(
                self.cfg, params, tokens, cache,
                vision_embeds=(batch_extras or {}).get("vision"), **kw)
        kw = {} if true_lengths is None else {"true_lengths": true_lengths}
        return self.family.prefill(self.cfg, params, tokens, cache, **kw)

    def decode_step(self, params, tokens, cache):
        return self.family.decode_step(self.cfg, params, tokens, cache)

    # -- serving capabilities ---------------------------------------------
    @property
    def prefill_supports_true_lengths(self) -> bool:
        """Whether prefill accepts length-bucketed padded prompts (KV
        caches with per-position writes; SSM states do not qualify)."""
        return bool(getattr(self.family, "PREFILL_TRUE_LENGTHS", False)) \
            and self.kind != "audio"

    @property
    def supports_paged_kv(self) -> bool:
        # vlm excluded: the vision prefix enters through dense prefill's
        # embedding concat; the paged chunked-prefill path is token-only.
        return bool(getattr(self.family, "SUPPORTS_PAGED_KV", False)) \
            and self.kind != "vlm"

    def init_paged_pool(self, num_pages: int, page_size: int, kv_dtype=None):
        return self.family.init_paged_pool(self.cfg, num_pages, page_size,
                                           kv_dtype=kv_dtype)

    def paged_step(self, params, tokens, pool, page_table, lengths, counts):
        return self.family.paged_step(self.cfg, params, tokens, pool,
                                      page_table, lengths, counts)

    def cache_batch_axes(self, cache) -> dict:
        """Batch-axis index for every cache entry (pooled slot writes).
        Families declare ``CACHE_BATCH_AXES``; unknown keys fall back to
        the historical heuristic (axis 0 for 1-D entries, else axis 1)."""
        declared = getattr(self.family, "CACHE_BATCH_AXES", {})
        return {k: declared.get(k, 0 if jnp.ndim(v) == 1 else 1)
                for k, v in cache.items()}

    def min_hbm_bytes(self, shape_name: str) -> int:
        """Theoretical HBM traffic floor for one step of this shape.

        train:   params read fwd+bwd + grads w+r + Adam mu/nu r+w (f32) +
                 layer-boundary activations w+r (bf16)
        decode:  full params read once + KV cache read (+ small writes)
        prefill: params read + activations written + cache written
        """
        sh = SHAPES[shape_name]
        S, B = sh["seq_len"], sh["global_batch"]
        kind = sh["kind"]
        n = self.param_count()
        n_active = self.active_param_count()
        D = self.cfg.d_model
        L = getattr(self.cfg, "n_layers", 1)
        if kind == "train":
            act = 2 * 2 * L * B * S * D           # save+read, bf16
            return int(3 * 2 * n + (4 + 16) * n + 2 * n_active * 0 + act)
        # serving floors
        cache = jax.eval_shape(functools.partial(self.init_cache, B, S))
        cache_bytes = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(cache))
        if kind == "decode":
            return int(2 * n + cache_bytes)
        act = 2 * B * S * D * L
        return int(2 * n + cache_bytes + act)

    # -- shapes ----------------------------------------------------------------
    def supports(self, shape_name: str) -> tuple[bool, str]:
        if shape_name == "long_500k" and not self.sub_quadratic:
            return False, ("full quadratic attention: 512k decode cache "
                           "infeasible; run on SSM/hybrid archs only "
                           "(see DESIGN.md §Arch-applicability)")
        return True, ""

    def step_kind(self, shape_name: str) -> str:
        return SHAPES[shape_name]["kind"]

    def input_specs(self, shape_name: str) -> dict:
        """ShapeDtypeStruct stand-ins for every input of the step function."""
        sh = SHAPES[shape_name]
        S, B = sh["seq_len"], sh["global_batch"]
        kind = sh["kind"]
        i32 = jnp.int32
        D = self.cfg.d_model

        if kind == "train":
            if self.kind == "vlm":
                P = self.cfg.vision_tokens
                return {
                    "tokens": jax.ShapeDtypeStruct((B, S - P), i32),
                    "labels": jax.ShapeDtypeStruct((B, S - P), i32),
                    "vision": jax.ShapeDtypeStruct((B, P, D), self.cfg.dtype),
                }
            if self.kind == "audio":
                return {
                    "tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "labels": jax.ShapeDtypeStruct((B, S), i32),
                    "frames": jax.ShapeDtypeStruct(
                        (B, self.cfg.n_audio_ctx, D), self.cfg.dtype),
                }
            return {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }

        if kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if self.kind == "vlm":
                P = self.cfg.vision_tokens
                specs["tokens"] = jax.ShapeDtypeStruct((B, S - P), i32)
                specs["vision"] = jax.ShapeDtypeStruct((B, P, D),
                                                       self.cfg.dtype)
            if self.kind == "audio":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, self.cfg.n_audio_ctx, D), self.cfg.dtype)
            specs["cache"] = jax.eval_shape(
                functools.partial(self.init_cache, B, S))
            return specs

        # decode: one token against a cache of S
        kv_dt = self.kv_dtype_decode if shape_name == "decode_32k" else None
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "cache": jax.eval_shape(
                functools.partial(self.init_cache, B, S, kv_dtype=kv_dt)),
        }
        return specs
