"""whisper-medium [audio]: 24L (enc) + 24L (dec) d=1024 16H (MHA) d_ff=4096
vocab=51968 — enc-dec; the conv frontend is a STUB (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
import jax.numpy as jnp

from repro.models import WhisperConfig, whisper
from .base import ArchBundle

ARCH_ID = "whisper-medium"


def full_bundle() -> ArchBundle:
    cfg = WhisperConfig(name=ARCH_ID, n_layers=24, d_model=1024, n_heads=16,
                        d_ff=4096, vocab=51968, n_audio_ctx=1500,
                        max_text_ctx=32768)
    return ArchBundle(ARCH_ID, "audio", cfg, whisper,
                      extras={"true_vocab": 51865})


def smoke_bundle() -> ArchBundle:
    cfg = WhisperConfig(name=ARCH_ID + "-smoke", n_layers=2, d_model=64,
                        n_heads=4, d_ff=128, vocab=256, n_audio_ctx=32,
                        max_text_ctx=64, dtype=jnp.float32)
    return ArchBundle(ARCH_ID, "audio", cfg, whisper)
