"""qwen2.5-14b [dense]: 48L d=5120 40H (GQA kv=8) d_ff=13824 vocab=152064 —
GQA + QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
import jax.numpy as jnp

from repro.models import TransformerConfig, transformer
from .base import ArchBundle

ARCH_ID = "qwen2.5-14b"


def full_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID, n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab=152064, qkv_bias=True, rope_theta=1e6)
    return ArchBundle(ARCH_ID, "dense", cfg, transformer)


def smoke_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=80, n_heads=5,
        n_kv_heads=1, d_ff=160, vocab=256, qkv_bias=True,
        dtype=jnp.float32)
    return ArchBundle(ARCH_ID, "dense", cfg, transformer)
