"""olmoe-1b-7b [moe]: 16L d=2048 16H (MHA kv=16) vocab=50304, MoE 64 experts
top-8, expert d_ff=1024. [arXiv:2409.02060; hf]"""
import jax.numpy as jnp

from repro.models import MoEConfig, TransformerConfig, transformer
from .base import ArchBundle

ARCH_ID = "olmoe-1b-7b"


def full_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID, n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab=50304,
        moe=MoEConfig(n_experts=64, top_k=8, d_ff=1024), rope_theta=1e6)
    return ArchBundle(ARCH_ID, "moe", cfg, transformer)


def smoke_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=96, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=96, capacity_factor=8.0), dtype=jnp.float32)
    return ArchBundle(ARCH_ID, "moe", cfg, transformer)
