"""qwen1.5-32b [dense]: 64L d=5120 40H (MHA kv=40) d_ff=27392 vocab=152064 —
QKV bias. Decode caches quantize to int8 (MHA cache at 32k x 128 batch
exceeds pod HBM in bf16; see EXPERIMENTS.md). [hf:Qwen/Qwen1.5-0.5B; hf]"""
import jax.numpy as jnp

from repro.models import TransformerConfig, transformer
from .base import ArchBundle

ARCH_ID = "qwen1.5-32b"


def full_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID, n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=27392, vocab=152064, qkv_bias=True, rope_theta=1e6)
    return ArchBundle(ARCH_ID, "dense", cfg, transformer,
                      kv_dtype_decode=jnp.int8)


def smoke_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=192, vocab=256, qkv_bias=True,
        dtype=jnp.float32)
    return ArchBundle(ARCH_ID, "dense", cfg, transformer,
                      kv_dtype_decode=jnp.int8)
