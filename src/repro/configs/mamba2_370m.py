"""mamba2-370m [ssm]: 48L d=1024, attn-free, ssm_state=128, vocab=50432 —
SSD (state-space duality). Sub-quadratic: runs long_500k.
[arXiv:2405.21060; unverified]"""
import jax.numpy as jnp

from repro.models import Mamba2Config, mamba2
from .base import ArchBundle

ARCH_ID = "mamba2-370m"


def full_bundle() -> ArchBundle:
    cfg = Mamba2Config(name=ARCH_ID, n_layers=48, d_model=1024,
                       vocab=50432, d_state=128, headdim=64, chunk=256)
    return ArchBundle(ARCH_ID, "ssm", cfg, mamba2, sub_quadratic=True,
                      extras={"true_vocab": 50280})


def smoke_bundle() -> ArchBundle:
    cfg = Mamba2Config(name=ARCH_ID + "-smoke", n_layers=2, d_model=64,
                       vocab=256, d_state=16, headdim=16, chunk=16,
                       dtype=jnp.float32)
    return ArchBundle(ARCH_ID, "ssm", cfg, mamba2, sub_quadratic=True)
