"""internvl2-26b [vlm]: InternLM2 backbone 48L d=6144 48H (GQA kv=8)
d_ff=16384 vocab=92672. The InternViT frontend is a STUB per the assignment:
input_specs supplies precomputed patch embeddings (B, 256, D) that prefix
the text sequence. [arXiv:2404.16821; hf]"""
import jax.numpy as jnp

from repro.models import TransformerConfig, transformer
from .base import ArchBundle

ARCH_ID = "internvl2-26b"


def full_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID, n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=92672, vision_tokens=256, rope_theta=1e6)
    return ArchBundle(ARCH_ID, "vlm", cfg, transformer,
                      extras={"true_vocab": 92553})


def smoke_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, vision_tokens=8,
        dtype=jnp.float32)
    return ArchBundle(ARCH_ID, "vlm", cfg, transformer)
