"""recurrentgemma-9b [hybrid]: 38L d=4096 16H (MQA kv=1, head_dim 256)
d_ff=12288 — RG-LRU + local attention (window 2048), pattern 1 attn : 2 rec.
vocab=256000. Sub-quadratic: runs long_500k (decode state is O(1) + a
window-bounded attention cache). [arXiv:2402.19427; unverified]"""
import jax.numpy as jnp

from repro.models import RGConfig, recurrentgemma
from .base import ArchBundle

ARCH_ID = "recurrentgemma-9b"


def full_bundle() -> ArchBundle:
    cfg = RGConfig(name=ARCH_ID, n_layers=38, d_model=4096, n_heads=16,
                   n_kv_heads=1, d_ff=12288, vocab=256000, window=2048)
    return ArchBundle(ARCH_ID, "hybrid", cfg, recurrentgemma,
                      sub_quadratic=True)


def smoke_bundle() -> ArchBundle:
    cfg = RGConfig(name=ARCH_ID + "-smoke", n_layers=5, d_model=64,
                   n_heads=4, n_kv_heads=1, d_ff=128, vocab=256, window=64,
                   dtype=jnp.float32)
    return ArchBundle(ARCH_ID, "hybrid", cfg, recurrentgemma,
                      sub_quadratic=True)
