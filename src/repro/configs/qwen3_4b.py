"""qwen3-4b [dense]: 36L d=2560 32H (GQA kv=8, head_dim 128) d_ff=9728
vocab=151936 — qk_norm, no qkv bias. [hf:Qwen/Qwen3-8B; hf]"""
import jax.numpy as jnp

from repro.models import TransformerConfig, transformer
from .base import ArchBundle

ARCH_ID = "qwen3-4b"


def full_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID, n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
        d_ff=9728, vocab=151936, head_dim=128, qk_norm=True,
        rope_theta=1e6)
    return ArchBundle(ARCH_ID, "dense", cfg, transformer)


def smoke_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, head_dim=16, qk_norm=True,
        dtype=jnp.float32)
    return ArchBundle(ARCH_ID, "dense", cfg, transformer)
