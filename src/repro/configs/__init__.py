"""Config registry: ``get_bundle(arch_id, smoke=False)`` + the shape table."""
from __future__ import annotations

from .base import (RING_MODES, SHAPES, ArchBundle, RingAttnPolicy,
                   decide_ring, ring_attn_policy)
from . import (granite_moe_3b, internvl2_26b, mamba2_370m, olmoe_1b_7b,
               qwen1_5_32b, qwen2_5_14b, qwen3_4b, recurrentgemma_9b,
               whisper_medium, yi_9b)

_MODULES = (qwen3_4b, qwen2_5_14b, qwen1_5_32b, yi_9b, internvl2_26b,
            granite_moe_3b, olmoe_1b_7b, mamba2_370m, whisper_medium,
            recurrentgemma_9b)

REGISTRY = {m.ARCH_ID: m for m in _MODULES}
ARCH_IDS = tuple(REGISTRY)


def get_bundle(arch_id: str, smoke: bool = False) -> ArchBundle:
    mod = REGISTRY[arch_id]
    return mod.smoke_bundle() if smoke else mod.full_bundle()


__all__ = ["SHAPES", "ArchBundle", "REGISTRY", "ARCH_IDS", "get_bundle",
           "RING_MODES", "RingAttnPolicy", "decide_ring", "ring_attn_policy"]
