"""yi-9b [dense]: 48L d=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-arch GQA. [arXiv:2403.04652; hf]"""
import jax.numpy as jnp

from repro.models import TransformerConfig, transformer
from .base import ArchBundle

ARCH_ID = "yi-9b"


def full_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID, n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab=64000, rope_theta=5e6)
    return ArchBundle(ARCH_ID, "dense", cfg, transformer)


def smoke_bundle() -> ArchBundle:
    cfg = TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=128, vocab=256, dtype=jnp.float32)
    return ArchBundle(ARCH_ID, "dense", cfg, transformer)
