"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Optimizer state is f32 regardless of param dtype (mixed precision); state
trees mirror the param tree so the same PartitionSpecs shard both.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    decay_steps = jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: dict) -> tuple[Any, dict, dict]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state["mu"], grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state["nu"], grads)

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (
            step_ + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": mu, "nu": nu, "step": step}, metrics
