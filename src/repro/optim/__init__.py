from .adamw import (AdamWConfig, adamw_init, adamw_update, clip_by_global_norm,
                    cosine_schedule)
from .compression import (compress_int8, decompress_int8,
                          ef_compressed_psum, init_error_feedback)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "clip_by_global_norm",
    "cosine_schedule", "compress_int8", "decompress_int8",
    "ef_compressed_psum", "init_error_feedback",
]
