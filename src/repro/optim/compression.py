"""Int8 error-feedback gradient compression for the cross-pod axis.

At 512+ chips the pod-to-pod (DCN or long-ICI) all-reduce of bf16 gradients
is the scaling bottleneck; compressing the pod-axis reduction to int8 with
per-tensor scales cuts those bytes 2x vs bf16 (4x vs f32) at negligible
quality cost when the quantization error is fed back (EF-SGD / 1-bit-Adam
lineage). Inside a pod the reduction stays full precision.

``ef_compressed_psum`` is used inside shard_map: quantize(g + e) -> int8
all-reduce over `axis` -> dequantize; the residual e' = (g + e) - q(g + e)
is carried to the next step.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compressed_psum(grads: Any, errors: Any, axis: str) -> tuple[Any, Any]:
    """Compressed mean-all-reduce over mesh axis `axis` with error feedback.

    Call INSIDE shard_map. Returns (reduced_grads_f32, new_errors).
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = compress_int8(x)
        # int8 payloads all-reduce; scales all-reduce too (sum of per-pod
        # contributions approximates the sum of dequantized tensors when we
        # reduce q*scale — we reduce the dequantized f32 of the local quant,
        # which keeps the wire format int8 + one scalar).
        deq_local = decompress_int8(q, scale)
        reduced = jax.lax.psum(deq_local, axis) / n
        new_e = x - deq_local           # what this shard failed to send
        return reduced, new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = tree.unflatten([o[0] for o in outs])
    errs = tree.unflatten([o[1] for o in outs])
    return red, errs
