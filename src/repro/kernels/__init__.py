# Pallas TPU kernels for the compute hot-spots the paper optimizes:
# the TEU tile executor as (1) output-stationary matmul, (2) direct conv2d
# (Eq. 2 incl. stride/dilation), (3) spatial-matching correlation (Eq. 3),
# and (4) flash attention (QK^T = Eq. 3 at LM scale) for prefill + decode.
# ops.py = jit'd wrappers (block shapes from the paper's tile search);
# ref.py = pure-jnp oracles for allclose validation (interpret mode on CPU).
from . import ops, ref
from .ops import (conv2d, correlation, flash_attention, flash_decode, matmul)

__all__ = ["ops", "ref", "conv2d", "correlation", "flash_attention",
           "flash_decode", "matmul"]
