"""Paged flash-decode Pallas kernel: page-table-gathered KV attention.

The serving twin of ``attention._decode_kernel``: instead of a dense
(B, S, Hkv, Dh) cache, K/V live in a global POOL of fixed-size pages and a
per-slot page table says which physical pages hold a slot's history.  The
page table is a scalar-prefetch operand (``compat.prefetch_scalar_grid_spec``)
so the K/V BlockSpec index maps chase it *inside the grid* — the gather is
pure DMA scheduling, no materialized contiguous copy.  This is the paper's
exchange-mesh move at serving scale: small local tiles (pages) promoted to
global visibility through an index fabric instead of dense reservation.

Grid: (B*Hkv, n_pages_per_slot); page j of slot b streams through VMEM
while the online-softmax accumulator for that slot/kv-head group stays
stationary — identical schedule to the dense decode kernel, only the
kv-block address is indirected.

The int8 path keeps the pool quantized in HBM and dequantizes one page at
a time inside the kernel (per-(token, head) scales ride along as their own
scalar-indexed blocks), so quantized serving never materializes an f32
cache.

On-TPU note: blocks are (page_size, Dh); with the default page_size=16 and
Dh=128 the bf16 tiles meet the (16, 128) packing rule, while int8 pools
want page_size >= 32 on real hardware (interpret mode, the CI path, does
not care).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.runtime import compat

NEG_INF = -1e30  # avoid nan from (-inf) - (-inf)


def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                         scale: float, page_size: int, quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # flat grid axis 0 = b * Hkv + h; lengths are replicated per kv head by
    # the wrapper so len_ref indexes directly by the flat id.
    b = pl.program_id(0)
    k = k_ref[0, 0].astype(jnp.float32)     # (page_size, d)
    v = v_ref[0, 0].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0, 0][:, None]
        v = v * vs_ref[0, 0][:, None]
    q = q_ref[0]                            # (group, d)
    s = jax.lax.dot_general(
        q.astype(jnp.float32), k,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (group, page_size)
    kpos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[b], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _drain():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def paged_flash_decode_pallas(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, page_table: jax.Array,
                              lengths: jax.Array,
                              k_scale: jax.Array | None = None,
                              v_scale: jax.Array | None = None, *,
                              page_size: int,
                              scale: float | None = None,
                              interpret: bool = False) -> jax.Array:
    """q: (B*Hkv, group, D) one token per slot, grouped by kv head;
    k_pages/v_pages: (Hkv, P, page_size, D) global pools; page_table:
    (B*Hkv, max_pages) physical ids (page 0 = trash, masked by length);
    lengths: (B*Hkv,) valid cached tokens (>= 1: page 0 of every live slot
    covers position 0, so the first grid step is never fully masked).
    Scales (int8 pools): (Hkv, P, page_size) f32.  Returns (B*Hkv, group,
    D).  The wrapper (kernels/ops.py) replicates per-slot tables/lengths
    across kv heads so grid axis 0 is flat (b, kv head)."""
    BH, G, Dh = q.shape
    Hkv, P, pg, _ = k_pages.shape
    assert pg == page_size, (pg, page_size)
    assert BH % Hkv == 0, (BH, Hkv)
    MP = page_table.shape[1]
    quantized = k_scale is not None
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    grid = (BH, MP)

    def kv_spec():
        # page indirection: block index for the page axis comes from the
        # prefetched table, the kv-head axis from the flat grid id.
        return pl.BlockSpec(
            (1, 1, page_size, Dh),
            lambda h, j, pt_ref, len_ref: (h % Hkv, pt_ref[h, j], 0, 0))

    def scale_spec():
        return pl.BlockSpec(
            (1, 1, page_size),
            lambda h, j, pt_ref, len_ref: (h % Hkv, pt_ref[h, j], 0))

    in_specs = [
        pl.BlockSpec((1, G, Dh), lambda h, j, pt_ref, len_ref: (h, 0, 0)),
        kv_spec(),
        kv_spec(),
    ]
    operands = [q, k_pages, v_pages]
    if quantized:
        in_specs += [scale_spec(), scale_spec()]
        operands += [k_scale, v_scale]

    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, G, Dh),
                               lambda h, j, pt_ref, len_ref: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
    )
    kern = functools.partial(_paged_decode_kernel, scale=scale,
                             page_size=page_size, quantized=quantized)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, G, Dh), q.dtype),
        interpret=interpret,
    )(page_table, lengths, *operands)
