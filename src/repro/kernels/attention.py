"""Flash attention Pallas kernels (prefill + decode) with GQA / windowing.

Attention IS the paper's spatial-matching workload at LM scale: QK^T is
Eq. (3) with the search window = the causal (or sliding) window, and the
online-softmax accumulator is the PSum buffer held stationary while the
temporal index (the kv block) streams — the same output-stationary schedule
``core.tiling`` derives for Eq. (4). GQA enters through the K/V index maps:
the q-head grid axis has zero partial derivative against the kv head beyond
its group, so K/V blocks are SHARED across the q-heads of a group exactly
like Fig. 2 shares E between P and Q.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # avoid nan from (-inf) - (-inf)


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int | None,
               block_q: int, block_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (block_q, d)
    k = k_ref[0]                       # (block_k, d)
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    iq = pl.program_id(1)
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _drain():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           block_q: int = 128, block_k: int = 128,
                           scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, D); k, v: (BH_kv, Sk, D) with BH % BH_kv == 0 (GQA groups
    must be laid out so head h of q uses kv head h // (BH // BH_kv))."""
    BH, Sq, Dh = q.shape
    BHkv, Sk, _ = k.shape
    assert BH % BHkv == 0, (BH, BHkv)
    group = BH // BHkv
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    grid = (BH, Sq // block_q, Sk // block_k)

    kern = functools.partial(_fa_kernel, scale=scale, causal=causal,
                             window=window, block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, Dh), lambda h, iq, ik: (h, iq, 0)),
            # K/V shared across the q-heads of a GQA group (zero derivative
            # of the kv index against the intra-group head axis).
            pl.BlockSpec((1, block_k, Dh),
                         lambda h, iq, ik: (h // group, ik, 0)),
            pl.BlockSpec((1, block_k, Dh),
                         lambda h, iq, ik: (h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dh), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Decode: one new token against a KV cache (the decode_* / long_* shapes).
# ---------------------------------------------------------------------------

def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_k: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (nq, d) — the group's q heads
    k = k_ref[0]                       # (block_k, d)
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (nq, block_k)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(1) - 1)
    def _drain():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_decode_pallas(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                        lengths: jax.Array, *, block_k: int = 512,
                        scale: float | None = None,
                        interpret: bool = False) -> jax.Array:
    """q: (B*Hkv, group, D) one token per sequence, grouped by kv head;
    k_cache/v_cache: (B*Hkv, S, D); lengths: (B*Hkv,) valid cache lengths.
    Returns (B*Hkv, group, D)."""
    BH, G, Dh = q.shape
    BH2, S, _ = k_cache.shape
    assert BH == BH2 and S % block_k == 0, (q.shape, k_cache.shape, block_k)
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    grid = (BH, S // block_k)
    kern = functools.partial(_decode_kernel, scale=scale, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, Dh), lambda h, ik: (h, 0, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda h, ik: (h, ik, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda h, ik: (h, ik, 0)),
            pl.BlockSpec((1,), lambda h, ik: (h,)),
        ],
        out_specs=pl.BlockSpec((1, G, Dh), lambda h, ik: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, lengths)
