"""Flash attention Pallas kernels (prefill fwd + bwd + decode), GQA/windowed.

Attention IS the paper's spatial-matching workload at LM scale: QK^T is
Eq. (3) with the search window = the causal (or sliding) window, and the
online-softmax accumulator is the PSum buffer held stationary while the
temporal index (the kv block) streams — the same output-stationary schedule
``core.tiling`` derives for Eq. (4). GQA enters through the K/V index maps:
the q-head grid axis has zero partial derivative against the kv head beyond
its group, so K/V blocks are SHARED across the q-heads of a group exactly
like Fig. 2 shares E between P and Q.

Three schedule ideas from the paper/related work live here:

* **Pair-table grid (Eyeriss-v2-style pruning).**  Instead of a dense
  rectangular ``(nq, nk)`` grid with ``pl.when`` no-ops on fully-masked
  tiles, the (q-block, k-block) pairs that survive the causal/sliding-
  window band are enumerated ON THE HOST into a static int32 schedule
  table, passed as a scalar-prefetch operand; the BlockSpec index maps
  chase it in-grid exactly like the paged kernel chases its page table.
  Fully-masked k-blocks are never scheduled — skipped FIFO hops, not
  streamed-and-discarded ones.  Causal cuts the scheduled tiles ~2x
  (nq*(nq+1)/2 of nq*nk), a sliding window to the band width.

* **Backward = PSum drain + re-stream.**  The forward saves only
  ``(o, lse)`` (same residual contract as ``parallel/ring_attention``);
  the dq kernel re-streams k-blocks holding a q-row accumulator
  stationary, the dk/dv kernel re-streams q-blocks holding a k-column
  accumulator stationary, each recomputing its score tile from
  ``(q, k, lse)`` — two more passes of the identical output-stationary
  schedule, never materializing S x S.

* **Traced position offsets.**  Ring attention folds one visiting shard
  per hop; the shard's global offset is a traced ``axis_index``.  Offsets
  ride as a second scalar-prefetch operand so the very same kernels serve
  the single-device path (static offsets, pruned schedule) and the ring's
  per-hop fold (traced offsets, dense schedule).
"""
from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pallas_bridge import pow2_floor
from repro.runtime import compat

NEG_INF = -1e30  # avoid nan from (-inf) - (-inf)


# ---------------------------------------------------------------------------
# Host-side pair-table schedules (the pruned grid)
# ---------------------------------------------------------------------------

def _row_range(iq: int, *, nk: int, block_q: int, block_k: int,
               causal: bool, window: int | None, kv_len: int,
               q_len: int) -> tuple[int, int]:
    """Inclusive [lo, hi] k-block range that q-block ``iq`` touches, or
    (0, -1) when the whole row is masked (padded q rows / empty bands)."""
    q_lo = iq * block_q
    q_hi = min(q_lo + block_q, q_len) - 1
    if q_hi < q_lo:                       # fully-padded q block
        return 0, -1
    lo, hi = 0, nk - 1
    hi = min(hi, (kv_len - 1) // block_k)    # never stream padded k blocks
    if causal:
        hi = min(hi, q_hi // block_k)
    if window is not None:
        # need some kpos with q_lo - kpos < window, i.e. k_hi > q_lo - window
        lo = max(lo, -(-(q_lo - window + 2 - block_k) // block_k))
    return lo, hi


@functools.lru_cache(maxsize=None)
def _pair_schedule(nq: int, nk: int, block_q: int, block_k: int,
                   causal: bool, window: int | None, kv_len: int,
                   q_len: int, order: str) -> tuple[np.ndarray, int]:
    """Static (n_pairs, 4) int32 schedule of surviving (q-block, k-block)
    grid steps: columns are (iq, ik, first, last).

    ``order='row'`` (forward / dq): pairs grouped by q block, so the
    output o/dq block index is constant across consecutive steps and the
    online-softmax scratch drains exactly once per row.  ``order='col'``
    (dk/dv): grouped by k block.  first/last flag the group boundaries
    (accumulator init / drain).  Rows (and, in 'col' order, columns) with
    an empty band still get one fully-masked sentinel pair so every
    output block is initialized and drained — the mask guard inside the
    kernels zeroes its contribution.

    Returns (table, n_scheduled) where n_scheduled counts the REAL pairs
    (sentinels excluded) — the number the pruning benchmark reports.
    """
    rows: list[list[int]] = []
    n_real = 0
    for iq in range(nq):
        lo, hi = _row_range(iq, nk=nk, block_q=block_q, block_k=block_k,
                            causal=causal, window=window, kv_len=kv_len,
                            q_len=q_len)
        if hi < lo:
            rows.append([iq, 0, -1, -1])  # sentinel: fully masked
        else:
            n_real += hi - lo + 1
            for ik in range(lo, hi + 1):
                rows.append([iq, ik, 0, 0])
    if order == "col":
        by_col: dict[int, list[int]] = {ik: [] for ik in range(nk)}
        for iq, ik, s, _ in rows:
            if s != -1:
                by_col[ik].append(iq)
        rows = []
        for ik in range(nk):
            iqs = by_col[ik] or [nq - 1]   # sentinel for untouched columns
            for j, iq in enumerate(iqs):
                rows.append([iq, ik, int(j == 0), int(j == len(iqs) - 1)])
    else:
        assert order == "row", order
        out = []
        by_row: dict[int, list[list[int]]] = {}
        for r in rows:
            by_row.setdefault(r[0], []).append(r)
        for iq in range(nq):
            group = by_row[iq]
            for j, r in enumerate(group):
                out.append([r[0], max(r[1], 0), int(j == 0),
                            int(j == len(group) - 1)])
        rows = out
    table = np.asarray(rows, dtype=np.int32)
    return table, n_real


def scheduled_block_counts(Sq: int, Sk: int, *, block_q: int, block_k: int,
                           causal: bool, window: int | None
                           ) -> tuple[int, int]:
    """(scheduled, dense) k-block counts for one head's grid — the
    pruning win the benchmark reports (dense = nq * nk)."""
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    _, real = _pair_schedule(nq, nk, block_q, block_k, bool(causal),
                             window, Sk, Sq, "row")
    return real, nq * nk


# ---------------------------------------------------------------------------
# Forward kernel: online softmax, emits (o, lse)
# ---------------------------------------------------------------------------

def _fa_fwd_kernel(sched_ref, offs_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
                   window: int | None, block_q: int, block_k: int,
                   kv_len: int):
    p_id = pl.program_id(1)
    iq = sched_ref[p_id, 0]
    ik = sched_ref[p_id, 1]
    first = sched_ref[p_id, 2]
    last = sched_ref[p_id, 3]

    @pl.when(first == 1)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (block_q, d)
    k = k_ref[0]                       # (block_k, d)
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    loc_k = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    qpos = offs_ref[0] + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = offs_ref[1] + loc_k
    mask = loc_k < kv_len              # padded keys are never attended
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # guard: a fully-masked tile must not contribute exp(0)=1 weights
    # while the running max is still NEG_INF (the self-healing alpha only
    # erases them once a live tile arrives — which pruning may never
    # schedule for sentinel rows)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(last == 1)
    def _drain():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(safe)


def _as_offs(q_offset, k_offset) -> jax.Array:
    return jnp.asarray(
        jnp.stack([jnp.asarray(q_offset), jnp.asarray(k_offset)]),
        jnp.int32)


def flash_attention_fwd_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                               causal: bool = True,
                               window: int | None = None,
                               block_q: int = 128, block_k: int = 128,
                               scale: float | None = None,
                               kv_len: int | None = None,
                               q_len: int | None = None,
                               q_offset=0, k_offset=0,
                               prune: bool = True,
                               interpret: bool = False
                               ) -> tuple[jax.Array, jax.Array]:
    """q: (BH, Sq, D); k, v: (BH_kv, Sk, D) with BH % BH_kv == 0 (GQA groups
    must be laid out so head h of q uses kv head h // (BH // BH_kv)).

    Returns ``(o, lse)`` with ``lse`` f32 (BH, Sq) — the flash residual.
    ``kv_len``/``q_len`` bound the VALID region when Sq/Sk carry padding;
    ``q_offset``/``k_offset`` (traced OK) shift the band mask to global
    positions for the ring's per-hop fold.  ``prune=True`` drops fully-
    masked k-blocks from the schedule (takes effect only when both
    offsets are statically zero — shifted bands use the dense grid)."""
    BH, Sq, Dh = q.shape
    BHkv, Sk, _ = k.shape
    assert BH % BHkv == 0, (BH, BHkv)
    group = BH // BHkv
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    kv_len = Sk if kv_len is None else kv_len
    q_len = Sq if q_len is None else q_len
    nq, nk = Sq // block_q, Sk // block_k
    # the pruned schedule is built in LOCAL positions — any nonzero (or
    # traced) offset shifts the band, so those calls get the dense grid
    # and rely on the in-kernel mask alone
    zero_offs = (isinstance(q_offset, int) and isinstance(k_offset, int)
                 and q_offset == 0 and k_offset == 0)
    if prune and zero_offs:
        sched, _ = _pair_schedule(nq, nk, block_q, block_k, bool(causal),
                                  window, kv_len, q_len, "row")
    else:
        sched, _ = _pair_schedule(nq, nk, block_q, block_k, False, None,
                                  kv_len, q_len, "row")
    n_pairs = sched.shape[0]

    kern = functools.partial(_fa_fwd_kernel, scale=scale, causal=causal,
                             window=window, block_q=block_q, block_k=block_k,
                             kv_len=kv_len)
    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=2,
        grid=(BH, n_pairs),
        in_specs=[
            pl.BlockSpec((1, block_q, Dh),
                         lambda h, p, sr, orf: (h, sr[p, 0], 0)),
            # K/V shared across the q-heads of a GQA group (zero derivative
            # of the kv index against the intra-group head axis).
            pl.BlockSpec((1, block_k, Dh),
                         lambda h, p, sr, orf: (h // group, sr[p, 1], 0)),
            pl.BlockSpec((1, block_k, Dh),
                         lambda h, p, sr, orf: (h // group, sr[p, 1], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, Dh),
                         lambda h, p, sr, orf: (h, sr[p, 0], 0)),
            pl.BlockSpec((1, block_q), lambda h, p, sr, orf: (h, sr[p, 0])),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((BH, Sq, Dh), q.dtype),
                   jax.ShapeDtypeStruct((BH, Sq), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(sched), _as_offs(q_offset, k_offset), q, k, v)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           block_q: int = 128, block_k: int = 128,
                           scale: float | None = None,
                           interpret: bool = False) -> jax.Array:
    """Forward-only entry (kept for benches/oracle sweeps); the trainable
    path is ``flash_attention_train``."""
    o, _ = flash_attention_fwd_pallas(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_k=block_k, scale=scale, interpret=interpret)
    return o


# ---------------------------------------------------------------------------
# Backward kernels: dq re-streams k-blocks, dk/dv re-stream q-blocks
# ---------------------------------------------------------------------------

def _fa_bwd_dq_kernel(sched_ref, offs_ref, q_ref, k_ref, v_ref, do_ref,
                      lse_ref, delta_ref, dq_ref, acc_ref, *, scale: float,
                      causal: bool, window: int | None, block_q: int,
                      block_k: int, kv_len: int):
    p_id = pl.program_id(1)
    iq = sched_ref[p_id, 0]
    ik = sched_ref[p_id, 1]

    @pl.when(sched_ref[p_id, 2] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                        # (block_q, d)
    k = k_ref[0]                        # (block_k, d)
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    loc_k = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    qpos = offs_ref[0] + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = offs_ref[1] + loc_k
    mask = loc_k < kv_len
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    # p from the saved lse — the PSum re-stream.  The explicit mask guard
    # matters: a fully-masked row has lse == NEG_INF and exp(s - lse)
    # would resurrect masked entries as exp(0) = 1.
    p = jnp.where(mask, jnp.exp(s - lse_ref[0][:, None]), 0.0)

    do = do_ref[0].astype(jnp.float32)                 # (block_q, d)
    dp = jax.lax.dot_general(
        do, v_ref[0].astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (block_q, block_k)
    ds = p * (dp - delta_ref[0][:, None]) * scale
    acc_ref[...] += jax.lax.dot_general(
        ds, k.astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(sched_ref[p_id, 3] == 1)
    def _drain():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _fa_bwd_dkv_kernel(sched_ref, offs_ref, q_ref, k_ref, v_ref, do_ref,
                       lse_ref, delta_ref, dk_ref, dv_ref, dk_acc, dv_acc, *,
                       scale: float, causal: bool, window: int | None,
                       block_q: int, block_k: int, kv_len: int):
    p_id = pl.program_id(1)
    iq = sched_ref[p_id, 0]
    ik = sched_ref[p_id, 1]

    @pl.when(sched_ref[p_id, 2] == 1)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    G = q_ref.shape[1]
    q = q_ref[0].astype(jnp.float32)    # (G, block_q, d) — the whole group
    k = k_ref[0].astype(jnp.float32)    # (block_k, d)
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale    # (G, block_q, block_k)

    loc_k = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (G, block_q, block_k), 2)
    qpos = offs_ref[0] + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (G, block_q, block_k), 1)
    kpos = offs_ref[1] + loc_k
    mask = loc_k < kv_len
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    p = jnp.where(mask, jnp.exp(s - lse_ref[0][..., None]), 0.0)

    do = do_ref[0].astype(jnp.float32)                 # (G, block_q, d)
    # dv += sum over the group's q rows of p^T @ do  (the kv-stationary
    # PSum: one accumulator per k block, q streams)
    dv_acc[...] += jax.lax.dot_general(
        p, do, dimension_numbers=(((0, 1), (0, 1)), ((), ())),
        preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(
        do, v_ref[0].astype(jnp.float32),
        dimension_numbers=(((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (G, block_q, block_k)
    ds = p * (dp - delta_ref[0][..., None]) * scale
    dk_acc[...] += jax.lax.dot_general(
        ds, q, dimension_numbers=(((0, 1), (0, 1)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(sched_ref[p_id, 3] == 1)
    def _drain():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                               do: jax.Array, lse: jax.Array,
                               delta: jax.Array, *, causal: bool = True,
                               window: int | None = None, block_q: int = 128,
                               block_k: int = 128, scale: float | None = None,
                               kv_len: int | None = None,
                               q_len: int | None = None,
                               q_offset=0, k_offset=0, prune: bool = True,
                               interpret: bool = False
                               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Flash backward from the saved ``(lse, delta)`` residuals.

    q/do: (BH, Sq, D); k/v: (BHkv, Sk, D); lse/delta: f32 (BH, Sq) with
    ``delta = rowsum(do * o)``.  Returns (dq, dk, dv) in f32 (callers cast;
    the ring accumulates hops in f32).  Two kernels, two re-streams of the
    forward's schedule: dq holds q rows stationary against streaming
    k-blocks (row-ordered pair table), dk/dv hold k columns stationary
    against streaming q-blocks (column-ordered pair table, GQA group
    folded inside the tile so the kv accumulator sums its whole group)."""
    BH, Sq, Dh = q.shape
    BHkv, Sk, _ = k.shape
    assert BH % BHkv == 0, (BH, BHkv)
    group = BH // BHkv
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    kv_len = Sk if kv_len is None else kv_len
    q_len = Sq if q_len is None else q_len
    nq, nk = Sq // block_q, Sk // block_k
    # dense schedule unless offsets are statically zero (see fwd)
    zero_offs = (isinstance(q_offset, int) and isinstance(k_offset, int)
                 and q_offset == 0 and k_offset == 0)
    eff_causal = bool(causal) if (prune and zero_offs) else False
    eff_window = window if (prune and zero_offs) else None
    sched_row, _ = _pair_schedule(nq, nk, block_q, block_k, eff_causal,
                                  eff_window, kv_len, q_len, "row")
    sched_col, _ = _pair_schedule(nq, nk, block_q, block_k, eff_causal,
                                  eff_window, kv_len, q_len, "col")
    offs = _as_offs(q_offset, k_offset)
    f32 = jnp.float32

    kern_kw = dict(scale=scale, causal=causal, window=window,
                   block_q=block_q, block_k=block_k, kv_len=kv_len)

    dq_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=2,
        grid=(BH, sched_row.shape[0]),
        in_specs=[
            pl.BlockSpec((1, block_q, Dh),
                         lambda h, p, sr, orf: (h, sr[p, 0], 0)),
            pl.BlockSpec((1, block_k, Dh),
                         lambda h, p, sr, orf: (h // group, sr[p, 1], 0)),
            pl.BlockSpec((1, block_k, Dh),
                         lambda h, p, sr, orf: (h // group, sr[p, 1], 0)),
            pl.BlockSpec((1, block_q, Dh),
                         lambda h, p, sr, orf: (h, sr[p, 0], 0)),
            pl.BlockSpec((1, block_q), lambda h, p, sr, orf: (h, sr[p, 0])),
            pl.BlockSpec((1, block_q), lambda h, p, sr, orf: (h, sr[p, 0])),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dh),
                               lambda h, p, sr, orf: (h, sr[p, 0], 0)),
        scratch_shapes=[pltpu.VMEM((block_q, Dh), f32)],
    )
    dq = pl.pallas_call(
        functools.partial(_fa_bwd_dq_kernel, **kern_kw),
        grid_spec=dq_spec,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dh), f32),
        interpret=interpret,
    )(jnp.asarray(sched_row), offs, q, k, v, do, lse, delta)

    # group-major views so one kv grid step sees its whole GQA group
    qg = q.reshape(BHkv, group, Sq, Dh)
    dog = do.reshape(BHkv, group, Sq, Dh)
    lseg = lse.reshape(BHkv, group, Sq)
    deltag = delta.reshape(BHkv, group, Sq)
    dkv_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=2,
        grid=(BHkv, sched_col.shape[0]),
        in_specs=[
            pl.BlockSpec((1, group, block_q, Dh),
                         lambda h, p, sr, orf: (h, 0, sr[p, 0], 0)),
            pl.BlockSpec((1, block_k, Dh),
                         lambda h, p, sr, orf: (h, sr[p, 1], 0)),
            pl.BlockSpec((1, block_k, Dh),
                         lambda h, p, sr, orf: (h, sr[p, 1], 0)),
            pl.BlockSpec((1, group, block_q, Dh),
                         lambda h, p, sr, orf: (h, 0, sr[p, 0], 0)),
            pl.BlockSpec((1, group, block_q),
                         lambda h, p, sr, orf: (h, 0, sr[p, 0])),
            pl.BlockSpec((1, group, block_q),
                         lambda h, p, sr, orf: (h, 0, sr[p, 0])),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, Dh),
                         lambda h, p, sr, orf: (h, sr[p, 1], 0)),
            pl.BlockSpec((1, block_k, Dh),
                         lambda h, p, sr, orf: (h, sr[p, 1], 0)),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, Dh), f32),
                        pltpu.VMEM((block_k, Dh), f32)],
    )
    dk, dv = pl.pallas_call(
        functools.partial(_fa_bwd_dkv_kernel, **kern_kw),
        grid_spec=dkv_spec,
        out_shape=[jax.ShapeDtypeStruct((BHkv, Sk, Dh), f32),
                   jax.ShapeDtypeStruct((BHkv, Sk, Dh), f32)],
        interpret=interpret,
    )(jnp.asarray(sched_col), offs, qg, k, v, dog, lseg, deltag)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Trainable entry: fwd + bwd bound under one custom VJP
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlashSpec:
    """Static description of one trainable flash-attention call (hashable:
    it rides ``custom_vjp``'s nondiff_argnums)."""
    causal: bool
    window: int | None
    block_q: int
    block_k: int
    scale: float
    kv_len: int
    q_len: int
    prune: bool
    interpret: bool

    def kw(self) -> dict:
        return dict(causal=self.causal, window=self.window,
                    block_q=self.block_q, block_k=self.block_k,
                    scale=self.scale, kv_len=self.kv_len, q_len=self.q_len,
                    prune=self.prune, interpret=self.interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def flash_attention_train(spec: FlashSpec, q, k, v):
    """Differentiable fused flash attention: q (BH, Sq, D), k/v (BHkv, Sk,
    D).  Forward saves only (o, lse); backward is the two Pallas re-stream
    kernels above — the default trainable attention path on TPU."""
    o, _ = flash_attention_fwd_pallas(q, k, v, **spec.kw())
    return o


def _flash_train_fwd(spec: FlashSpec, q, k, v):
    o, lse = flash_attention_fwd_pallas(q, k, v, **spec.kw())
    return o, (q, k, v, o, lse)


def _flash_train_bwd(spec: FlashSpec, res, do):
    q, k, v, o, lse = res
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)
    dq, dk, dv = flash_attention_bwd_pallas(q, k, v, do, lse, delta,
                                            **spec.kw())
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_train.defvjp(_flash_train_fwd, _flash_train_bwd)


# ---------------------------------------------------------------------------
# Decode: one new token against a KV cache (the decode_* / long_* shapes).
# ---------------------------------------------------------------------------

def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_k: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (nq, d) — the group's q heads
    k = k_ref[0]                       # (block_k, d)
    s = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (nq, block_k)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < len_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(1) - 1)
    def _drain():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_decode_pallas(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                        lengths: jax.Array, *, block_k: int = 512,
                        scale: float | None = None,
                        interpret: bool = False) -> jax.Array:
    """q: (B*Hkv, group, D) one token per sequence, grouped by kv head;
    k_cache/v_cache: (B*Hkv, S, D); lengths: (B*Hkv,) valid cache lengths.
    Returns (B*Hkv, group, D).

    ``block_k`` is a ceiling, not a contract: when the cache length is not
    a multiple (short caches, odd bucket sizes), the block clamps to the
    pow2 floor of S and the cache pads to the next block multiple — padded
    positions sit at >= S >= lengths, so the length mask drops them."""
    BH, G, Dh = q.shape
    BH2, S, _ = k_cache.shape
    assert BH == BH2, (q.shape, k_cache.shape)
    if S % block_k != 0:
        block_k = min(block_k, pow2_floor(S))
        Sp = -(-S // block_k) * block_k
        pad = [(0, 0), (0, Sp - S), (0, 0)]
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
        S = Sp
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    grid = (BH, S // block_k)
    kern = functools.partial(_decode_kernel, scale=scale, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, Dh), lambda h, ik: (h, 0, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda h, ik: (h, ik, 0)),
            pl.BlockSpec((1, block_k, Dh), lambda h, ik: (h, ik, 0)),
            pl.BlockSpec((1,), lambda h, ik: (h,)),
        ],
        out_specs=pl.BlockSpec((1, G, Dh), lambda h, ik: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, lengths)
