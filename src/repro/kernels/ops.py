"""Public jit'd wrappers around the Pallas kernels.

Each wrapper (1) picks block shapes from the paper's tile search
(``core.pallas_bridge``), (2) pads inputs to block multiples, (3) dispatches
to the Pallas kernel — interpret mode on CPU (the container), compiled Mosaic
on TPU — and (4) slices the padding back off.  ``ref.py`` holds the oracles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.pallas_bridge import (attention_block_shapes,
                                      matmul_block_shapes, round_up)
from . import attention as _attention
from . import conv2d as _conv2d
from . import correlation as _correlation
from . import matmul as _matmul
from . import paged_attention as _paged_attention


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _record_dispatch(kernel: str, **args) -> None:
    """Telemetry hook for kernel dispatch decisions (impl chosen, block
    shapes, pruning ratio).  The wrappers are jitted, so this runs at
    TRACE time — once per compiled shape, zero steady-state overhead.
    Counters land in the global registry unconditionally (rare events);
    the trace instant fires only when telemetry is enabled."""
    from repro.obs import REGISTRY, get_telemetry
    REGISTRY.counter("kernel_dispatch", kernel=kernel,
                     impl=str(args.get("impl", "pallas")))
    if "pruning_ratio" in args:
        REGISTRY.gauge("kernel_pruning_ratio", args["pruning_ratio"],
                       kernel=kernel, sq=args.get("sq"), sk=args.get("sk"))
    t = get_telemetry()
    if t.enabled:
        t.instant("kernel_dispatch", cat="kernel", kernel=kernel, **args)


def _pad_to(x: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    pads = [(0, t - s) for s, t in zip(x.shape, shape)]
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul(a: jax.Array, b: jax.Array, *, block_m: int | None = None,
           block_n: int | None = None, block_k: int | None = None) -> jax.Array:
    """VectorMesh-tiled matmul: (M, K) @ (K, N) -> (M, N)."""
    M, K = a.shape
    _, N = b.shape
    if block_m is None or block_n is None or block_k is None:
        bm, bn, bk = matmul_block_shapes(max(M, 8), max(N, 128), max(K, 128))
        block_m = block_m or min(bm, 256)
        block_n = block_n or min(bn, 256)
        block_k = block_k or min(bk, 512)
    Mp, Np, Kp = (round_up(M, block_m), round_up(N, block_n),
                  round_up(K, block_k))
    _record_dispatch("matmul", M=M, N=N, K=K, block_m=block_m,
                     block_n=block_n, block_k=block_k)
    out = _matmul.matmul_pallas(
        _pad_to(a, (Mp, Kp)), _pad_to(b, (Kp, Np)),
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=_interpret())
    return out[:M, :N]


@functools.partial(jax.jit,
                   static_argnames=("stride", "dilation", "block_oh",
                                    "block_co"))
def conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1, dilation: int = 1,
           block_oh: int = 8, block_co: int = 128) -> jax.Array:
    """NHWC conv, VALID padding (pad x yourself for SAME)."""
    N, IH, IW, CI = x.shape
    KH, KW, _, CO = w.shape
    OH = (IH - (KH - 1) * dilation - 1) // stride + 1
    OW = (IW - (KW - 1) * dilation - 1) // stride + 1
    block_oh = min(block_oh, OH)
    block_co = min(block_co, CO)
    OHp = round_up(OH, block_oh)
    COp = round_up(CO, block_co)
    # pad input rows so the last halo block stays in bounds
    IHp = (OHp - 1) * stride + (KH - 1) * dilation + 1
    xp = _pad_to(x, (N, max(IH, IHp), IW, CI))
    wp = _pad_to(w, (KH, KW, CI, COp))
    _record_dispatch("conv2d", oh=OH, ow=OW, ci=CI, co=CO,
                     block_oh=block_oh, block_co=block_co)
    out = _conv2d.conv2d_pallas(xp, wp, stride=stride, dilation=dilation,
                                block_oh=block_oh, block_co=block_co,
                                interpret=_interpret())
    return out[:, :OH, :OW, :CO]


@functools.partial(jax.jit, static_argnames=("radius", "block_y"))
def correlation(i1: jax.Array, i2: jax.Array, *, radius: int,
                block_y: int = 8) -> jax.Array:
    """FlowNet correlation (Eq. 3): (H, W, C) x2 -> (H, W, D, D)."""
    H, W, C = i1.shape
    block_y = min(block_y, H)
    Hp = round_up(H, block_y)
    i1p = _pad_to(i1, (Hp, W, C))
    i2p = jnp.pad(i2, ((radius, radius + (Hp - H)), (radius, radius), (0, 0)))
    out = _correlation.correlation_pallas(
        i1p, i2p, radius=radius, block_y=block_y, interpret=_interpret())
    return out[:H]


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q", "block_k",
                                    "trainable", "prune"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int | None = None, block_k: int | None = None,
                    trainable: bool = True,
                    prune: bool = True) -> jax.Array:
    """q: (B, H, S, D), k/v: (B, Hkv, S, D) -> (B, H, S, D).

    The default path is the TRAINABLE fused kernel: forward saves only
    (o, lse) and the backward runs the Pallas dq / dkv re-stream kernels
    under a custom VJP (``trainable=False`` keeps the fwd-only kernel for
    oracle sweeps).  Block shapes come from the paper's tile search
    (``pallas_bridge.attention_block_shapes``, memoized per shape) unless
    pinned; fully-masked k-blocks are pruned from the grid schedule
    (``prune=False`` keeps the dense grid — the benchmark baseline)."""
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Sk, _ = k.shape
    if block_q is None or block_k is None:
        bq, bk = attention_block_shapes(Sq, Sk, Dh)
        block_q = block_q or bq
        block_k = block_k or bk
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    Sqp, Skp = round_up(Sq, block_q), round_up(Sk, block_k)
    qf = _pad_to(q, (B, Hq, Sqp, Dh)).reshape(B * Hq, Sqp, Dh)
    kf = _pad_to(k, (B, Hkv, Skp, Dh)).reshape(B * Hkv, Skp, Dh)
    vf = _pad_to(v, (B, Hkv, Skp, Dh)).reshape(B * Hkv, Skp, Dh)
    real, total = _attention.scheduled_block_counts(
        Sqp, Skp, block_q=block_q, block_k=block_k, causal=causal,
        window=window)
    if not prune:
        real = total                      # dense grid: nothing skipped
    _record_dispatch("flash_attention",
                     impl="train" if trainable else "fwd",
                     sq=Sq, sk=Sk, block_q=block_q, block_k=block_k,
                     scheduled_blocks=real, dense_blocks=total,
                     pruning_ratio=real / total if total else 1.0)
    if trainable:
        spec = _attention.FlashSpec(
            causal=causal, window=window, block_q=block_q, block_k=block_k,
            scale=1.0 / math.sqrt(Dh), kv_len=Sk, q_len=Sq, prune=prune,
            interpret=_interpret())
        out = _attention.flash_attention_train(spec, qf, kf, vf)
    else:
        out, _ = _attention.flash_attention_fwd_pallas(
            qf, kf, vf, causal=causal, window=window, block_q=block_q,
            block_k=block_k, kv_len=Sk, q_len=Sq, prune=prune,
            interpret=_interpret())
    return out.reshape(B, Hq, Sqp, Dh)[:, :, :Sq]


@functools.partial(jax.jit, static_argnames=("block_k",))
def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 lengths: jax.Array, *, block_k: int = 512) -> jax.Array:
    """q: (B, H, D) one token; caches: (B, Hkv, S, D); lengths: (B,).

    Returns (B, H, D)."""
    B, Hq, Dh = q.shape
    _, Hkv, S, _ = k_cache.shape
    G = Hq // Hkv
    block_k = min(block_k, S)
    Sp = round_up(S, block_k)
    qf = q.reshape(B, Hkv, G, Dh).reshape(B * Hkv, G, Dh)
    kf = _pad_to(k_cache, (B, Hkv, Sp, Dh)).reshape(B * Hkv, Sp, Dh)
    vf = _pad_to(v_cache, (B, Hkv, Sp, Dh)).reshape(B * Hkv, Sp, Dh)
    lens = jnp.repeat(lengths, Hkv).astype(jnp.int32)
    _record_dispatch("flash_decode", batch=B, s=S, block_k=block_k)
    out = _attention.flash_decode_pallas(
        qf, kf, vf, lens, block_k=block_k, interpret=_interpret())
    return out.reshape(B, Hkv, G, Dh).reshape(B, Hq, Dh)


@jax.jit
def paged_flash_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       page_table: jax.Array, lengths: jax.Array,
                       k_scale: jax.Array | None = None,
                       v_scale: jax.Array | None = None) -> jax.Array:
    """Paged decode: q (B, H, D) one token; pools (P, page, Hkv, D);
    page_table (B, max_pages) physical page ids; lengths (B,) valid tokens;
    optional int8-pool scales (P, page, Hkv).  Returns (B, H, D).

    Per-slot tables/lengths are replicated across kv heads so the kernel
    grid can stay flat (b, kv head); the pool transposes to kv-head-major
    so the page axis is the one the table indexes."""
    B, H, Dh = q.shape
    P, page_size, Hkv, _ = k_pages.shape
    G = H // Hkv
    qf = q.reshape(B, Hkv, G, Dh).reshape(B * Hkv, G, Dh)
    kt = k_pages.transpose(2, 0, 1, 3)        # (Hkv, P, page, D)
    vt = v_pages.transpose(2, 0, 1, 3)
    pt = jnp.repeat(page_table.astype(jnp.int32), Hkv, axis=0)
    lens = jnp.repeat(lengths.astype(jnp.int32), Hkv)
    ks = vs = None
    if k_scale is not None:
        ks = k_scale.transpose(2, 0, 1)       # (Hkv, P, page)
        vs = v_scale.transpose(2, 0, 1)
    _record_dispatch("paged_flash_decode",
                     impl="int8" if k_scale is not None else "pallas",
                     batch=B, pages=P, page_size=page_size,
                     max_pages=int(page_table.shape[1]))
    out = _paged_attention.paged_flash_decode_pallas(
        qf, kt, vt, pt, lens, ks, vs, page_size=page_size,
        interpret=_interpret())
    return out.reshape(B, Hkv, G, Dh).reshape(B, H, Dh)
