"""Spatial-matching / correlation Pallas kernel (paper Eq. 3, FlowNet [16]).

    C(dy, dx, y, x) = sum_c I1(c, y, x) * I2(c, y + dy, x + dx)

Layout here is channels-last: I1 (H, W, C), I2 pre-padded to
(H + 2R, W + 2R, C) by ops.py, output (H, W, D, D) with D = 2R + 1
(displacements enumerated in the last two axes, FlowNet cost-volume style).

The TEU tile is a block of `y` rows x all x x all channels; both displacement
axes are grid dims whose I1 index map is INVARIANT (zero partial derivative,
paper Fig. 2), so the I1 block is fetched once and shared across all (dy, dx)
tiles — the data-exchange mesh again. I2's halo block is Element-indexed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.runtime import compat


def _corr_kernel(i1_ref, i2_ref, o_ref):
    # i1_ref: (by, W, C); i2_ref: (by, W, C) — the window shifted by (dy, dx)
    # o_ref: (by, W, 1, 1)
    prod = i1_ref[...].astype(jnp.float32) * i2_ref[...].astype(jnp.float32)
    o_ref[...] = prod.sum(axis=-1)[..., None, None].astype(o_ref.dtype)


def correlation_pallas(i1: jax.Array, i2_padded: jax.Array, *, radius: int,
                       block_y: int = 8, interpret: bool = False) -> jax.Array:
    """i1: (H, W, C); i2_padded: (H+2R, W+2R, C) -> (H, W, D, D), D = 2R+1."""
    H, W, C = i1.shape
    D = 2 * radius + 1
    assert i2_padded.shape == (H + 2 * radius, W + 2 * radius, C)
    assert H % block_y == 0, (H, block_y)
    grid = (H // block_y, D, D)

    return pl.pallas_call(
        _corr_kernel,
        grid=grid,
        in_specs=[
            # I1 invariant to (dy, dx): fetched once per y-block, shared
            # across all D*D displacement steps (FIFO-mesh analogue).
            pl.BlockSpec((block_y, W, C), lambda y, dy, dx: (y, 0, 0)),
            # I2 window at displacement (dy, dx) — element-indexed halo.
            compat.element_block_spec(
                (compat.Element(block_y), compat.Element(W), C),
                lambda y, dy, dx: (y * block_y + dy, dx, 0)),
        ],
        out_specs=pl.BlockSpec((block_y, W, 1, 1),
                               lambda y, dy, dx: (y, 0, dy, dx)),
        out_shape=jax.ShapeDtypeStruct((H, W, D, D), i1.dtype),
        interpret=interpret,
    )(i1, i2_padded)
