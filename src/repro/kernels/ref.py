"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(out_dtype)


def conv2d_ref(x: jax.Array, w: jax.Array, *, stride: int = 1,
               dilation: int = 1) -> jax.Array:
    """x: (N, IH, IW, CI), w: (KH, KW, CI, CO) -> NHWC, VALID padding."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out.astype(x.dtype)


def correlation_ref(i1: jax.Array, i2: jax.Array, *, radius: int) -> jax.Array:
    """i1, i2: (H, W, C) -> (H, W, D, D) cost volume, D = 2*radius+1."""
    H, W, C = i1.shape
    D = 2 * radius + 1
    i2p = jnp.pad(i2, ((radius, radius), (radius, radius), (0, 0)))
    rows = []
    for dy in range(D):
        cols = []
        for dx in range(D):
            win = jax.lax.dynamic_slice(i2p, (dy, dx, 0), (H, W, C))
            cols.append((i1.astype(jnp.float32) *
                         win.astype(jnp.float32)).sum(-1))
        rows.append(jnp.stack(cols, axis=-1))
    return jnp.stack(rows, axis=-2).astype(i1.dtype)  # (H, W, D(dy), D(dx))


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BHkv, Sk, D); GQA by head grouping."""
    BH, Sq, Dh = q.shape
    BHkv, Sk, _ = k.shape
    group = BH // BHkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("hqk,hkd->hqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def paged_decode_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     page_table: jax.Array, lengths: jax.Array,
                     k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None, *,
                     scale: float | None = None) -> jax.Array:
    """q: (B, H, D); k_pages/v_pages: (P, page, Hkv, D) global pools;
    page_table: (B, max_pages); lengths: (B,); scales (int8 pools):
    (P, page, Hkv) f32.  Gathers each slot's pages into a contiguous cache
    then runs the dense decode oracle — the allclose target for
    ``paged_attention.paged_flash_decode_pallas``."""
    B, H, Dh = q.shape
    P, page, Hkv, _ = k_pages.shape
    G = H // Hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(Dh)
    S = page_table.shape[1] * page

    def gather(pages, scales):
        x = pages[page_table]                      # (B, MP, page, Hkv, D)
        x = x.astype(jnp.float32)
        if scales is not None:
            x = x * scales[page_table][..., None]
        return x.reshape(B, S, Hkv, Dh)

    k = gather(k_pages, k_scale)
    v = gather(v_pages, v_scale)
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k) * sc
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return o.reshape(B, H, Dh).astype(q.dtype)


def decode_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
               lengths: jax.Array, *, scale: float | None = None) -> jax.Array:
    """q: (BHkv, G, D); caches (BHkv, S, D); lengths (BHkv,) -> (BHkv, G, D)."""
    BH, G, Dh = q.shape
    S = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    s = jnp.einsum("hgd,hsd->hgs", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("hgs,hsd->hgd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)
