"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(out_dtype)


def conv2d_ref(x: jax.Array, w: jax.Array, *, stride: int = 1,
               dilation: int = 1) -> jax.Array:
    """x: (N, IH, IW, CI), w: (KH, KW, CI, CO) -> NHWC, VALID padding."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out.astype(x.dtype)


def correlation_ref(i1: jax.Array, i2: jax.Array, *, radius: int) -> jax.Array:
    """i1, i2: (H, W, C) -> (H, W, D, D) cost volume, D = 2*radius+1."""
    H, W, C = i1.shape
    D = 2 * radius + 1
    i2p = jnp.pad(i2, ((radius, radius), (radius, radius), (0, 0)))
    rows = []
    for dy in range(D):
        cols = []
        for dx in range(D):
            win = jax.lax.dynamic_slice(i2p, (dy, dx, 0), (H, W, C))
            cols.append((i1.astype(jnp.float32) *
                         win.astype(jnp.float32)).sum(-1))
        rows.append(jnp.stack(cols, axis=-1))
    return jnp.stack(rows, axis=-2).astype(i1.dtype)  # (H, W, D(dy), D(dx))


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BHkv, Sk, D); GQA by head grouping."""
    BH, Sq, Dh = q.shape
    BHkv, Sk, _ = k.shape
    group = BH // BHkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("hqk,hkd->hqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def decode_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
               lengths: jax.Array, *, scale: float | None = None) -> jax.Array:
    """q: (BHkv, G, D); caches (BHkv, S, D); lengths (BHkv,) -> (BHkv, G, D)."""
    BH, G, Dh = q.shape
    S = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(Dh)
    s = jnp.einsum("hgd,hsd->hgs", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("hgs,hsd->hgd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)
