"""VectorMesh-tiled matmul Pallas kernel (the TEU, §II-B/C, on the MXU).

Output-stationary: the f32 accumulator (the "PSum buffer") stays in VMEM
while the temporal index k streams through it — grid order (i, j, k) with k
innermost, exactly the schedule ``core.exchange.order_grid_for_sharing``
produces for Eq. (1). Block shapes come from the paper's bandwidth-
minimizing tile search (``core.pallas_bridge.plan_kernel``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.runtime import compat


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref):
    # k == 0: reset the PSum buffer (paper: PSums stay static in the TEU).
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    # last k: drain the PSum exactly once (optimal output bandwidth, §II-B).
    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _drain():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, *, block_m: int, block_n: int,
                  block_k: int, out_dtype=None,
                  interpret: bool = False) -> jax.Array:
    """a: (M, K) @ b: (K, N) -> (M, N); dims must be multiples of the blocks."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        (M, N, K), (block_m, block_n, block_k))
    out_dtype = out_dtype or a.dtype
    grid = (M // block_m, N // block_n, K // block_k)

    kwargs = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(a, b)
