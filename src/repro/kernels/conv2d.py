"""Direct 2D convolution Pallas kernel (paper Eq. 2, incl. stride/dilation).

NHWC x HWIO -> NHWC. The TEU tile maps to (a block of output rows) x (all
columns) x (a block of output channels); the overlapping input window — the
operand the FIFO mesh shares between neighbouring tiles in Fig. 2 — is
expressed with an element-indexed halo block (``compat.element_block_spec``,
``pl.Element`` on new JAX / ``pl.Unblocked`` on 0.4.x), and is REUSED across all
co-blocks because the grid order puts `co` innermost of the parallel dims
(the block's index map is invariant to `co`, so Mosaic keeps it VMEM-resident
— the intra-chip analogue of sharing E between P and Q). The reduction
(ci, kh, kw) runs inside the kernel body (temporal indices of Eq. 2), keeping
the f32 PSum block stationary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.runtime import compat


def _conv_kernel(x_ref, w_ref, o_ref, *, stride: int, dilation: int,
                 kh: int, kw: int):
    # x_ref: (1, ih_blk, iw_pad, ci)  w_ref: (kh, kw, ci, bco)
    # o_ref: (1, block_oh, ow, bco)
    x = x_ref[0]
    block_oh, ow = o_ref.shape[1], o_ref.shape[2]
    acc = jnp.zeros(o_ref.shape[1:], jnp.float32)
    for m in range(kh):
        for n in range(kw):
            win = jax.lax.slice(
                x,
                (m * dilation, n * dilation, 0),
                (m * dilation + (block_oh - 1) * stride + 1,
                 n * dilation + (ow - 1) * stride + 1,
                 x.shape[2]),
                (stride, stride, 1),
            )  # (block_oh, ow, ci)
            acc += jax.lax.dot_general(
                win, w_ref[m, n],
                dimension_numbers=(((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    o_ref[0] = acc.astype(o_ref.dtype)


def conv2d_pallas(x: jax.Array, w: jax.Array, *, stride: int = 1,
                  dilation: int = 1, block_oh: int = 8, block_co: int = 128,
                  interpret: bool = False) -> jax.Array:
    """x: (N, IH, IW, CI), w: (KH, KW, CI, CO) -> (N, OH, OW, CO). VALID pad.

    OH must be a multiple of block_oh and CO of block_co (ops.py pads).
    """
    N, IH, IW, CI = x.shape
    KH, KW, CI2, CO = w.shape
    assert CI == CI2, (x.shape, w.shape)
    OH = (IH - (KH - 1) * dilation - 1) // stride + 1
    OW = (IW - (KW - 1) * dilation - 1) // stride + 1
    assert OH % block_oh == 0, (OH, block_oh)
    assert CO % block_co == 0, (CO, block_co)

    # halo window of input rows feeding one block of output rows
    ih_blk = (block_oh - 1) * stride + (KH - 1) * dilation + 1
    grid = (N, OH // block_oh, CO // block_co)
    kern = functools.partial(_conv_kernel, stride=stride, dilation=dilation,
                             kh=KH, kw=KW)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            # Element-indexed rows: overlapping halo blocks; invariant to c.
            compat.element_block_spec(
                (1, compat.Element(ih_blk), IW, CI),
                lambda n, y, c: (n, y * block_oh * stride, 0, 0)),
            pl.BlockSpec((KH, KW, CI, block_co), lambda n, y, c: (0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, block_oh, OW, block_co),
                               lambda n, y, c: (n, y, 0, c)),
        out_shape=jax.ShapeDtypeStruct((N, OH, OW, CO), x.dtype),
        interpret=interpret,
    )(x, w)
