"""Metrics registry: labeled counters / gauges / histograms, jax-free.

One process-wide :data:`REGISTRY` absorbs the repo's scattered ad-hoc
stats (serving outcome counters, ``BlockPoolKV`` alloc/evict counts,
autotune cache hits, GradGuard skip/rollback events, chaos fired events,
checkpoint save/restore/CRC timings) into a single snapshot-to-dict
surface.  Components PUSH events as they happen (counters/histograms) and
the snapshot layer PULLS point-in-time component stats into gauges (e.g.
``engine.telemetry()`` mirrors pool utilization and prefix hit rate), so
nothing in a hot loop ever formats a string or touches jax.

Design constraints, in order:

* **jax-free + import-light** — imported by host-side control modules
  (``serving.kv``, ``core.autotune``, ``checkpoint.manager``) that must
  stay property-testable in microseconds;
* **thread-safe** — the checkpoint manager records save timings from its
  background writer thread while the train loop records step events; one
  lock around dict updates, never held during user code;
* **deterministic snapshots** — metric keys are sorted and label values
  are rendered canonically, so two runs that perform the same work
  produce byte-identical ``snapshot()["counters"]`` (the chaos
  virtual-clock replay test depends on this).

Series identity is ``(name, ((label, value), ...))`` with labels sorted;
the snapshot renders it as the Prometheus-style string
``name{k=v,k2=v2}`` (bare ``name`` with no labels).
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Any, Iterable, Mapping

_QUANTILES = (0.5, 0.9, 0.99)


def _series_key(name: str, labels: Mapping[str, Any]) -> tuple:
    return (name, tuple(sorted((k, str(v)) for k, v in labels.items())))


def _render(key: tuple) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class _Hist:
    """Streaming histogram: count/sum/min/max plus a bounded reservoir of
    recent observations for approximate quantiles (exact until ``cap``)."""

    __slots__ = ("count", "total", "vmin", "vmax", "samples", "cap", "_i")

    def __init__(self, cap: int = 512):
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self.samples: list[float] = []
        self.cap = cap
        self._i = 0

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        if len(self.samples) < self.cap:
            self.samples.append(v)
        else:                       # ring overwrite: keep the newest window
            self.samples[self._i % self.cap] = v
            self._i += 1

    def summary(self) -> dict:
        out = {"count": self.count, "sum": self.total,
               "min": self.vmin if self.count else 0.0,
               "max": self.vmax if self.count else 0.0,
               "mean": self.total / self.count if self.count else 0.0}
        if self.samples:
            s = sorted(self.samples)
            for q in _QUANTILES:
                out[f"p{int(q * 100)}"] = s[
                    min(len(s) - 1, int(q * len(s)))]
        return out


class MetricsRegistry:
    """Thread-safe labeled counters / gauges / histograms.

    >>> m = MetricsRegistry()
    >>> m.counter("serve_tokens", 8, mode="paged")
    >>> m.gauge("kv_utilization", 0.83)
    >>> m.observe("ckpt_save_s", 0.12)
    >>> m.snapshot()["counters"]["serve_tokens{mode=paged}"]
    8
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[tuple, float] = {}
        self._gauges: dict[tuple, float] = {}
        self._hists: dict[tuple, _Hist] = {}

    # -- write side ---------------------------------------------------------

    def counter(self, name: str, value: float = 1, **labels) -> None:
        """Add ``value`` (monotone; negative increments are a caller bug
        but not policed — snapshots stay truthful to what was recorded)."""
        key = _series_key(name, labels)
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + value

    def gauge(self, name: str, value: float, **labels) -> None:
        """Set a point-in-time value (last write wins)."""
        key = _series_key(name, labels)
        with self._lock:
            self._gauges[key] = value

    def observe(self, name: str, value: float, **labels) -> None:
        """Record one histogram observation."""
        key = _series_key(name, labels)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = _Hist()
            h.observe(value)

    @contextlib.contextmanager
    def timer(self, name: str, **labels):
        """Time a ``with`` block into histogram ``name`` (seconds) — the
        fleet wraps page migrations and host-loss recovery in these."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.observe(name, time.perf_counter() - t0, **labels)

    def absorb(self, stats: Mapping[str, Any], *, prefix: str = "",
               **labels) -> None:
        """Mirror a component's ad-hoc stats dict as gauges (the pull
        half: ``engine.telemetry()`` feeds kv/prefix/outcome stats here).
        Non-numeric values are skipped; nested dicts are flattened with
        ``.`` separators."""
        flat: list[tuple[str, float]] = []

        def walk(d: Mapping[str, Any], base: str) -> None:
            for k, v in d.items():
                if isinstance(v, Mapping):
                    walk(v, f"{base}{k}.")
                elif isinstance(v, bool):
                    flat.append((f"{base}{k}", float(v)))
                elif isinstance(v, (int, float)):
                    flat.append((f"{base}{k}", float(v)))

        walk(stats, prefix)
        for k, v in flat:
            self.gauge(k, v, **labels)

    # -- read side ----------------------------------------------------------

    def get_counter(self, name: str, **labels) -> float:
        with self._lock:
            return self._counters.get(_series_key(name, labels), 0)

    def snapshot(self) -> dict:
        """Deterministically-ordered dict of every series.

        ``counters`` holds only deliberately-recorded monotone event
        counts — the section replay-determinism tests compare; ``gauges``
        and ``histograms`` may carry wall-clock-derived values."""
        with self._lock:
            counters = {_render(k): v
                        for k, v in sorted(self._counters.items())}
            gauges = {_render(k): v
                      for k, v in sorted(self._gauges.items())}
            hists = {_render(k): h.summary()
                     for k, h in sorted(self._hists.items())}
        return {"counters": counters, "gauges": gauges,
                "histograms": hists}

    def reset(self, names: Iterable[str] | None = None) -> None:
        """Drop every series, or only those whose NAME is in ``names``."""
        with self._lock:
            if names is None:
                self._counters.clear()
                self._gauges.clear()
                self._hists.clear()
                return
            keep = lambda k: k[0] not in names  # noqa: E731
            self._counters = {k: v for k, v in self._counters.items()
                              if keep(k)}
            self._gauges = {k: v for k, v in self._gauges.items()
                            if keep(k)}
            self._hists = {k: v for k, v in self._hists.items() if keep(k)}


# The process-wide default.  Always live (recording a counter is a dict
# add under a lock — cheap enough for the rare events pushed here: kernel
# trace-time dispatches, checkpoint saves, GradGuard actions, autotune
# cache misses).  Hot-loop per-tick recording is additionally gated on
# ``Telemetry.enabled`` by the components that do it.
REGISTRY = MetricsRegistry()
