"""Unified telemetry: metrics registry + span tracer + traffic accounting.

Three layers (see docs/ARCHITECTURE.md "Observability"):

1. :mod:`repro.obs.metrics` — labeled counters/gauges/histograms with one
   process-wide :data:`~repro.obs.metrics.REGISTRY` that absorbs the
   repo's scattered ad-hoc stats;
2. :mod:`repro.obs.trace` — nested span tracer (virtual-clock compatible)
   with a Chrome trace-event JSON exporter perfetto can load;
3. :mod:`repro.obs.roofline_live` — observed-vs-predicted traffic rows
   that close the loop on the paper's fetch-reduction claims at runtime.

The :class:`Telemetry` facade bundles a tracer with the registry and a
single ``enabled`` switch.  The GLOBAL default is DISABLED: hot paths
(the serving tick loop) check ``telemetry.enabled`` once and skip every
span/counter, so an untelemetered serve pays only a handful of attribute
reads per tick (< 2% tick time — asserted by the smoke benchmark).
``obs.enable()`` flips the global on (the launchers do this when
``--trace-out``/``--metrics-out`` is passed); components that cannot be
handed a Telemetry explicitly (kernel wrappers, checkpoint manager) reach
it through :func:`get_telemetry`.

The package is deliberately jax-free so the host-side control modules
that import it stay jax-free too.
"""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager
from typing import Any, Callable

from .metrics import REGISTRY, MetricsRegistry
from .trace import SpanTracer


@contextmanager
def _noop_span(*_a, **_kw):
    yield None


class Telemetry:
    """A tracer + the metrics registry behind one enabled/disabled switch.

    ``span``/``instant`` delegate to the tracer when enabled and are
    no-ops otherwise; ``metrics`` is always the (cheap, ever-live)
    registry — components use ``telemetry.enabled`` to gate per-tick
    hot-loop recording and push rare events unconditionally.
    """

    def __init__(self, *, enabled: bool = True,
                 registry: MetricsRegistry | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 process_name: str = "repro"):
        self.enabled = enabled
        self.metrics = registry if registry is not None else REGISTRY
        self.tracer = SpanTracer(clock, process_name=process_name)

    # -- recording (gated) --------------------------------------------------

    def span(self, name: str, cat: str = "span", **args):
        if not self.enabled:
            return _noop_span()
        return self.tracer.span(name, cat, **args)

    def begin(self, name: str, cat: str = "span", **args):
        return self.tracer.begin(name, cat, **args) if self.enabled else None

    def finish(self, handle, **extra) -> None:
        if handle is not None:
            self.tracer.finish(handle, **extra)

    def instant(self, name: str, cat: str = "event", **args) -> None:
        if self.enabled:
            self.tracer.instant(name, cat, **args)

    def counter(self, name: str, value: float = 1, **labels) -> None:
        if self.enabled:
            self.metrics.counter(name, value, **labels)

    # -- artifacts ----------------------------------------------------------

    def snapshot(self) -> dict:
        return self.metrics.snapshot()

    def write_trace(self, path: str) -> str:
        return self.tracer.write_chrome_trace(path)

    def write_metrics(self, path: str, extra: dict[str, Any] | None = None
                      ) -> str:
        """Write ``snapshot()`` (plus optional caller context) as JSON."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        snap = self.snapshot()
        if extra:
            snap = {**snap, **extra}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True, default=str)
        os.replace(tmp, path)
        return path


_DISABLED = Telemetry(enabled=False)
_default: Telemetry = _DISABLED


def get_telemetry() -> Telemetry:
    """The process-global telemetry (disabled until :func:`enable`)."""
    return _default


def set_telemetry(t: Telemetry | None) -> Telemetry:
    """Install ``t`` as the global (None restores the disabled default);
    returns the previous one so scopes can put it back."""
    global _default
    prev = _default
    _default = t if t is not None else _DISABLED
    return prev


def enable(*, clock: Callable[[], float] = time.monotonic,
           process_name: str = "repro") -> Telemetry:
    """Install and return a fresh ENABLED global telemetry."""
    t = Telemetry(enabled=True, clock=clock, process_name=process_name)
    set_telemetry(t)
    return t


__all__ = ["REGISTRY", "MetricsRegistry", "SpanTracer", "Telemetry",
           "enable", "get_telemetry", "set_telemetry"]
