"""Span tracer with a Chrome trace-event JSON exporter (perfetto-loadable).

A :class:`SpanTracer` records NESTED spans (context-manager, decorator, or
explicit ``begin``/``finish`` for non-lexical scopes like the train loop's
RUN segments) plus INSTANT events, on per-thread stacks so concurrent
threads (the serving tick loop vs the checkpoint writer) interleave
without corrupting each other's nesting.

The clock is injectable: ``SpanTracer(clock=lambda: vclock[0])`` lets the
train loop trace on its per-step VIRTUAL clock, so a chaos scenario
replays with bit-identical timestamps (the determinism tests compare
exported traces across replays).  The default is ``time.monotonic``.
Clocks return SECONDS; the exporter converts to the trace-event format's
microseconds.

Export follows the Chrome trace-event format that perfetto/chrome://tracing
load: a top-level ``{"traceEvents": [...]}`` object whose events carry the
required ``name``/``ph``/``ts``/``pid``/``tid`` fields — ``"X"`` complete
events additionally carry ``dur``, ``"i"`` instants carry scope ``"s":
"t"``, and per-thread ``"M"`` metadata events name the threads.  Span
``args`` pass straight through to the event's ``args`` (perfetto shows
them in the selection panel).
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Callable


class _SpanHandle:
    """An open span (returned by :meth:`SpanTracer.begin`)."""

    __slots__ = ("name", "cat", "t0", "tid", "args", "closed")

    def __init__(self, name: str, cat: str, t0: float, tid: int,
                 args: dict):
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.tid = tid
        self.args = args
        self.closed = False


class SpanTracer:
    """Collects events; thread-safe; bounded (oldest events drop once
    ``max_events`` is hit, so a long-lived engine cannot leak without
    bound — the counter ``dropped`` says how many were lost)."""

    def __init__(self, clock: Callable[[], float] = time.monotonic, *,
                 process_name: str = "repro", max_events: int = 200_000):
        self.clock = clock
        self.process_name = process_name
        self.max_events = max_events
        self.dropped = 0
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._local = threading.local()      # per-thread span stack
        self._tids: dict[int, str] = {}      # tid -> thread name

    # -- internals ----------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _tid(self) -> int:
        t = threading.current_thread()
        tid = t.ident or 0
        if tid not in self._tids:
            with self._lock:
                self._tids[tid] = t.name
        return tid

    def _emit(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) >= self.max_events:
                self._events.pop(0)
                self.dropped += 1
            self._events.append(ev)

    # -- recording ----------------------------------------------------------

    def begin(self, name: str, cat: str = "span", **args) -> _SpanHandle:
        """Open a span NOW; close it with :meth:`finish`.  For scopes that
        don't nest lexically (the train loop's RUN segment ends wherever
        the next fault begins)."""
        h = _SpanHandle(name, cat, self.clock(), self._tid(), args)
        self._stack().append(h)
        return h

    def finish(self, handle: _SpanHandle, **extra_args) -> None:
        """Close an open span (idempotent).  Also force-closes any spans
        opened above it on this thread's stack that were left open —
        nesting in the export stays well-formed even on early exits."""
        if handle.closed:
            return
        stack = self._stack()
        while stack:
            h = stack.pop()
            h.closed = True
            t1 = self.clock()
            args = {**h.args, **(extra_args if h is handle else {})}
            self._emit({"name": h.name, "cat": h.cat, "ph": "X",
                        "ts": h.t0, "dur": max(0.0, t1 - h.t0),
                        "tid": h.tid, "args": args})
            if h is handle:
                return
        # handle was not on this thread's stack (crossed threads): still
        # record it so the span is not silently lost
        handle.closed = True
        self._emit({"name": handle.name, "cat": handle.cat, "ph": "X",
                    "ts": handle.t0,
                    "dur": max(0.0, self.clock() - handle.t0),
                    "tid": handle.tid,
                    "args": {**handle.args, **extra_args}})

    @contextmanager
    def span(self, name: str, cat: str = "span", **args):
        h = self.begin(name, cat, **args)
        try:
            yield h
        finally:
            self.finish(h)

    def instant(self, name: str, cat: str = "event", **args) -> None:
        """A zero-duration marker (chaos faults, request completions)."""
        self._emit({"name": name, "cat": cat, "ph": "i",
                    "ts": self.clock(), "tid": self._tid(), "s": "t",
                    "args": args})

    def trace(self, name: str | None = None, cat: str = "span"):
        """Decorator form: ``@tracer.trace()`` wraps the call in a span
        named after the function."""

        def deco(fn):
            span_name = name or fn.__qualname__

            @functools.wraps(fn)
            def wrapped(*a, **kw):
                with self.span(span_name, cat):
                    return fn(*a, **kw)

            return wrapped

        return deco

    # -- introspection (tests) ----------------------------------------------

    def spans(self, name: str | None = None) -> list[dict]:
        """Completed span events (optionally filtered by name), in
        completion order, timestamps still in clock seconds."""
        with self._lock:
            evs = [e for e in self._events if e["ph"] == "X"]
        return [e for e in evs if name is None or e["name"] == name]

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    # -- export -------------------------------------------------------------

    def chrome_trace(self) -> dict:
        """``{"traceEvents": [...]}`` in the Chrome trace-event JSON
        format (ts/dur in microseconds; pid/tid integral; "M" metadata
        events naming the process and threads)."""
        pid = os.getpid()
        out: list[dict] = [{
            "name": "process_name", "ph": "M", "ts": 0, "pid": pid,
            "tid": 0, "args": {"name": self.process_name}}]
        with self._lock:
            events = list(self._events)
            tids = dict(self._tids)
        for tid, tname in sorted(tids.items()):
            out.append({"name": "thread_name", "ph": "M", "ts": 0,
                        "pid": pid, "tid": tid, "args": {"name": tname}})
        for e in events:
            ev = {"name": e["name"], "cat": e.get("cat", "span"),
                  "ph": e["ph"], "ts": e["ts"] * 1e6, "pid": pid,
                  "tid": e["tid"], "args": e.get("args", {})}
            if e["ph"] == "X":
                ev["dur"] = e["dur"] * 1e6
            if e["ph"] == "i":
                ev["s"] = e.get("s", "t")
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> str:
        """Serialize to ``path`` (atomic tmp+rename); returns the path."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.chrome_trace(), f)
        os.replace(tmp, path)
        return path
