"""Live roofline/traffic accountant: observed vs predicted bytes + FLOPs.

The paper's headline numbers (2-22x fewer global-buffer fetches, up to 5x
fewer DRAM fetches) are PREDICTIONS from ``analysis/roofline.py``, the
tile-search engine and ``sim/``.  This module closes the loop at runtime:
it derives OBSERVED bytes-moved and FLOPs from what the live system
actually did — the serving engine's per-tick KV-traffic counters, the
prefix-cache/page-pool stats, and XLA's cost analysis of compiled
programs — and lines them up against the analytic prediction as
``observed vs predicted`` rows with a documented tolerance.  A regression
that silently changes the traffic a subsystem generates (scheduler
chunking, COW explosion, a kernel reading the padded page view) breaks
the tolerance instead of hiding in a wall-time.

Two traffic LEVELS mirror the paper's memory hierarchy:

``gb``    (global buffer) — token-exact bytes the COMPUTE consumed:
          per decode/prefill row, the attended context length x the
          per-token KV byte cost.  Predicted and observed use independent
          derivations (a closed-form sum over the request trace vs the
          engine's per-tick accumulation), so equality is an invariant
          of the scheduler/engine bookkeeping, not a tautology.
``dram``  — page-granular bytes the POOL served: the kernel streams whole
          pages, so observed reads round each context up to its page
          boundary.  observed/predicted(gb) quantifies the paging
          overhead and is bounded by ``1 + page_size / min_context``.

For compiled workloads (conv2d here; the dryrun sweep generally) the
observed side is XLA's ``cost_analysis`` of the compiled executable and
the predicted side is the analytic floor (exact MACs, operand+output
bytes) plus the paper scheduler's global-buffer fetch plan.

jax is imported lazily — the serving-side accounting stays jax-free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

LEVELS = ("gb", "dram")

# Documented tolerances (ratio bands, observed / predicted) asserted by
# tests/test_obs.py and reported by ``TrafficRow.row()``:
#   * gb   — the two derivations must agree to float rounding; the band
#            allows scheduler-edge slack (budget-split chunks).
#   * dram — page-granularity overhead: every context rounds up to a page
#            boundary, so observed >= predicted(gb) but bounded by one
#            page per row read.
#   * hlo_flops — XLA counts the same MACs the NDRange does (2 flops per
#            MAC); fusion bookkeeping may add epsilon.
#   * hlo_bytes — XLA's "bytes accessed" counts each operand per use, so
#            a fused conv sits above the touch-once floor but within a
#            small factor of it on a single-op program.
TOLERANCES = {"gb": 1.02, "dram": 1.75, "hlo_flops": 1.25, "hlo_bytes": 4.0}


@dataclasses.dataclass(frozen=True)
class TrafficRow:
    """One observed-vs-predicted comparison."""
    workload: str                  # e.g. "paged_decode", "conv2d"
    level: str                     # "gb" | "dram" | "hlo_flops" | ...
    observed: float
    predicted: float
    unit: str = "bytes"
    tolerance: float = 0.0         # ratio band; 0 -> TOLERANCES[level]
    bound: bool = False            # one-sided: only observed <= pred * tol

    @property
    def ratio(self) -> float:
        return self.observed / self.predicted if self.predicted else \
            float("inf")

    @property
    def tol(self) -> float:
        return self.tolerance or TOLERANCES.get(self.level, 1.5)

    @property
    def within(self) -> bool:
        if self.predicted <= 0:
            return False
        if self.bound:
            return self.ratio <= self.tol
        return 1.0 / self.tol <= self.ratio <= self.tol

    def row(self) -> dict:
        return {"workload": self.workload, "level": self.level,
                "observed": self.observed, "predicted": self.predicted,
                "unit": self.unit, "ratio": round(self.ratio, 4),
                "tolerance": self.tol, "within": self.within}


# ---------------------------------------------------------------------------
# Paged-decode serving traffic
# ---------------------------------------------------------------------------

def predict_paged_decode_traffic(
        prompt_lens: Sequence[int], max_new: int, *, page_size: int,
        page_bytes: int, prefill_chunk: int,
        matched: Sequence[int] | None = None) -> dict[str, float]:
    """Closed-form KV traffic for serving ``prompt_lens`` to completion.

    Mirrors the engine's tick accounting from the OUTSIDE: each prefill
    chunk attends over the context cached so far, each decode tick writes
    the previous token and attends over the grown context, and the final
    sampled token is never written back.  ``matched`` gives per-request
    prefix-cache hits (tokens served for free; default all-cold).

    Assumes chunks are never split by the per-tick token budget (size the
    engine's ``prefill_token_budget`` >= ``prefill_chunk`` x concurrent
    prefills when comparing against this) and greedy decode runs the full
    ``max_new`` (``eos_id = -1``).
    """
    bpt = page_bytes / page_size          # per-token KV bytes (K+V+scales)
    gb_tokens = 0                         # token-exact attended context
    dram_tokens = 0                       # page-granular pool reads
    written = 0
    for j, prompt_len in enumerate(prompt_lens):
        start = matched[j] if matched is not None else 0
        pos = start
        while pos < prompt_len:
            pos = min(prompt_len, pos + prefill_chunk)
            gb_tokens += pos
            dram_tokens += -(-pos // page_size) * page_size
        for i in range(1, max_new):
            ctx = prompt_len + i
            gb_tokens += ctx
            dram_tokens += -(-ctx // page_size) * page_size
        written += (prompt_len - start) + (max_new - 1)
    return {
        "gb_read_bytes": gb_tokens * bpt,
        "dram_read_bytes": dram_tokens * bpt,
        "written_bytes": written * bpt,
        "gb_read_tokens": gb_tokens,
        "dram_read_tokens": dram_tokens,
        "written_tokens": written,
    }


def paged_decode_rows(observed: Mapping[str, float],
                      predicted: Mapping[str, float]) -> list[TrafficRow]:
    """Line the engine's observed traffic (``engine.telemetry()
    ["traffic"]``) up against :func:`predict_paged_decode_traffic`."""
    return [
        TrafficRow("paged_decode", "gb", observed["gb_read_bytes"],
                   predicted["gb_read_bytes"]),
        TrafficRow("paged_decode", "dram", observed["dram_read_bytes"],
                   predicted["dram_read_bytes"]),
        TrafficRow("paged_decode", "gb", observed["written_bytes"],
                   predicted["written_bytes"], unit="bytes_written",
                   tolerance=TOLERANCES["gb"]),
    ]


# ---------------------------------------------------------------------------
# Compiled-workload traffic (XLA cost analysis as the observer)
# ---------------------------------------------------------------------------

def observe_compiled(fn, *args) -> dict[str, float]:
    """Compile ``fn(*args)`` and read XLA's cost analysis: observed FLOPs
    and bytes accessed, plus the memory-analysis peak."""
    import jax  # lazy: keep the module importable jax-free

    from repro.runtime import compat

    compiled = jax.jit(fn).lower(*args).compile()
    cost = compat.cost_analysis(compiled)
    mem = compat.memory_stats(compiled)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "peak_bytes": float(mem["peak_bytes"])}


def conv2d_rows(N: int, H: int, W: int, CI: int, CO: int, KH: int, KW: int,
                *, dtype_bytes: int = 4) -> list[TrafficRow]:
    """Observed-vs-predicted rows for one NHWC VALID conv2d.

    Observed: XLA cost analysis of the compiled conv (the runtime).
    Predicted: exact MAC count (2 FLOPs/MAC) and the touch-once DRAM
    floor (input + weights + output bytes); the paper scheduler's
    global-buffer fetch plan for the same op is attached as a gauge-style
    extra row so the analytic GB prediction rides along with every
    comparison (``analysis/roofline`` closes over it offline).
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core import TEU_BUFFER, conv2d_op, order_grid_for_sharing, \
        search_tiles

    OH, OW = H - KH + 1, W - KW + 1
    macs = N * OH * OW * CO * CI * KH * KW
    floor_bytes = dtype_bytes * (N * H * W * CI + KH * KW * CI * CO +
                                 N * OH * OW * CO)

    def conv(x, w):
        return jax.lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    import jax
    x = jnp.asarray(np.zeros((N, H, W, CI), np.float32))
    w = jnp.asarray(np.zeros((KH, KW, CI, CO), np.float32))
    obs = observe_compiled(conv, x, w)

    # the paper's §II-B prediction for the same op: tile schedule + grid
    # order -> HBM->global-buffer fetch bytes on the TEU arch
    op = conv2d_op(CO, CI, OH, OW, KH, KW, bytes_per_elem=dtype_bytes)
    sched = search_tiles(op, TEU_BUFFER)
    plan = order_grid_for_sharing(op, sched.tile)
    return [
        TrafficRow("conv2d", "hlo_flops", obs["flops"], 2.0 * macs,
                   unit="flops"),
        TrafficRow("conv2d", "hlo_bytes", obs["bytes"], floor_bytes),
        # the scheduler's own GB fetch plan vs the refetch-everything
        # bound: the paper's fetch-reduction claim as a runtime row (the
        # plan must never exceed the naive bound)
        TrafficRow("conv2d", "gb", plan.total_fetch_bytes,
                   plan.total_fetch_bytes + plan.resident_bytes_saved,
                   tolerance=1.0 + 1e-9, bound=True),
    ]


def report(rows: Sequence[TrafficRow], *, registry=None) -> list[dict]:
    """Render rows as dicts and mirror them into a metrics registry
    (``obs.REGISTRY`` by default) as gauges keyed by workload/level."""
    if registry is None:
        from . import metrics
        registry = metrics.REGISTRY
    out = []
    for r in rows:
        registry.gauge("traffic_observed", r.observed,
                       workload=r.workload, level=r.level, unit=r.unit)
        registry.gauge("traffic_predicted", r.predicted,
                       workload=r.workload, level=r.level, unit=r.unit)
        registry.gauge("traffic_ratio", r.ratio,
                       workload=r.workload, level=r.level, unit=r.unit)
        out.append(r.row())
    return out
