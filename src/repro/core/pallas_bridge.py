"""Bridge: VectorMesh tile schedules -> Pallas BlockSpecs (TPU adaptation).

The paper's TEU schedule becomes one Pallas grid step: operand tiles live in
VMEM, the PSum buffer is an f32 VMEM accumulator, and the BFN conflict-free
condition becomes (sublane, lane) = (8, 128) alignment of the block shapes.
The grid order comes from ``core.exchange.order_grid_for_sharing`` so blocks
invariant along the innermost grid dims stay VMEM-resident (the intra-chip
FIFO analogue).

Both searches resolve through the vectorized + memoized scheduler engine
(``repro.core.autotune``), so ``plan_kernel`` for a repeated op shape (e.g.
every decoder layer of an LM calling ``matmul_block_shapes`` with the same
M/N/K) is a cache lookup, not a lattice scan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

from .ndrange import TensorOp
from .tiling import BufferSpec, TileSchedule, search_tiles
from .exchange import order_grid_for_sharing, GridOrder

# TPU tiling quanta for the last two axes of a VMEM block (fp32/bf16).
SUBLANE = 8
LANE = 128
MXU = 128


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def aligned(x: int, m: int) -> bool:
    return x % m == 0


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Everything a Pallas kernel needs: block shapes, grid, order."""

    schedule: TileSchedule
    grid_order: GridOrder
    block: dict[str, int]          # tile sizes, TPU-aligned
    grid: tuple[int, ...]          # grid extents in grid_order
    dims_order: tuple[str, ...]

    @property
    def vmem_bytes(self) -> int:
        return self.schedule.input_bytes + self.schedule.psum_bytes


def plan_kernel(op: TensorOp, *, vmem_budget_bytes: int = 64 * 1024 * 1024,
                psum_budget_bytes: int = 32 * 1024 * 1024,
                align: Mapping[str, int] | None = None,
                caps: Mapping[str, int] | None = None) -> KernelPlan:
    """Run the paper's tile search with TPU constraints and order the grid.

    ``align`` maps NDRange dim name -> required multiple (e.g. the two matmul
    lanes -> 128 for the MXU). Dims equal to their full size are exempt
    (ragged final blocks are handled by masking in the kernels).
    """
    buf = BufferSpec(input_bytes=vmem_budget_bytes,
                     psum_bytes=psum_budget_bytes,
                     align=dict(align or {}),
                     lanes=MXU * MXU)
    sched = search_tiles(op, buf, caps=caps)
    order = order_grid_for_sharing(op, sched.tile)
    grid_shape = op.grid_shape(sched.tile)
    grid = tuple(grid_shape[name] for name in order.order)
    return KernelPlan(schedule=sched, grid_order=order, block=dict(sched.tile),
                      grid=grid, dims_order=order.order)


def matmul_block_shapes(M: int, N: int, K: int,
                        *, vmem_budget_bytes: int = 8 * 1024 * 1024
                        ) -> tuple[int, int, int]:
    """Convenience: (bm, bn, bk) for an MxK @ KxN matmul, MXU-aligned.

    Uses the paper objective ((bm+bn)*bk bytes per bm*bn*bk MACs) under the
    VMEM budget; clamps to the problem size and rounds to MXU quanta.
    """
    from .ndrange import matmul_op
    op = matmul_op(M, N, K)
    plan = plan_kernel(
        op,
        vmem_budget_bytes=vmem_budget_bytes,
        psum_budget_bytes=vmem_budget_bytes // 2,
        align={"i": MXU if M >= MXU else 1,
               "j": LANE if N >= LANE else 1,
               "k": LANE if K >= LANE else 1},
    )
    b = plan.block
    return b["i"], b["j"], b["k"]
