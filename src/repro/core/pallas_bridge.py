"""Bridge: VectorMesh tile schedules -> Pallas BlockSpecs (TPU adaptation).

The paper's TEU schedule becomes one Pallas grid step: operand tiles live in
VMEM, the PSum buffer is an f32 VMEM accumulator, and the BFN conflict-free
condition becomes (sublane, lane) = (8, 128) alignment of the block shapes.
The grid order comes from ``core.exchange.order_grid_for_sharing`` so blocks
invariant along the innermost grid dims stay VMEM-resident (the intra-chip
FIFO analogue).

Both searches resolve through the vectorized + memoized scheduler engine
(``repro.core.autotune``), so ``plan_kernel`` for a repeated op shape (e.g.
every decoder layer of an LM calling ``matmul_block_shapes`` with the same
M/N/K) is a cache lookup, not a lattice scan.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Mapping

from .ndrange import TensorOp
from .tiling import BufferSpec, TileSchedule, search_tiles
from .exchange import order_grid_for_sharing, GridOrder

# TPU tiling quanta for the last two axes of a VMEM block (fp32/bf16).
SUBLANE = 8
LANE = 128
MXU = 128


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pow2_floor(n: int) -> int:
    return 1 << (max(1, int(n)).bit_length() - 1)


def aligned(x: int, m: int) -> bool:
    return x % m == 0


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Everything a Pallas kernel needs: block shapes, grid, order."""

    schedule: TileSchedule
    grid_order: GridOrder
    block: dict[str, int]          # tile sizes, TPU-aligned
    grid: tuple[int, ...]          # grid extents in grid_order
    dims_order: tuple[str, ...]

    @property
    def vmem_bytes(self) -> int:
        return self.schedule.input_bytes + self.schedule.psum_bytes


def plan_kernel(op: TensorOp, *, vmem_budget_bytes: int = 64 * 1024 * 1024,
                psum_budget_bytes: int = 32 * 1024 * 1024,
                align: Mapping[str, int] | None = None,
                caps: Mapping[str, int] | None = None) -> KernelPlan:
    """Run the paper's tile search with TPU constraints and order the grid.

    ``align`` maps NDRange dim name -> required multiple (e.g. the two matmul
    lanes -> 128 for the MXU). Dims equal to their full size are exempt
    (ragged final blocks are handled by masking in the kernels).
    """
    buf = BufferSpec(input_bytes=vmem_budget_bytes,
                     psum_bytes=psum_budget_bytes,
                     align=dict(align or {}),
                     lanes=MXU * MXU)
    sched = search_tiles(op, buf, caps=caps)
    order = order_grid_for_sharing(op, sched.tile)
    grid_shape = op.grid_shape(sched.tile)
    grid = tuple(grid_shape[name] for name in order.order)
    return KernelPlan(schedule=sched, grid_order=order, block=dict(sched.tile),
                      grid=grid, dims_order=order.order)


@functools.lru_cache(maxsize=4096)
def attention_block_shapes(q_len: int, kv_len: int, head_dim: int,
                           *, vmem_budget_bytes: int = 4 * 1024 * 1024
                           ) -> tuple[int, int]:
    """(block_q, block_k) for a flash-attention score tile, TPU-aligned.

    Runs the paper's tile search on the QK^T NDRange (head dim is the
    temporal/streamed axis, q and s are the stationary PSum axes) instead
    of hard-coding 128s: the per-shape result is memoized here AND behind
    the scheduler engine's structural-key cache, so every decoder layer of
    an LM resolves its blocks with a dict lookup.  Blocks clamp to
    [SUBLANE, 512] x [LANE, 1024] and to the (padded) problem size —
    the flash kernels pad ragged tails and mask them via kv_len/q_len."""
    from .ndrange import attention_scores_op
    q_cap = max(SUBLANE, min(512, q_len))
    k_cap = max(LANE if kv_len >= LANE else pow2_floor(kv_len),
                min(1024, kv_len))
    op = attention_scores_op(1, max(q_len, SUBLANE), max(kv_len, 1),
                             head_dim)
    plan = plan_kernel(
        op,
        vmem_budget_bytes=vmem_budget_bytes,
        psum_budget_bytes=vmem_budget_bytes // 2,
        align={"q": SUBLANE if q_len >= SUBLANE else 1,
               "s": LANE if kv_len >= LANE else 1},
        caps={"h": 1, "q": q_cap, "s": k_cap},
    )
    bq = max(1, min(plan.block["q"], q_cap))
    bk = max(1, min(plan.block["s"], k_cap))
    return bq, bk


def matmul_block_shapes(M: int, N: int, K: int,
                        *, vmem_budget_bytes: int = 8 * 1024 * 1024
                        ) -> tuple[int, int, int]:
    """Convenience: (bm, bn, bk) for an MxK @ KxN matmul, MXU-aligned.

    Uses the paper objective ((bm+bn)*bk bytes per bm*bn*bk MACs) under the
    VMEM budget; clamps to the problem size and rounds to MXU quanta.
    """
    from .ndrange import matmul_op
    op = matmul_op(M, N, K)
    plan = plan_kernel(
        op,
        vmem_budget_bytes=vmem_budget_bytes,
        psum_budget_bytes=vmem_budget_bytes // 2,
        align={"i": MXU if M >= MXU else 1,
               "j": LANE if N >= LANE else 1,
               "k": LANE if K >= LANE else 1},
    )
    b = plan.block
    return b["i"], b["j"], b["k"]
