"""Butterfly-network conflict-free banked access (paper §II-C, MERIT [23]).

A TEU's input buffer is a 2^X-banked SRAM (X=5 -> 32 banks) feeding 2^X PEs
through a butterfly network. Lin et al. [23] show that if the address of PE N
can be written

    A_N = A_0 + sum_{i=0}^{X-1} 2^i * o_i * b_i      (o_i odd, b_i = i-th bit of N)

(the paper prints ``2^X o_i b_i``, a typo: with 2^X every term is bank-
aligned and all PEs hit bank A_0 mod 2^X — the MERIT condition is per-bit
weights 2^i with odd multipliers, which makes N -> A_N mod 2^X a bijection)

... then the butterfly can route all 2^X requests in one cycle. Two things must
hold for single-cycle service:
  (1) bank-conflict freedom: the bank index (A_N mod 2^X) is a *permutation*
      of the PEs, and
  (2) butterfly routability: the permutation is realizable by a 2^X butterfly.

The MERIT form guarantees both. This module provides an executable model:
  * ``merit_addresses``   — generate the guaranteed-good pattern;
  * ``is_conflict_free``  — check (1) for an arbitrary address vector;
  * ``butterfly_routable``— check (2) by actually routing the network;
  * ``pad_stride``        — the paper's padding fix: bump an even stride to the
      next odd one so strided access becomes conflict-free.

On TPU the analogous structural constraint is lane/sublane alignment of VMEM
blocks (multiples of (8, 128)); see ``pallas_bridge.aligned``.  We keep this
model because it is a paper contribution and is property-tested in
``tests/test_bfn.py``.
"""
from __future__ import annotations

from typing import Sequence


def merit_addresses(base: int, odd_coeffs: Sequence[int], X: int) -> list[int]:
    """A_N = base + sum_i 2^i * o_i * b_i for N in [0, 2^X)."""
    if len(odd_coeffs) != X:
        raise ValueError(f"need {X} coefficients, got {len(odd_coeffs)}")
    for o in odd_coeffs:
        if o % 2 == 0:
            raise ValueError(f"coefficient {o} is even; MERIT requires odd")
    n = 1 << X
    out = []
    for N in range(n):
        a = base
        for i in range(X):
            if (N >> i) & 1:
                a += (1 << i) * odd_coeffs[i]
        out.append(a)
    return out


def strided_addresses(base: int, stride: int, X: int) -> list[int]:
    """The common pattern: PE N reads base + N*stride."""
    return [base + N * stride for N in range(1 << X)]


def bank_of(addr: int, X: int) -> int:
    return addr % (1 << X)


def is_conflict_free(addrs: Sequence[int], X: int) -> bool:
    """(1): all 2^X requests land in distinct banks."""
    banks = [bank_of(a, X) for a in addrs]
    return len(set(banks)) == len(addrs) == (1 << X)


def butterfly_routable(perm: Sequence[int], X: int) -> bool:
    """(2): can a 2^X butterfly realize PE N -> output perm[N]?

    A (single) butterfly network routes exactly the permutations where, at
    stage i (i = 0..X-1), each 2x2 switch is set consistently. We route
    greedily per stage: stage i partners differ in bit i of the *input* index;
    the switch must send one to the '0' side and one to the '1' side of bit i
    of the destination. Conflict (both partners need the same side) => not
    routable. This is the standard butterfly routing condition.
    """
    n = 1 << X
    if sorted(perm) != list(range(n)):
        return False
    cur = list(range(n))  # cur[pos] = packet originally from PE cur[pos]
    for stage in range(X):
        bit = 1 << stage
        nxt = [-1] * n
        for lo in range(n):
            if lo & bit:
                continue
            hi = lo | bit
            a, b = cur[lo], cur[hi]  # packets at the two switch inputs
            da, db = perm[a] & bit, perm[b] & bit
            if da == db:
                return False  # both packets want the same output port
            if da == 0:
                nxt[lo], nxt[hi] = a, b
            else:
                nxt[lo], nxt[hi] = b, a
        cur = nxt
    return all(cur[pos] is not None for pos in range(n)) and all(
        (perm[cur[pos]] == pos) for pos in range(n))


def serves_in_one_cycle(addrs: Sequence[int], X: int) -> bool:
    """Full condition: conflict-free banks AND butterfly-routable permutation."""
    if not is_conflict_free(addrs, X):
        return False
    # PE N needs the data in bank bank_of(addrs[N]); the network must route
    # bank b's read port to every PE requesting bank b.
    perm = [bank_of(a, X) for a in addrs]
    return butterfly_routable(perm, X)


def pad_stride(stride: int) -> int:
    """Paper's padding fix: strided patterns with an ODD stride are MERIT-form.

    base + N*stride has bank pattern N*stride mod 2^X, which is a permutation
    iff stride is odd. Padding each row of a 2D buffer by one element turns an
    even row-stride into an odd one.
    """
    return stride if stride % 2 == 1 else stride + 1


def xor_shuffle(addrs: Sequence[int], key: int, X: int) -> list[int]:
    """Bank-XOR shuffling [25]: remap bank = bank ^ (addr-dependent key).

    Used with pad_stride to make 2D tile accesses conflict-free; preserves
    the data, permutes the banks.
    """
    n = 1 << X
    return [(a - bank_of(a, X)) + (bank_of(a, X) ^ (key % n)) for a in addrs]
