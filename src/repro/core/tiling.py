"""Bandwidth-minimizing output-stationary tiling (paper §II-B, Eq. 4).

A tile of a ``TensorOp`` keeps its PSums (output footprint) stationary in the
TEU's PSum buffer, streams its input footprints through the input buffers, and
costs ``tile_input_bytes / tile_macs`` bytes of external bandwidth per MAC —
the paper's objective.  ``search_tiles`` enumerates candidate tiles under the
buffer-capacity constraints and returns the Pareto-best schedule.

The same search serves two hardware targets:
  * the paper's TEU (16 KB input buffers, 5 KB PSum, 32 PEs)  — used by sim/;
  * a TPU TensorCore (VMEM budget, 128x128 MXU alignment)     — used by kernels/.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from .ndrange import TensorOp, PARALLEL, TEMPORAL, enumerate_tiles


@dataclasses.dataclass(frozen=True)
class BufferSpec:
    """Capacity constraints of one execution tile (TEU or TensorCore)."""

    input_bytes: int           # input operand buffer capacity
    psum_bytes: int            # accumulator buffer capacity
    psum_bytes_per_elem: int = 4   # PSums accumulate in wider precision (f32)
    # Vector/matrix-unit shape constraints: every PARALLEL tile dim that maps to
    # a compute lane must be a multiple of `align.get(dim)` (1 = unconstrained).
    align: Mapping[str, int] = dataclasses.field(default_factory=dict)
    # Number of parallel lanes consumed per cycle (32 PEs for a TEU). Used by
    # the perf model, not the capacity check.
    lanes: int = 32


# Paper TEU: two 32-bank 16 KB input buffers, 5 KB PSum buffer, 32 PEs.
TEU_BUFFER = BufferSpec(input_bytes=2 * 16 * 1024, psum_bytes=5 * 1024, lanes=32)

# TPU v5e TensorCore: ~128 MiB VMEM; leave headroom for double buffering (/2)
# and the accumulator. MXU wants 128-multiples on the two matmul lanes.
VMEM_BUFFER = BufferSpec(input_bytes=64 * 1024 * 1024,
                         psum_bytes=32 * 1024 * 1024,
                         lanes=128 * 128)


@dataclasses.dataclass(frozen=True)
class TileSchedule:
    """A chosen tile + derived traffic/compute statistics."""

    op_name: str
    tile: dict[str, int]
    macs: int
    input_bytes: int
    psum_bytes: int
    bytes_per_mac: float
    num_tiles: int
    grid: dict[str, int]

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        t = ",".join(f"{k}={v}" for k, v in self.tile.items())
        return (f"TileSchedule({self.op_name}: [{t}] "
                f"{self.bytes_per_mac:.4f} B/MAC, {self.num_tiles} tiles)")


def tile_fits(op: TensorOp, tile: Mapping[str, int], buf: BufferSpec) -> bool:
    if op.tile_input_bytes(tile) > buf.input_bytes:
        return False
    if op.tile_psum_elems(tile) * buf.psum_bytes_per_elem > buf.psum_bytes:
        return False
    for dim, a in buf.align.items():
        if dim in tile and tile[dim] % a != 0 and tile[dim] != op.dim_map[dim].size:
            return False
    return True


def schedule_for(op: TensorOp, tile: Mapping[str, int]) -> TileSchedule:
    op.validate_tile(tile)
    return TileSchedule(
        op_name=op.name,
        tile=dict(tile),
        macs=op.tile_macs(tile),
        input_bytes=op.tile_input_bytes(tile),
        psum_bytes=op.tile_psum_elems(tile) * 4,
        bytes_per_mac=op.tile_bytes_per_mac(tile),
        num_tiles=op.num_tiles(tile),
        grid=op.grid_shape(tile),
    )


def search_tiles(op: TensorOp, buf: BufferSpec = TEU_BUFFER, *,
                 caps: Mapping[str, int] | None = None,
                 prefer_large: bool = True) -> TileSchedule:
    """Paper §II-B: pick the valid tile minimizing external bytes/MAC.

    Ties (common when several tiles hit the same footprint ratio) break toward
    larger tiles (fewer tiles => fewer PSum drains and less control overhead),
    then toward fuller temporal extent (fewer partial-sum revisits).

    Delegates to the vectorized + pruned + memoized engine in
    ``repro.core.autotune`` (result-identical to the brute force below;
    ~100x faster on conv-style 6-dim lattices and free on repeats).  Use
    ``search_tiles_reference`` to run the original O(lattice) scan.
    """
    from .autotune import search_tiles_engine  # lazy: avoids import cycle
    return search_tiles_engine(op, buf, caps=caps, prefer_large=prefer_large)


def search_tiles_reference(op: TensorOp, buf: BufferSpec = TEU_BUFFER, *,
                           caps: Mapping[str, int] | None = None,
                           prefer_large: bool = True) -> TileSchedule:
    """Brute-force reference for ``search_tiles`` (kept for equivalence
    tests and ``benchmarks/bench_scheduler.py --reference`` timing)."""
    best: TileSchedule | None = None
    best_key = None
    for tile in enumerate_tiles(op, caps=caps):
        if not tile_fits(op, tile, buf):
            continue
        s = schedule_for(op, tile)
        # Larger temporal tile => output written once per full reduction pass.
        temporal_cov = math.prod(
            tile[d.name] / d.size for d in op.temporal_dims) if op.temporal_dims else 1.0
        key = (s.bytes_per_mac, -temporal_cov, -s.macs if prefer_large else s.macs)
        if best is None or key < best_key:
            best, best_key = s, key
    if best is None:
        raise ValueError(
            f"no tile of {op.name} fits buffers "
            f"(input<= {buf.input_bytes}B, psum<={buf.psum_bytes}B)")
    return best


# ---------------------------------------------------------------------------
# Whole-workload traffic model (used by sim/ and by the DRAM-traffic tests).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrafficReport:
    """External traffic for executing the full op under a tile schedule."""

    input_fetch_bytes: int     # bytes fetched from the next memory level
    output_write_bytes: int    # PSum drains (exactly one per output elem here)
    total_macs: int

    @property
    def total_bytes(self) -> int:
        return self.input_fetch_bytes + self.output_write_bytes

    def normalized_access(self, per: int = 1000) -> float:
        """Paper Table III metric: bytes per `per` MAC operations."""
        return self.total_bytes * per / max(1, self.total_macs)


def traffic(op: TensorOp, tile: Mapping[str, int], *,
            shared_axes: Sequence[str] = ()) -> TrafficReport:
    """Count external fetches for the whole NDRange under a tiling.

    Without sharing, each tile fetches its full input footprint: operands are
    re-fetched once per tile even when a neighbouring tile just used them.
    ``shared_axes`` lists NDRange dims along which the FIFO mesh shares data:
    an operand invariant to a shared axis is fetched only once per *group* of
    tiles spanning that axis (paper Fig. 2 — E fetched once for P and Q).
    """
    op.validate_tile(tile)
    grid = op.grid_shape(tile)
    n_tiles = math.prod(grid.values())
    fetch = 0
    for v in op.inputs:
        inv = set(v.invariant_dims(op.dims))
        # Tiles that differ only along shared+invariant axes fetch once.
        group = 1
        for ax in shared_axes:
            if ax in inv:
                group *= grid[ax]
        fetch += v.footprint_bytes(tile) * (n_tiles // max(1, group))
        # note: footprint over the tile is per-tile unique data; groups share it.
    out_bytes = op.output.footprint_bytes(op.full_tile())
    return TrafficReport(
        input_fetch_bytes=fetch,
        output_write_bytes=out_bytes,
        total_macs=op.total_macs(),
    )
