"""Data-exchange mesh analysis (paper §II-B, Fig. 2).

Two tiles share an input operand iff the operand's affine index map has zero
partial derivative against every NDRange axis on which the tiles differ
(``d(i,k)/dj = 0``  =>  tiles differing only in j share A).  In hardware the
share travels over the FIFO mesh between neighbouring TEUs; the operand is
fetched from the global buffer exactly once per sharing group.

Two consumers of this analysis:

* ``plan_mesh_exchange`` — TEU-mesh granularity (used by sim/): tiles are
  mapped wave-by-wave onto an R x C TEU mesh; operands invariant along the
  mesh-row/col axis are fetched once per row/col and forwarded over FIFOs.

* ``order_grid_for_sharing`` — Pallas granularity (used by kernels/): choose
  the grid-dimension order so operands whose block index is invariant along
  the innermost grid dims stay resident in VMEM across consecutive grid steps
  (Mosaic skips re-fetching a block whose index_map output is unchanged) —
  the single-core analogue of the FIFO hand-off.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Mapping, Sequence

from .ndrange import TensorOp


@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """Result of mapping a tiled op onto an R x C TEU mesh with FIFO sharing."""

    mesh_shape: tuple[int, int]
    row_axis: str | None            # NDRange dim laid along mesh rows
    col_axis: str | None            # NDRange dim laid along mesh cols
    fetch_bytes: int                # unique bytes fetched from global memory
    fetch_bytes_unshared: int       # bytes if every TEU fetched privately
    fifo_hop_bytes: int             # bytes moved over FIFOs instead
    waves: int

    @property
    def sharing_factor(self) -> float:
        return self.fetch_bytes_unshared / max(1, self.fetch_bytes)


def _axis_choices(op: TensorOp, grid: Mapping[str, int]) -> list[str | None]:
    axes: list[str | None] = [None]
    axes += [d.name for d in op.parallel_dims if grid[d.name] > 1]
    return axes


def plan_mesh_exchange(op: TensorOp, tile: Mapping[str, int],
                       mesh_shape: tuple[int, int], *,
                       share_rows: bool = True,
                       share_cols: bool = True,
                       row_span_cap: int | None = None,
                       col_span_cap: int | None = None) -> ExchangePlan:
    """Memoized front door for mesh-exchange planning (see the reference
    below for the semantics; repeated (op, tile, mesh) queries — e.g. the
    simulator's PE sweeps — hit the ``repro.core.autotune`` cache)."""
    from .autotune import plan_mesh_exchange_engine  # lazy: import cycle
    return plan_mesh_exchange_engine(
        op, tile, mesh_shape, share_rows=share_rows, share_cols=share_cols,
        row_span_cap=row_span_cap, col_span_cap=col_span_cap)


def plan_mesh_exchange_reference(op: TensorOp, tile: Mapping[str, int],
                                 mesh_shape: tuple[int, int], *,
                                 share_rows: bool = True,
                                 share_cols: bool = True,
                                 row_span_cap: int | None = None,
                                 col_span_cap: int | None = None
                                 ) -> ExchangePlan:
    """Pick the (row_axis, col_axis) mesh layout minimizing global fetches.

    Execution proceeds in waves of R*C tiles. Within a wave, an operand that is
    invariant to the row axis is fetched by one TEU per column and forwarded
    down the column FIFOs (and symmetrically for columns). Operands invariant
    to both axes are fetched once per wave.

    ``share_rows``/``share_cols`` model restricted interconnects: Eyeriss'
    horizontal multicast shares along one axis only (the other axis still
    *executes* tiles concurrently but each unit fetches privately).
    """
    R, C = mesh_shape
    grid = op.grid_shape(tile)
    n_tiles = math.prod(grid.values())
    inv = {v.tensor_name: set(v.invariant_dims(op.dims)) for v in op.inputs}
    fp = {v.tensor_name: v.footprint_bytes(tile) for v in op.inputs}
    unshared = sum(fp.values()) * n_tiles

    best: ExchangePlan | None = None
    for row_axis, col_axis in itertools.product(_axis_choices(op, grid),
                                                _axis_choices(op, grid)):
        if row_axis is not None and row_axis == col_axis:
            continue
        # tiles concurrently resident along each mesh dimension
        r_span = min(R, grid[row_axis]) if row_axis else 1
        c_span = min(C, grid[col_axis]) if col_axis else 1
        wave = r_span * c_span
        waves = -(-n_tiles // wave)
        fetch = 0
        hops = 0
        for v in op.inputs:
            group = 1
            if share_rows and row_axis and row_axis in inv[v.tensor_name]:
                group *= min(r_span, row_span_cap or r_span)
            if share_cols and col_axis and col_axis in inv[v.tensor_name]:
                group *= min(c_span, col_span_cap or c_span)
            per_wave_fetch = fp[v.tensor_name] * (wave // group)
            fetch += per_wave_fetch * waves
            hops += fp[v.tensor_name] * (wave - wave // group) * waves
        plan = ExchangePlan((R, C), row_axis, col_axis, fetch, unshared,
                            hops, waves)
        if best is None or plan.fetch_bytes < best.fetch_bytes:
            best = plan
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# Pallas-grid ordering: VMEM residency as the intra-chip FIFO analogue.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GridOrder:
    """A permutation of grid dims, outermost first, with its reuse score."""

    order: tuple[str, ...]
    resident_bytes_saved: int   # HBM bytes NOT refetched thanks to residency
    total_fetch_bytes: int      # HBM bytes fetched under this order


def grid_fetch_bytes(op: TensorOp, tile: Mapping[str, int],
                     order: Sequence[str]) -> int:
    """HBM bytes fetched over the whole grid for a given dim order.

    A block of operand V is (re)fetched whenever a grid dim V depends on
    changes. With `order` outermost-first, V is fetched
    prod_{d in order, V depends on d} grid[d] times per full sweep *of the dims
    inside its innermost dependent dim* — i.e. exactly
    prod_{d: V depends on d} grid[d] x prod_{d outer than innermost dep} 1.
    Standard result: fetches(V) = prod over dims d of grid[d] if V depends on d
    else (grid[d] if d is OUTER than V's innermost dependent dim else 1).
    """
    grid = op.grid_shape(tile)
    total = 0
    for v in op.inputs:
        deps = {d.name for d in op.dims
                if any(e.depends_on(d.name) for e in v.index_exprs)}
        # position of the innermost dim v depends on
        innermost_dep = -1
        for pos, name in enumerate(order):
            if name in deps:
                innermost_dep = pos
        fetches = 1
        for pos, name in enumerate(order):
            if name in deps or pos < innermost_dep:
                fetches *= grid[name]
        total += v.footprint_bytes(tile) * fetches
    return total


def order_grid_for_sharing(op: TensorOp,
                           tile: Mapping[str, int]) -> GridOrder:
    """Choose the grid order minimizing HBM refetches (max VMEM residency).

    Reduction dims always stay innermost so the f32 accumulator drains
    exactly once per output block (paper's PSum-stationary rule); only the
    relative order of parallel dims is searched.

    Delegates to ``repro.core.autotune.order_grid_engine``: all parallel-dim
    permutations are scored in one NumPy reduction and the result is
    memoized.  ``order_grid_for_sharing_reference`` keeps the original
    per-permutation Python scan for equivalence testing.
    """
    from .autotune import order_grid_engine  # lazy: avoids import cycle
    return order_grid_engine(op, tile)


def order_grid_for_sharing_reference(op: TensorOp,
                                     tile: Mapping[str, int]) -> GridOrder:
    """Brute-force reference for ``order_grid_for_sharing``."""
    par = [d.name for d in op.parallel_dims]
    tmp = [d.name for d in op.temporal_dims]
    best: GridOrder | None = None
    for perm in itertools.permutations(par):
        order = tuple(perm) + tuple(tmp)
        fetch = grid_fetch_bytes(op, tile, order)
        naive = sum(v.footprint_bytes(tile) for v in op.inputs) * op.num_tiles(tile)
        g = GridOrder(order, naive - fetch, fetch)
        if best is None or g.total_fetch_bytes < best.total_fetch_bytes:
            best = g
    assert best is not None
    return best
