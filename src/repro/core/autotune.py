"""Scheduler engine: vectorized, pruned, memoized tile search + exchange
planning (paper §II-B, Fig. 2 — fast path).

Everything this repo derives from the paper — the Table III traffic numbers,
the Fig. 3/4 rooflines, the dry-run table, and the Pallas ``plan_kernel``
block shapes — funnels through two brute-force searches: the §II-B tile
search (``core.tiling.search_tiles``) and the Fig. 2 grid-order search
(``core.exchange.order_grid_for_sharing``).  The reference implementations
walk the candidate lattice tile-object-by-tile-object in pure Python
(~28k dict candidates and ~0.3 s for one ResNet conv layer) and are re-run
for every (arch, workload) pair the simulator touches.

This module replaces those hot paths with three composable layers:

1. **Vectorized candidate evaluation** (``_search_tiles_vectorized``).
   The pow2 tile lattice is materialized as NumPy arrays.  Each operand
   axis is an affine expression whose footprint extent over a tile box is
   ``1 + sum_i |c_i| (t_i - 1)`` — affine in the tile sizes — so per-axis
   extents, operand footprints, PSum elems, MACs and bytes-per-MAC for
   *all* candidates are computed by broadcasting, never by per-tile
   ``AffineExpr`` object traversal.

2. **Admissibility pruning** (branch-and-bound on the partial product).
   Footprints are monotone nondecreasing in every tile dim, so while the
   lattice is built up dim-by-dim, any partial assignment whose footprint
   *lower bound* (remaining dims at their minimum, 1) already violates a
   buffer capacity is dropped — together with the entire sublattice
   hanging off it.  Conv-style 6-dim ops never touch the full cartesian
   product.  Per-dim candidate values are pre-capped the same way.

3. **Memoization** (``_memo`` + optional on-disk cache).  Results are
   keyed by a *structural* op signature (dim sizes/kinds, affine coeffs,
   bytes-per-elem, macs-per-point — NOT the op name) plus the BufferSpec /
   caps / mesh arguments, in a process-wide LRU.  ``search_tiles``,
   ``plan_mesh_exchange``, ``order_grid_for_sharing`` and (transitively)
   ``pallas_bridge.plan_kernel`` all share it, so the simulator's repeated
   searches across PE sweeps are free after the first.  Setting
   ``REPRO_SCHED_DISK_CACHE=1`` additionally persists entries as JSON under
   ``.cache/repro_scheduler/`` (override the location with
   ``REPRO_CACHE_DIR``) so repeated benchmark runs start warm; delete the
   directory or call ``clear_cache(disk=True)`` to reset.

The engine is *provably* result-identical to the reference brute force: it
draws candidates from the same ``ndrange.tile_candidates`` lattice, keeps
them in the same iteration order (first-minimum wins on ties, like the
reference ``<`` scan), evaluates the same objective ``(bytes_per_mac,
-temporal_coverage, -macs)``, and builds the winning ``TileSchedule``
through the same ``schedule_for`` constructor.  ``tests/test_autotune.py``
asserts equality against the reference on all five op families.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import threading
from collections import OrderedDict
from typing import Any, Callable, Mapping

import numpy as np

from .ndrange import TensorOp, tile_candidates

# ---------------------------------------------------------------------------
# Structural signatures (cache keys).
# ---------------------------------------------------------------------------

# Python ints are exact at any size; the vectorized path works in int64 and
# divides via float64 (which loses the correctly-rounded int/int semantics
# past 2**53).  Fall back to the reference scan when any full-tile quantity
# could exceed that, so the engine stays bit-identical to the brute force.
_INT64_SAFE = 2 ** 53


def op_signature(op: TensorOp) -> tuple:
    """Canonical *structural* identity of a TensorOp — everything that
    affects scheduling, excluding the display name.  Two ops built
    separately with identical dims/kinds/affine maps/dtypes hash equal and
    share cache entries."""
    return (
        tuple((d.name, d.size, d.kind) for d in op.dims),
        tuple((v.index_exprs, v.bytes_per_elem) for v in op.inputs),
        (op.output.index_exprs, op.output.bytes_per_elem),
        op.macs_per_point,
    )


def _buf_signature(buf) -> tuple:
    # `lanes` feeds the perf model, not the search — excluded on purpose so
    # e.g. a 128-PE and 512-PE arch with equal buffers share one entry.
    return (buf.input_bytes, buf.psum_bytes, buf.psum_bytes_per_elem,
            tuple(sorted(buf.align.items())))


def _caps_signature(caps: Mapping[str, int] | None) -> tuple:
    return tuple(sorted((caps or {}).items()))


# ---------------------------------------------------------------------------
# Layer 3: memoization (in-process LRU + optional on-disk JSON cache).
# ---------------------------------------------------------------------------

_LRU_MAXSIZE = 4096
_lru: OrderedDict[tuple, Any] = OrderedDict()
_lru_lock = threading.Lock()
cache_stats = {"hits": 0, "misses": 0, "disk_hits": 0, "evictions": 0}


def _mirror_stats() -> None:
    """Mirror the cache counters into the metrics registry as gauges (the
    8 µs-warm claim's regression surface: bench_scheduler reports them)."""
    from repro.obs import REGISTRY
    for k, v in cache_stats.items():
        REGISTRY.gauge(f"autotune_cache.{k}", v)


def _disk_cache_dir() -> str | None:
    if os.environ.get("REPRO_SCHED_DISK_CACHE", "0") not in ("1", "true", "yes"):
        return None
    return os.environ.get("REPRO_CACHE_DIR",
                          os.path.join(".cache", "repro_scheduler"))


def _disk_path(key: tuple) -> str | None:
    root = _disk_cache_dir()
    if root is None:
        return None
    h = hashlib.sha256(repr(key).encode()).hexdigest()[:32]
    return os.path.join(root, f"{key[0]}_{h}.json")


def reset_cache_stats() -> None:
    """Zero the cache counters (tests and delta-based reporting)."""
    with _lru_lock:
        cache_stats.update(hits=0, misses=0, disk_hits=0, evictions=0)


def clear_cache(*, disk: bool = False) -> None:
    """Drop every memoized schedule/plan (and the on-disk cache if asked).
    ``cache_stats`` counters survive — they are lifetime telemetry, not
    cache contents (``reset_cache_stats`` zeroes them)."""
    with _lru_lock:
        _lru.clear()
    if disk:
        root = os.environ.get("REPRO_CACHE_DIR",
                              os.path.join(".cache", "repro_scheduler"))
        if os.path.isdir(root):
            for name in os.listdir(root):
                if name.endswith(".json"):
                    try:
                        os.remove(os.path.join(root, name))
                    except OSError:
                        pass


def _memo(key: tuple, compute: Callable[[], Any],
          to_json: Callable[[Any], Any] | None = None,
          from_json: Callable[[Any], Any] | None = None) -> Any:
    """LRU + optional disk lookup around ``compute()``.

    ``to_json``/``from_json`` serialize the value for the disk tier; when
    omitted the value is only cached in memory.
    """
    with _lru_lock:
        if key in _lru:
            _lru.move_to_end(key)
            cache_stats["hits"] += 1
            return _lru[key]
    path = _disk_path(key) if to_json is not None else None
    if path is not None and os.path.exists(path):
        try:
            with open(path) as f:
                value = from_json(json.load(f))
            cache_stats["disk_hits"] += 1
            with _lru_lock:
                _lru[key] = value
                while len(_lru) > _LRU_MAXSIZE:
                    _lru.popitem(last=False)
                    cache_stats["evictions"] += 1
            return value
        except (OSError, ValueError, KeyError, TypeError):
            pass  # corrupt entry: recompute and overwrite
    cache_stats["misses"] += 1
    value = compute()
    with _lru_lock:
        _lru[key] = value
        while len(_lru) > _LRU_MAXSIZE:
            _lru.popitem(last=False)
            cache_stats["evictions"] += 1
    if path is not None:
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(to_json(value), f)
            os.replace(tmp, path)
        except OSError:
            pass  # disk tier is best-effort
    return value


# ---------------------------------------------------------------------------
# Layers 1+2: vectorized lattice evaluation with branch-and-bound pruning.
# ---------------------------------------------------------------------------

def _lattice_overflow_risk(op: TensorOp) -> bool:
    full = op.full_tile()
    worst = op.tile_macs(full) + op.tile_input_bytes(full)
    worst += op.tile_psum_elems(full) + op.num_tiles(full)
    return worst >= _INT64_SAFE


def _build_pruned_lattice(op: TensorOp, buf, caps, pow2=True):
    """Materialize admissible tile candidates as an (N, n_dims) int64 array.

    Processes dims left-to-right (the ``itertools.product`` nesting order),
    carrying per-operand-axis extents; after each dim the *lower bound* of
    input bytes / PSum elems (remaining dims at tile=1 contribute nothing to
    any extent) is checked against the buffer and violating rows — whole
    sublattices of the remaining dims — are dropped.  Row order stays the
    product order, which is what makes first-minimum tie-breaking identical
    to the reference scan.
    """
    axes = tile_candidates(op, caps=caps, pow2=pow2)
    names = [d.name for d in op.dims]
    in_exprs = [(v.bytes_per_elem, e) for v in op.inputs for e in v.index_exprs]
    in_starts = []  # slices of in_exprs per input operand
    i = 0
    for v in op.inputs:
        in_starts.append((i, i + len(v.index_exprs)))
        i += len(v.index_exprs)
    out_exprs = list(op.output.index_exprs)

    # Per-dim pre-cap (cheap first pruning pass): a candidate value t for dim
    # d is admissible only if the footprint with every other dim at 1 fits.
    for j, d in enumerate(op.dims):
        kept = []
        for t in axes[j]:
            in_b = sum(
                v.bytes_per_elem * math.prod(
                    1 + abs(e.coeff(d.name)) * (t - 1)
                    for e in v.index_exprs)
                for v in op.inputs)
            ps = math.prod(1 + abs(e.coeff(d.name)) * (t - 1)
                           for e in out_exprs)
            if in_b <= buf.input_bytes and \
                    ps * buf.psum_bytes_per_elem <= buf.psum_bytes:
                kept.append(t)
            else:
                break  # monotone in t: larger values violate too
        axes[j] = kept or axes[j][:1]  # keep t=1 so infeasibility is reported
                                       # by the final mask, as in the reference

    tiles = np.zeros((1, 0), dtype=np.int64)
    exts = np.ones((1, len(in_exprs)), dtype=np.int64)   # input-axis extents
    pexts = np.ones((1, len(out_exprs)), dtype=np.int64)  # psum-axis extents
    for j, d in enumerate(op.dims):
        vals = np.asarray(axes[j], dtype=np.int64)
        n_old, n_v = tiles.shape[0], vals.shape[0]
        # old-major, vals-minor ravel == itertools.product order
        tiles = np.concatenate(
            [np.repeat(tiles, n_v, axis=0),
             np.tile(vals, n_old)[:, None]], axis=1)
        ic = np.array([abs(e.coeff(d.name)) for _, e in in_exprs],
                      dtype=np.int64)
        oc = np.array([abs(e.coeff(d.name)) for e in out_exprs],
                      dtype=np.int64)
        exts = (np.repeat(exts, n_v, axis=0)
                + ic[None, :] * (np.tile(vals, n_old)[:, None] - 1))
        pexts = (np.repeat(pexts, n_v, axis=0)
                 + oc[None, :] * (np.tile(vals, n_old)[:, None] - 1))
        # Branch-and-bound: lower-bound footprints with remaining dims at 1.
        in_lb = np.zeros(tiles.shape[0], dtype=np.int64)
        for (s, t), v in zip(in_starts, op.inputs):
            in_lb += exts[:, s:t].prod(axis=1) * v.bytes_per_elem
        ps_lb = pexts.prod(axis=1) * buf.psum_bytes_per_elem
        alive = (in_lb <= buf.input_bytes) & (ps_lb <= buf.psum_bytes)
        if j == len(op.dims) - 1 or not alive.all():
            # Always keep at least the all-ones row so the infeasible case
            # falls through to the final mask and raises like the reference.
            if not alive.any():
                alive = alive.copy()
                alive[0] = True
            tiles, exts, pexts = tiles[alive], exts[alive], pexts[alive]
        if j == len(op.dims) - 1:
            in_bytes, psum_elems = in_lb[alive], pexts.prod(axis=1)
    if tiles.shape[1] == 0:  # op with no dims (degenerate)
        in_bytes = np.zeros(1, dtype=np.int64)
        psum_elems = np.ones(1, dtype=np.int64)
    return names, tiles, in_bytes, psum_elems


def _search_tiles_vectorized(op: TensorOp, buf, caps, prefer_large: bool):
    """Vectorized replica of the reference ``search_tiles`` scan."""
    from .tiling import schedule_for  # local import: tiling imports us lazily

    names, tiles, in_bytes, psum_elems = _build_pruned_lattice(
        op, buf, caps)
    sizes = np.array([op.dim_map[n].size for n in names], dtype=np.int64)

    macs = tiles.prod(axis=1) * op.macs_per_point
    valid = (in_bytes <= buf.input_bytes) & \
            (psum_elems * buf.psum_bytes_per_elem <= buf.psum_bytes)
    for j, n in enumerate(names):
        a = buf.align.get(n)
        if a and a > 1:
            valid &= (tiles[:, j] % a == 0) | (tiles[:, j] == sizes[j])
    if not valid.any():
        raise ValueError(
            f"no tile of {op.name} fits buffers "
            f"(input<= {buf.input_bytes}B, psum<={buf.psum_bytes}B)")

    # Objective, staged exactly like the reference tuple comparison
    # (bytes_per_mac, -temporal_cov, -macs): exact-equality filtering per
    # stage == lexicographic min with first-occurrence tie-break.
    bpm = in_bytes / np.maximum(1, macs)          # float64, same rounding
    tcov = np.ones(tiles.shape[0])
    for j, n in enumerate(names):
        if op.dim_map[n].kind == "temporal":
            # same per-dim division + left-to-right product as math.prod
            tcov = tcov * (tiles[:, j] / sizes[j])

    mask = valid.copy()
    bpm_min = bpm[mask].min()
    mask &= bpm == bpm_min
    tc_max = tcov[mask].max()
    mask &= tcov == tc_max
    m_best = macs[mask].max() if prefer_large else macs[mask].min()
    mask &= macs == m_best
    idx = int(np.flatnonzero(mask)[0])
    tile = {n: int(tiles[idx, j]) for j, n in enumerate(names)}
    return schedule_for(op, tile)


# ---------------------------------------------------------------------------
# Public engine entry points (wired behind the core.tiling / core.exchange
# wrappers; call these directly for explicit engine use).
# ---------------------------------------------------------------------------

def _schedule_to_json(s) -> dict:
    return dataclasses.asdict(s)


def _schedule_from_json(d: dict):
    from .tiling import TileSchedule
    return TileSchedule(**d)


def search_tiles_engine(op: TensorOp, buf, *,
                        caps: Mapping[str, int] | None = None,
                        prefer_large: bool = True):
    """Memoized + vectorized + pruned §II-B tile search.

    Result-identical to ``core.tiling.search_tiles_reference``; the cache
    key is structural, so the returned schedule's ``op_name`` is patched to
    the caller's op when a differently-named twin produced the entry.
    """
    key = ("sched", op_signature(op), _buf_signature(buf),
           _caps_signature(caps), prefer_large)

    def compute():
        if _lattice_overflow_risk(op):
            from .tiling import search_tiles_reference
            return search_tiles_reference(op, buf, caps=caps,
                                          prefer_large=prefer_large)
        return _search_tiles_vectorized(op, buf, caps, prefer_large)

    s = _memo(key, compute, _schedule_to_json, _schedule_from_json)
    # Fresh dicts per caller: the LRU entry is shared process-wide, and a
    # caller mutating schedule.tile/.grid in place must not poison it.
    return dataclasses.replace(s, op_name=op.name, tile=dict(s.tile),
                               grid=dict(s.grid))


def order_grid_engine(op: TensorOp, tile: Mapping[str, int]):
    """Memoized + vectorized Fig. 2 grid-order search (Pallas granularity).

    Evaluates every parallel-dim permutation's HBM fetch bytes with one
    NumPy reduction instead of per-permutation Python accounting; picks the
    first minimum (== the reference ``itertools.permutations`` scan).
    Temporal dims always stay innermost (PSum-stationary rule).
    """
    key = ("order", op_signature(op), _caps_signature(tile))

    def from_json(d):
        from .exchange import GridOrder
        return GridOrder(tuple(d["order"]), d["resident_bytes_saved"],
                         d["total_fetch_bytes"])

    def compute():
        # worst case over all permutations is the refetch-everything bound
        # (num_tiles * sum of footprints); past int64-exact territory the
        # vectorized prod would wrap silently, so use the big-int reference.
        worst = op.num_tiles(tile) * sum(
            v.footprint_bytes(tile) for v in op.inputs)
        if worst >= _INT64_SAFE:
            from .exchange import order_grid_for_sharing_reference
            return order_grid_for_sharing_reference(op, tile)
        return _order_grid_vectorized(op, tile)

    return _memo(key, compute, _schedule_to_json, from_json)


def _order_grid_vectorized(op: TensorOp, tile):
    import itertools

    from .exchange import GridOrder

    grid = op.grid_shape(tile)
    par = [d.name for d in op.parallel_dims]
    tmp = [d.name for d in op.temporal_dims]
    perms = [tuple(p) + tuple(tmp) for p in itertools.permutations(par)]
    n_dims = len(op.dims)
    name_idx = {d.name: j for j, d in enumerate(op.dims)}
    gs = np.array([grid[d.name] for d in op.dims], dtype=np.int64)
    P = np.array([[name_idx[n] for n in order] for order in perms],
                 dtype=np.int64)                    # (n_perms, n_dims)
    deps = np.zeros((len(op.inputs), n_dims), dtype=bool)
    fp = np.zeros(len(op.inputs), dtype=np.int64)
    for i, v in enumerate(op.inputs):
        fp[i] = v.footprint_bytes(tile)
        for j, d in enumerate(op.dims):
            deps[i, j] = any(e.depends_on(d.name) for e in v.index_exprs)

    dep_at = deps[:, P]                             # (n_inputs, n_perms, n_dims)
    pos = np.arange(n_dims)
    # innermost (largest) position holding a dep, -1 when the operand is
    # invariant to every dim
    innermost = np.where(dep_at.any(axis=2),
                         n_dims - 1 - np.argmax(dep_at[:, :, ::-1], axis=2),
                         -1)
    refetch = dep_at | (pos[None, None, :] < innermost[:, :, None])
    factors = np.where(refetch, gs[P][None, :, :], 1)
    fetch = (factors.prod(axis=2) * fp[:, None]).sum(axis=0)  # (n_perms,)
    best = int(np.argmin(fetch))                    # first occurrence on ties
    naive = int(fp.sum()) * op.num_tiles(tile)
    return GridOrder(perms[best], naive - int(fetch[best]), int(fetch[best]))


def plan_mesh_exchange_engine(op: TensorOp, tile: Mapping[str, int],
                              mesh_shape: tuple[int, int], *,
                              share_rows: bool = True,
                              share_cols: bool = True,
                              row_span_cap: int | None = None,
                              col_span_cap: int | None = None):
    """Memoized mesh-exchange planner (the candidate space — (row, col)
    axis pairs — is tiny, so the win here is caching across the simulator's
    repeated (arch, workload) sweeps, not vectorization)."""
    key = ("mesh", op_signature(op), _caps_signature(tile), mesh_shape,
           share_rows, share_cols, row_span_cap, col_span_cap)

    def from_json(d):
        from .exchange import ExchangePlan
        return ExchangePlan(tuple(d["mesh_shape"]), d["row_axis"],
                            d["col_axis"], d["fetch_bytes"],
                            d["fetch_bytes_unshared"], d["fifo_hop_bytes"],
                            d["waves"])

    def compute():
        from .exchange import plan_mesh_exchange_reference
        return plan_mesh_exchange_reference(
            op, tile, mesh_shape, share_rows=share_rows,
            share_cols=share_cols, row_span_cap=row_span_cap,
            col_span_cap=col_span_cap)

    return _memo(key, compute, _schedule_to_json, from_json)
