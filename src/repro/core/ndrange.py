"""NDRange tensor-op formulation (paper §II-A, Eq. 1-3).

Every VectorMesh target workload is written as

    C(parallel idxs) = sum_{temporal idxs} R_A(...) * R_B(...)

where each operand R_X is an *affine view* of a stored tensor: every stored-tensor
dimension is an affine combination of NDRange indices (e.g. for conv,
``R_I(i,j,k,l,m,n) = I(l, j+m, k+n)``).  The parallel/temporal split plus these
affine index maps are the entire scheduling interface: tiling (paper Eq. 4), the
data-exchange partial-derivative test (paper Fig. 2), and the bandwidth model all
derive from them.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Mapping, Sequence

PARALLEL = "parallel"
TEMPORAL = "temporal"


@dataclasses.dataclass(frozen=True)
class Dim:
    """One NDRange dimension."""

    name: str
    size: int
    kind: str  # PARALLEL | TEMPORAL

    def __post_init__(self):
        if self.kind not in (PARALLEL, TEMPORAL):
            raise ValueError(f"bad dim kind {self.kind!r}")
        if self.size <= 0:
            raise ValueError(f"dim {self.name} has non-positive size {self.size}")


@dataclasses.dataclass(frozen=True)
class AffineExpr:
    """sum_i coeff[dim_i] * dim_i + const — one stored-tensor axis index."""

    coeffs: tuple[tuple[str, int], ...]  # ((dim_name, coeff), ...) sorted
    const: int = 0

    @staticmethod
    def of(coeffs: Mapping[str, int], const: int = 0) -> "AffineExpr":
        items = tuple(sorted((k, v) for k, v in coeffs.items() if v != 0))
        return AffineExpr(items, const)

    def depends_on(self, dim_name: str) -> bool:
        """The paper's partial-derivative test: d(expr)/d(dim) != 0."""
        return any(k == dim_name for k, _ in self.coeffs)

    def coeff(self, dim_name: str) -> int:
        for k, v in self.coeffs:
            if k == dim_name:
                return v
        return 0

    def extent(self, tile: Mapping[str, int]) -> int:
        """Number of distinct values this expression takes over a tile.

        For an affine expression the exact count over a box is the range span
        (affine maps over boxes hit a contiguous-ish set; we use the standard
        footprint bound  1 + sum |c_i| (t_i - 1)  which is exact for conv-style
        stride-1 maps and for single-dim maps).
        """
        span = 1
        for k, c in self.coeffs:
            span += abs(c) * (tile[k] - 1)
        return span


@dataclasses.dataclass(frozen=True)
class OperandView:
    """R_X: an affine view of stored tensor `tensor_name` with dtype-size bytes."""

    tensor_name: str
    index_exprs: tuple[AffineExpr, ...]  # one per stored-tensor axis
    bytes_per_elem: int = 2  # bf16 default

    def footprint_elems(self, tile: Mapping[str, int]) -> int:
        """Unique stored elements touched by a tile (product of per-axis extents)."""
        n = 1
        for e in self.index_exprs:
            n *= e.extent(tile)
        return n

    def footprint_bytes(self, tile: Mapping[str, int]) -> int:
        return self.footprint_elems(tile) * self.bytes_per_elem

    def invariant_dims(self, dims: Sequence[Dim]) -> tuple[str, ...]:
        """NDRange dims this operand does NOT depend on (zero partial derivative).

        These are exactly the axes along which neighbouring tiles can SHARE this
        operand over the FIFO mesh (paper §II-B: ``d(i,k)/dj = 0`` => share A).
        """
        out = []
        for d in dims:
            if not any(e.depends_on(d.name) for e in self.index_exprs):
                out.append(d.name)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class TensorOp:
    """C(parallel) = sum_{temporal} prod_k R_k(...) — the paper's workload form."""

    name: str
    dims: tuple[Dim, ...]
    inputs: tuple[OperandView, ...]
    output: OperandView  # indexed by parallel dims only
    macs_per_point: int = 1

    def __post_init__(self):
        names = [d.name for d in self.dims]
        if len(set(names)) != len(names):
            raise ValueError("duplicate dim names")
        # Output must not depend on temporal dims (PSum-stationary property).
        for d in self.dims:
            if d.kind == TEMPORAL:
                for e in self.output.index_exprs:
                    if e.depends_on(d.name):
                        raise ValueError(
                            f"output depends on temporal dim {d.name}; "
                            "not expressible as a reduction"
                        )

    # -- basic quantities -------------------------------------------------
    @property
    def dim_map(self) -> dict[str, Dim]:
        return {d.name: d for d in self.dims}

    @property
    def parallel_dims(self) -> tuple[Dim, ...]:
        return tuple(d for d in self.dims if d.kind == PARALLEL)

    @property
    def temporal_dims(self) -> tuple[Dim, ...]:
        return tuple(d for d in self.dims if d.kind == TEMPORAL)

    def total_points(self) -> int:
        return math.prod(d.size for d in self.dims)

    def total_macs(self) -> int:
        return self.total_points() * self.macs_per_point

    def full_tile(self) -> dict[str, int]:
        return {d.name: d.size for d in self.dims}

    # -- tiling quantities (paper Eq. 4 analysis) -------------------------
    def tile_macs(self, tile: Mapping[str, int]) -> int:
        return math.prod(tile[d.name] for d in self.dims) * self.macs_per_point

    def tile_psum_elems(self, tile: Mapping[str, int]) -> int:
        return self.output.footprint_elems(tile)

    def tile_input_bytes(self, tile: Mapping[str, int]) -> int:
        return sum(v.footprint_bytes(tile) for v in self.inputs)

    def tile_bytes_per_mac(self, tile: Mapping[str, int]) -> float:
        """Paper's objective: (t_i+t_j)t_k / (t_i t_j t_k) generalized."""
        return self.tile_input_bytes(tile) / max(1, self.tile_macs(tile))

    def num_tiles(self, tile: Mapping[str, int]) -> int:
        return math.prod(
            -(-d.size // tile[d.name]) for d in self.dims  # ceil-div
        )

    def grid_shape(self, tile: Mapping[str, int]) -> dict[str, int]:
        return {d.name: -(-d.size // tile[d.name]) for d in self.dims}

    def validate_tile(self, tile: Mapping[str, int]) -> None:
        for d in self.dims:
            t = tile.get(d.name)
            if t is None or t < 1 or t > d.size:
                raise ValueError(f"tile for {d.name} out of range: {t}")


# ---------------------------------------------------------------------------
# Constructors for the paper's three workload families (Eq. 1, 2, 3).
# ---------------------------------------------------------------------------

def matmul_op(M: int, N: int, K: int, *, bytes_per_elem: int = 2,
              name: str = "matmul") -> TensorOp:
    """Eq. (1): C(i,j) = sum_k A(i,k) B(k,j)."""
    dims = (
        Dim("i", M, PARALLEL),
        Dim("j", N, PARALLEL),
        Dim("k", K, TEMPORAL),
    )
    A = OperandView("A", (AffineExpr.of({"i": 1}), AffineExpr.of({"k": 1})),
                    bytes_per_elem)
    B = OperandView("B", (AffineExpr.of({"k": 1}), AffineExpr.of({"j": 1})),
                    bytes_per_elem)
    C = OperandView("C", (AffineExpr.of({"i": 1}), AffineExpr.of({"j": 1})),
                    bytes_per_elem)
    return TensorOp(name, dims, (A, B), C)


def conv2d_op(Co: int, Ci: int, oh: int, ow: int, kh: int, kw: int, *,
              stride: int = 1, dilation: int = 1, bytes_per_elem: int = 2,
              name: str = "conv2d") -> TensorOp:
    """Eq. (2): C(co,y,x) = sum_{ci,m,n} I(ci, y*s+m*d, x*s+n*d) K(co,ci,m,n)."""
    dims = (
        Dim("co", Co, PARALLEL),
        Dim("y", oh, PARALLEL),
        Dim("x", ow, PARALLEL),
        Dim("ci", Ci, TEMPORAL),
        Dim("m", kh, TEMPORAL),
        Dim("n", kw, TEMPORAL),
    )
    I = OperandView(
        "I",
        (
            AffineExpr.of({"ci": 1}),
            AffineExpr.of({"y": stride, "m": dilation}),
            AffineExpr.of({"x": stride, "n": dilation}),
        ),
        bytes_per_elem,
    )
    Kv = OperandView(
        "K",
        (
            AffineExpr.of({"co": 1}),
            AffineExpr.of({"ci": 1}),
            AffineExpr.of({"m": 1}),
            AffineExpr.of({"n": 1}),
        ),
        bytes_per_elem,
    )
    C = OperandView(
        "C",
        (AffineExpr.of({"co": 1}), AffineExpr.of({"y": 1}), AffineExpr.of({"x": 1})),
        bytes_per_elem,
    )
    return TensorOp(name, dims, (I, Kv), C)


def depthwise_conv2d_op(C_: int, oh: int, ow: int, kh: int, kw: int, *,
                        stride: int = 1, bytes_per_elem: int = 2,
                        name: str = "dwconv2d") -> TensorOp:
    """MobileNet depthwise conv: no channel reduction; C(c,y,x)=sum_{m,n}."""
    dims = (
        Dim("c", C_, PARALLEL),
        Dim("y", oh, PARALLEL),
        Dim("x", ow, PARALLEL),
        Dim("m", kh, TEMPORAL),
        Dim("n", kw, TEMPORAL),
    )
    I = OperandView(
        "I",
        (
            AffineExpr.of({"c": 1}),
            AffineExpr.of({"y": stride, "m": 1}),
            AffineExpr.of({"x": stride, "n": 1}),
        ),
        bytes_per_elem,
    )
    Kv = OperandView(
        "K",
        (AffineExpr.of({"c": 1}), AffineExpr.of({"m": 1}), AffineExpr.of({"n": 1})),
        bytes_per_elem,
    )
    C = OperandView(
        "C",
        (AffineExpr.of({"c": 1}), AffineExpr.of({"y": 1}), AffineExpr.of({"x": 1})),
        bytes_per_elem,
    )
    return TensorOp(name, dims, (I, Kv), C)


def correlation_op(sw: int, sh: int, ow: int, oh: int, Ci: int, *,
                   bytes_per_elem: int = 2, name: str = "correlation") -> TensorOp:
    """Eq. (3): C(i,j,k,l) = sum_m I1(m,i,j) I2(m,i+k,j+l) — spatial matching."""
    dims = (
        Dim("i", sw, PARALLEL),
        Dim("j", sh, PARALLEL),
        Dim("k", ow, PARALLEL),
        Dim("l", oh, PARALLEL),
        Dim("m", Ci, TEMPORAL),
    )
    I1 = OperandView(
        "I1",
        (AffineExpr.of({"m": 1}), AffineExpr.of({"i": 1}), AffineExpr.of({"j": 1})),
        bytes_per_elem,
    )
    I2 = OperandView(
        "I2",
        (
            AffineExpr.of({"m": 1}),
            AffineExpr.of({"i": 1, "k": 1}),
            AffineExpr.of({"j": 1, "l": 1}),
        ),
        bytes_per_elem,
    )
    C = OperandView(
        "C",
        (
            AffineExpr.of({"i": 1}),
            AffineExpr.of({"j": 1}),
            AffineExpr.of({"k": 1}),
            AffineExpr.of({"l": 1}),
        ),
        bytes_per_elem,
    )
    return TensorOp(name, dims, (I1, I2), C)


def attention_scores_op(heads: int, q_len: int, kv_len: int, head_dim: int, *,
                        bytes_per_elem: int = 2,
                        name: str = "attn_qk") -> TensorOp:
    """QK^T as a batched matmul — the LM-scale 'spatial matching' analogue."""
    dims = (
        Dim("h", heads, PARALLEL),
        Dim("q", q_len, PARALLEL),
        Dim("s", kv_len, PARALLEL),
        Dim("d", head_dim, TEMPORAL),
    )
    Q = OperandView(
        "Q",
        (AffineExpr.of({"h": 1}), AffineExpr.of({"q": 1}), AffineExpr.of({"d": 1})),
        bytes_per_elem,
    )
    Kv = OperandView(
        "K",
        (AffineExpr.of({"h": 1}), AffineExpr.of({"s": 1}), AffineExpr.of({"d": 1})),
        bytes_per_elem,
    )
    C = OperandView(
        "S",
        (AffineExpr.of({"h": 1}), AffineExpr.of({"q": 1}), AffineExpr.of({"s": 1})),
        bytes_per_elem,
    )
    return TensorOp(name, dims, (Q, Kv), C)


def tile_candidates(op: TensorOp, *, caps: Mapping[str, int] | None = None,
                    pow2: bool = True) -> list[list[int]]:
    """Per-dim candidate tile sizes, sorted ascending, one list per op dim.

    ``pow2=True`` (default): powers of two up to the (possibly capped) dim
    size, plus the capped size itself.  ``pow2=False``: a denser ladder that
    also includes the 1.5x midpoints (1, 2, 3, 4, 6, 8, 12, 16, 24, ...).
    This is the single source of truth for the candidate lattice — both the
    brute-force ``enumerate_tiles`` and the vectorized engine in
    ``repro.core.autotune`` draw from it, which is what makes their results
    provably identical.
    """
    axes = []
    for d in op.dims:
        cap = min(d.size, (caps or {}).get(d.name, d.size))
        vals = set()
        v = 1
        while v <= cap:
            vals.add(v)
            if not pow2 and v > 1 and v + v // 2 <= cap:
                vals.add(v + v // 2)
            v *= 2
        vals.add(cap)
        axes.append(sorted(vals))
    return axes


def enumerate_tiles(op: TensorOp, *, caps: Mapping[str, int] | None = None,
                    pow2: bool = True) -> "itertools.product":
    """Candidate tile iterator: powers of two (and the full size) per dim."""
    axes = tile_candidates(op, caps=caps, pow2=pow2)
    names = [d.name for d in op.dims]
    for combo in itertools.product(*axes):
        yield dict(zip(names, combo))
