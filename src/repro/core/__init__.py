# The paper's primary contribution: the VectorMesh scheduling methodology as a
# composable library — NDRange tensor-op formulation (Eq. 1-3), bandwidth-
# minimizing output-stationary tiling (Eq. 4), FIFO-mesh data-exchange analysis
# (Fig. 2), and the BFN conflict-free access condition (§II-C) — plus the
# bridge that turns schedules into Pallas BlockSpecs / grid orders on TPU.
from .ndrange import (
    AffineExpr,
    Dim,
    OperandView,
    TensorOp,
    PARALLEL,
    TEMPORAL,
    attention_scores_op,
    conv2d_op,
    correlation_op,
    depthwise_conv2d_op,
    matmul_op,
)
from .tiling import (
    BufferSpec,
    TEU_BUFFER,
    VMEM_BUFFER,
    TileSchedule,
    TrafficReport,
    schedule_for,
    search_tiles,
    search_tiles_reference,
    tile_fits,
    traffic,
)
from .exchange import (
    ExchangePlan,
    GridOrder,
    grid_fetch_bytes,
    order_grid_for_sharing,
    order_grid_for_sharing_reference,
    plan_mesh_exchange,
    plan_mesh_exchange_reference,
)
from .autotune import cache_stats, clear_cache, op_signature
from . import bfn
from .pallas_bridge import KernelPlan, matmul_block_shapes, plan_kernel

__all__ = [
    "AffineExpr", "Dim", "OperandView", "TensorOp", "PARALLEL", "TEMPORAL",
    "attention_scores_op", "conv2d_op", "correlation_op",
    "depthwise_conv2d_op", "matmul_op",
    "BufferSpec", "TEU_BUFFER", "VMEM_BUFFER", "TileSchedule",
    "TrafficReport", "schedule_for", "search_tiles",
    "search_tiles_reference", "tile_fits", "traffic",
    "ExchangePlan", "GridOrder", "grid_fetch_bytes", "order_grid_for_sharing",
    "order_grid_for_sharing_reference", "plan_mesh_exchange",
    "plan_mesh_exchange_reference",
    "cache_stats", "clear_cache", "op_signature",
    "bfn", "KernelPlan", "matmul_block_shapes", "plan_kernel",
]
