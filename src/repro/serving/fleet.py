"""Fault-tolerant multi-host serving fleet: router + page-ownership
directory + KV page migration + chaos-driven request recovery.

ROADMAP's last planet-scale serving leg, item (c): pages MIGRATE over the
mesh instead of replicating tables.  N serving engines (one per "host",
each with its own block pool, radix trie, and scheduler) sit behind a
front-end router.  A :class:`~repro.serving.prefix.PageOwnershipDirectory`
— the radix trie grown an ``owner_host`` per node — answers "which host
holds this prefix"; a request landing on a different host triggers a
point-to-point page migration over a
:class:`~repro.runtime.fleet.LocalPageExchange` /
:class:`~repro.runtime.fleet.TcpPageExchange` channel (CRC per page)
rather than a re-prefill.  This is the paper's FIFO-mesh
promote-local-to-global thesis at KV-page granularity: a page is computed
once, owned once, and made globally visible by MOVING it, the way a tile
result moves through the exchange mesh instead of being recomputed per
consumer.

Robustness is the headline — the router is a recovery state machine
driven by the serving chaos kinds in ``runtime/chaos.py``:

  host loss (``die@T:host=H``)
      the host's directory entries are TOMBSTONED (lookups stop at them,
      which yields recompute-from-longest-SURVIVING-ancestor for free),
      and its in-flight requests are re-admitted on survivors with
      bounded per-request retries and seeded backoff;
  migration-channel blackout (``netsplit@T:host=H,duration=D``)
      transfers raise :class:`~repro.runtime.fleet.PageExchangeTimeout`
      and the router falls back to recompute — timeouts are never
      confused with corruption;
  in-flight corruption (``pagecorrupt@T``)
      the receiver's per-page CRC rejects the frame
      (:class:`~repro.runtime.fleet.PageCorruptError`) and the router
      recomputes — a damaged page never enters a pool;
  stuck requests
      a dispatch in flight past ``hedge_after`` ticks gets a HEDGED twin
      on another live host; the first copy to finish wins and the loser
      is cancelled (releasing its pages).

Determinism: every engine shares one bundle + params, greedy decoding is
batching-independent (the PR 3/7 differential property), and a migrated
page is bit-identical to the locally computed KV — so for every request
the fleet completes, its tokens equal the single-engine baseline's, chaos
or not.  ``tests/test_serving_fleet.py`` proves exactly that.

:class:`LocalFleet` runs the hosts in-process (tests, benchmarks — the
analogue of ``LocalStripeExchange``); ``launch/serve.py --fleet N`` runs
real serve worker processes under ``runtime/supervisor.py`` with the same
chaos specs delivered via ``--chaos``.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Any

import numpy as np

from repro.obs import get_telemetry
from repro.runtime.fleet import (LocalPageExchange, PageCorruptError,
                                 PageExchangeTimeout, encode_page_frame)

from .engine import ServingEngine
from .prefix import PageOwnershipDirectory

PLACEMENTS = ("affinity", "round_robin")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Router policy knobs (the engines keep their own ServeConfig)."""
    max_retries: int = 3           # re-dispatches after host loss, per rid
    retry_backoff: int = 2         # base hold-off ticks (seeded jitter on top)
    hedge_after: int | None = None  # ticks in flight before a hedged twin
    migrate: bool = True           # move owned pages to the serving host
    placement: str = "affinity"    # affinity | round_robin
    seed: int = 0                  # drives the retry-backoff jitter


@dataclasses.dataclass
class _Copy:
    """One dispatch of a fleet request onto one host's engine."""
    host: int
    local_rid: int
    tick: int                      # fleet tick it was dispatched


@dataclasses.dataclass
class _Flight:
    """Router-side request state (the engines never see fleet rids)."""
    rid: int
    prompt: np.ndarray
    priority: int
    deadline: int | None
    attempts: int = 0              # death-triggered re-dispatches so far
    next_try_tick: int = 0
    copies: list[_Copy] = dataclasses.field(default_factory=list)
    hedged: bool = False
    death_tick: int | None = None  # first host loss that hit this request


class LocalFleet:
    """N in-process serving engines behind a recovering router.

    ``engines`` must share bundle + params (the determinism contract);
    each becomes one "host".  ``chaos`` is a
    :class:`~repro.runtime.chaos.ChaosInjector` consulted on the FLEET's
    tick clock.  The page-exchange channel is injectable for tests; by
    default a :class:`LocalPageExchange` wired to the chaos netsplit /
    pagecorrupt hooks.
    """

    def __init__(self, engines: list[ServingEngine],
                 cfg: FleetConfig | None = None, *,
                 chaos: Any = None, exchange: Any = None,
                 telemetry: Any = None):
        if not engines:
            raise ValueError("fleet needs at least one engine")
        if any(e.cfg.kv_mode == "dense" for e in engines):
            raise ValueError("fleet hosts must run a paged kv_mode "
                             "(page migration needs a page pool)")
        self.cfg = cfg or FleetConfig()
        if self.cfg.placement not in PLACEMENTS:
            raise ValueError(f"placement {self.cfg.placement!r} "
                             f"not in {PLACEMENTS}")
        self.engines = list(engines)
        self.alive = [True] * len(engines)
        self.chaos = chaos
        self.obs = telemetry if telemetry is not None else get_telemetry()
        self.metrics = self.obs.metrics
        page_size = engines[0].kv.cfg.page_size
        if any(e.kv.cfg.page_size != page_size for e in engines):
            raise ValueError("fleet hosts must agree on page_size")
        self.directory = PageOwnershipDirectory(page_size)
        if exchange is None:
            exchange = LocalPageExchange()
            if chaos is not None:
                exchange.blackout = \
                    lambda h: chaos.netsplit_active(self.tick, h)
                exchange.corrupt_hook = \
                    lambda: chaos.corrupt_next_page(self.tick)
        self.exchange = exchange
        self.tick = 0
        self.results: dict[int, list[int]] = {}
        self.outcomes: dict[int, str] = {}   # ok|timeout|shed|failed
        self._flights: dict[int, _Flight] = {}
        self._next_rid = 0
        self._rr = 0                         # round_robin cursor
        # counters (stats(); telemetry() absorbs them into the registry)
        self.migrations = {"ok": 0, "timeout": 0, "corrupt": 0}
        self.migrated_pages = 0
        self.retries = 0
        self.failed = 0
        self.hedges = 0
        self.deaths = 0

    # -- intake + loop surfaces ---------------------------------------------

    def submit(self, prompt_tokens, priority: int = 0,
               deadline: int | None = None) -> int:
        """Queue one request with a FLEET-scoped rid; dispatch happens on
        the next :meth:`step` (placement + migration are tick work)."""
        rid = self._next_rid
        self._next_rid += 1
        self._flights[rid] = _Flight(
            rid=rid, prompt=np.asarray(prompt_tokens, np.int32),
            priority=priority, deadline=deadline)
        return rid

    def pending(self) -> bool:
        return any(rid not in self.results for rid in self._flights)

    def run(self, *, max_ticks: int = 100_000) -> dict[int, list[int]]:
        """Drain every submitted request (completed, timed out, shed, or
        failed after the retry budget)."""
        while self.pending():
            if self.tick >= max_ticks:
                raise RuntimeError(f"fleet made no progress in "
                                   f"{max_ticks} ticks")
            self.step()
        return self.results

    def live_hosts(self) -> list[int]:
        return [h for h, a in enumerate(self.alive) if a]

    # -- the recovery state machine, one tick -------------------------------

    def step(self) -> None:
        """One fleet tick: fire host-death chaos, re-admit orphans,
        dispatch queued work (migrating owned pages to the target),
        advance every live engine one tick, harvest completions
        (first-writer-wins for hedged twins), and hedge overdue
        dispatches."""
        self.tick += 1
        if self.chaos is not None:
            for host in self.live_hosts():
                if self.chaos.should_die(self.tick, host):
                    self._kill_host(host)
        self._dispatch_queued()
        for host in self.live_hosts():
            self.engines[host].step()
        self._harvest()
        self._hedge_overdue()

    # -- host loss -----------------------------------------------------------

    def _kill_host(self, host: int) -> None:
        """Host ``host`` is gone: tombstone its directory pages, orphan
        its in-flight copies, and queue the affected requests for
        re-dispatch on survivors (the directory's tombstones make their
        next lookup stop at the longest SURVIVING ancestor)."""
        self.alive[host] = False
        self.deaths += 1
        tombs = self.directory.tombstone_host(host)
        self.metrics.counter("fleet_tombstones", tombs)
        self.metrics.counter("fleet_deaths")
        self.obs.instant("host_die", host=host, tick=self.tick,
                         tombstoned=tombs)
        for fl in self._flights.values():
            if fl.rid in self.results:
                continue
            before = len(fl.copies)
            fl.copies = [c for c in fl.copies if c.host != host]
            if before == len(fl.copies) or fl.copies:
                continue          # untouched, or a hedged twin survives
            if fl.death_tick is None:
                fl.death_tick = self.tick
            fl.attempts += 1
            if fl.attempts > self.cfg.max_retries:
                self.results[fl.rid] = []
                self.outcomes[fl.rid] = "failed"
                self.failed += 1
                self.metrics.counter("fleet_requests", outcome="failed")
                continue
            # seeded backoff: deterministic per (seed, rid, attempt) so a
            # chaos scenario replays bit-identically
            rng = random.Random(f"{self.cfg.seed}:{fl.rid}:{fl.attempts}")
            base = max(1, self.cfg.retry_backoff)
            fl.next_try_tick = self.tick + \
                base * 2 ** (fl.attempts - 1) + rng.randrange(base)
            self.retries += 1
            self.metrics.counter("fleet_retries")

    # -- placement + migration ----------------------------------------------

    def _pick_target(self, fl: _Flight, match) -> int:
        live = self.live_hosts()
        if not live:
            raise RuntimeError("fleet has no live hosts")
        if self.cfg.placement == "round_robin":
            host = live[self._rr % len(live)]
            self._rr += 1
            return host
        # affinity: land on the host already owning the longest prefix
        # run (no migration at all), else the least-loaded survivor
        if match.hit and match.owners[0] in live:
            return match.owners[0]
        return min(live, key=lambda h: (len(self.engines[h].inflight()), h))

    def _migrate(self, fl: _Flight, match, target: int) -> None:
        """Move the leading directory-owned page run to ``target`` so its
        prefill starts from transferred KV instead of recomputing it.
        Timeouts and CRC failures both degrade to recompute — the request
        itself never fails on a migration fault."""
        src = match.owners[0]
        run_tokens = 0
        for owner, seg in zip(match.owners, match.segments):
            if owner != src:
                break
            run_tokens += len(seg)
        if src == target or src not in self.live_hosts() or run_tokens == 0:
            return
        exported = self.engines[src].export_prefix_pages(
            fl.prompt, run_tokens)
        if not exported:
            return               # locally evicted since it was published
        frames = [encode_page_frame(seg, vals) for seg, vals in exported]
        sent = sum(len(f) for f in frames)
        try:
            with self.metrics.timer("fleet_migration_s"):
                decoded = self.exchange.transfer(src, target, frames)
                imported = self.engines[target].import_prefix_pages(decoded)
        except PageExchangeTimeout:
            self.migrations["timeout"] += 1
            self.metrics.counter("fleet_migrations", outcome="timeout")
            return
        except PageCorruptError:
            self.migrations["corrupt"] += 1
            self.metrics.counter("fleet_migrations", outcome="corrupt")
            return
        self.migrations["ok"] += 1
        self.migrated_pages += len(frames)
        self.metrics.counter("fleet_migrations", outcome="ok")
        self.metrics.counter("page_exchange_bytes", sent)
        self.metrics.counter("page_exchange_pages", len(frames))
        if imported:
            self.directory.transfer(fl.prompt, imported, target)
            self.engines[src].drop_prefix_path(fl.prompt, imported)
        self.obs.instant("migrate", rid=fl.rid, src=src, dst=target,
                         pages=len(frames), bytes=sent)

    def _dispatch_queued(self) -> None:
        for fl in self._flights.values():
            if fl.rid in self.results or fl.copies \
                    or fl.next_try_tick > self.tick:
                continue
            match = self.directory.lookup(fl.prompt)
            target = self._pick_target(fl, match)
            if self.cfg.migrate and match.hit:
                self._migrate(fl, match, target)
            local = self.engines[target].submit(
                fl.prompt, priority=fl.priority, deadline=fl.deadline)
            fl.copies.append(_Copy(host=target, local_rid=local,
                                   tick=self.tick))

    # -- harvest + hedging ---------------------------------------------------

    def _harvest(self) -> None:
        for fl in self._flights.values():
            if not fl.copies:
                continue
            done = [c for c in fl.copies
                    if c.local_rid in self.engines[c.host].results]
            for c in done:
                fl.copies.remove(c)
                eng = self.engines[c.host]
                outcome = eng.outcomes.get(c.local_rid, "ok")
                if outcome == "cancelled" or fl.rid in self.results:
                    continue
                self.results[fl.rid] = eng.results[c.local_rid]
                self.outcomes[fl.rid] = outcome
                self.metrics.counter("fleet_requests", outcome=outcome)
                if outcome == "ok":
                    self._publish(fl, c.host)
                if fl.death_tick is not None:
                    self.metrics.observe("fleet_recovery_ticks",
                                         self.tick - fl.death_tick)
                # retire the losing hedge twins: their pages go back now
                for twin in fl.copies:
                    if self.alive[twin.host]:
                        self.engines[twin.host].cancel(twin.local_rid)

    def _publish(self, fl: _Flight, host: int) -> None:
        """A completed request promotes its cached prefix to global
        visibility: its full pages enter the directory under the serving
        host (the engine's trie already adopted them locally).  The final
        sampled token's KV was never written, hence the ``[:-1]``."""
        out = self.results[fl.rid]
        seq = np.concatenate([fl.prompt, np.asarray(out, np.int32)]) \
            if out else fl.prompt
        self.directory.publish(seq[:-1], host)

    def _hedge_overdue(self) -> None:
        if self.cfg.hedge_after is None:
            return
        for fl in self._flights.values():
            if fl.rid in self.results or fl.hedged or len(fl.copies) != 1:
                continue
            copy = fl.copies[0]
            if self.tick - copy.tick < self.cfg.hedge_after:
                continue
            others = [h for h in self.live_hosts() if h != copy.host]
            if not others:
                continue
            host = min(others,
                       key=lambda h: (len(self.engines[h].inflight()), h))
            local = self.engines[host].submit(
                fl.prompt, priority=fl.priority, deadline=fl.deadline)
            fl.copies.append(_Copy(host=host, local_rid=local,
                                   tick=self.tick))
            fl.hedged = True
            self.hedges += 1
            self.metrics.counter("fleet_hedges")
            self.obs.instant("hedge", rid=fl.rid, slow_host=copy.host,
                             twin_host=host)

    # -- accounting ----------------------------------------------------------

    def stats(self) -> dict:
        counts = {"ok": 0, "timeout": 0, "shed": 0, "failed": 0}
        for v in self.outcomes.values():
            counts[v] = counts.get(v, 0) + 1
        ex_bytes = getattr(self.exchange, "bytes_sent", 0)
        return {
            "ticks": self.tick,
            "hosts": len(self.engines),
            "live_hosts": len(self.live_hosts()),
            "deaths": self.deaths,
            "outcomes": counts,
            "retries": self.retries,
            "hedges": self.hedges,
            "migrations": dict(self.migrations),
            "migrated_pages": self.migrated_pages,
            "page_exchange_bytes": ex_bytes,
            "directory": self.directory.stats(),
        }

    def telemetry(self) -> dict:
        """Snapshot + mirror into the metrics registry (``fleet.*``
        gauges), same pull pattern as ``ServingEngine.telemetry``."""
        snap = self.stats()
        self.metrics.absorb(snap, prefix="fleet.")
        for host, eng in enumerate(self.engines):
            self.metrics.absorb({"alive": self.alive[host]},
                                prefix=f"fleet.host{host}.")
        return snap
