"""Phase-aware request scheduler: prefill/decode disaggregation + priority
admission + preemption by page pressure.

Replaces the seed engine's FIFO slot round-robin with three explicit
phases per request:

  WAITING  -> admission by (priority desc, arrival asc); a request is only
              admitted when a slot is free AND the block pool can map its
              whole prompt (plus one decode page of headroom).
  PREFILL  -> the prompt is consumed in fixed-size CHUNKS, budgeted per
              tick (``prefill_token_budget``), so one long prompt cannot
              starve the decode pool — the serving analogue of
              prefill/decode disaggregation.  Chunks of different requests
              interleave across ticks.
  DECODE   -> the whole slot pool advances one token per tick (one jitted
              SPMD step regardless of occupancy, as before).

Preemption: when the pool runs dry — either a high-priority arrival can't
be admitted or a decoding slot needs its next page — the LOWEST-priority
active request is evicted: its pages return to the free list and the
request re-enters WAITING with its generated tokens folded into the prompt
(vLLM-style recompute on re-admission).  Eviction never targets ANOTHER
request with priority >= the one that needs the pages; when no strictly
lower-priority victim exists, a decoding slot that cannot grow evicts
ITSELF (equal-priority peers keep their progress).

The scheduler is host-side control logic over :class:`~repro.serving.kv.
BlockPoolKV` — no jax imports — so policies are unit-testable in
microseconds.  The engine executes the plans it returns.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools

import numpy as np

from .kv import BlockPoolKV


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # int32 tokens, may grow on eviction
    priority: int = 0                  # larger = more urgent
    arrival: int = 0                   # submit order (FIFO tie-break)
    phase: Phase = Phase.WAITING
    slot: int = -1
    prefill_pos: int = 0               # tokens of prompt already cached
    generated: list[int] = dataclasses.field(default_factory=list)
    history: list[int] = dataclasses.field(default_factory=list)
    # ^ tokens generated before a preemption (folded into the prompt for
    #   recompute; still part of the request's output)
    max_new_tokens: int = 0
    preemptions: int = 0

    @property
    def n_generated(self) -> int:
        return len(self.history) + len(self.generated)

    @property
    def output(self) -> list[int]:
        return self.history + self.generated

    @property
    def total_len(self) -> int:
        return len(self.prompt) + self.max_new_tokens - len(self.history)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    num_slots: int
    prefill_chunk: int = 32            # tokens per prefill call
    prefill_token_budget: int = 64     # prefill tokens per tick, all reqs
    decode_headroom_pages: int = 1     # reserved beyond the prompt at admit


@dataclasses.dataclass
class PrefillJob:
    req: Request
    start: int                         # chunk start within req.prompt
    count: int                         # valid tokens in this chunk


class PhaseScheduler:
    """Owns the request lifecycle; the engine owns the device arrays."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self._waiting: list[tuple[int, int, Request]] = []   # priority heap
        self._active: dict[int, Request] = {}                # slot -> req
        self._tie = itertools.count()

    # -- intake -------------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.phase = Phase.WAITING
        heapq.heappush(self._waiting, (-req.priority, next(self._tie), req))

    @property
    def has_work(self) -> bool:
        return bool(self._waiting or self._active)

    def active(self) -> list[Request]:
        return list(self._active.values())

    def decoding(self) -> list[Request]:
        return [r for r in self._active.values() if r.phase is Phase.DECODE]

    # -- admission + preemption ---------------------------------------------

    def _evictable_below(self, priority: int) -> Request | None:
        """Lowest-priority active request strictly below ``priority``
        (latest arrival breaks ties — it has the least sunk work)."""
        cands = [r for r in self._active.values() if r.priority < priority]
        if not cands:
            return None
        return min(cands, key=lambda r: (r.priority, -r.arrival))

    def _evict(self, kv: BlockPoolKV, req: Request) -> None:
        kv.free_slot(req.slot, evicted=True)
        del self._active[req.slot]
        # recompute-on-readmission: generated tokens become prompt suffix
        if req.generated:
            req.prompt = np.concatenate(
                [req.prompt,
                 np.asarray(req.generated, req.prompt.dtype)])
            req.history.extend(req.generated)
            req.generated = []
        req.slot = -1
        req.prefill_pos = 0
        req.preemptions += 1
        self.submit(req)

    def admit(self, kv: BlockPoolKV) -> list[Request]:
        """Admit waiting requests in priority order; may evict lower-
        priority active requests when the pool is the binding constraint.
        Returns the newly admitted requests (now in PREFILL phase)."""
        admitted = []
        while self._waiting:
            _, _, req = self._waiting[0]
            need = kv.pages_for(len(req.prompt)) + \
                self.cfg.decode_headroom_pages
            # page pressure: evict strictly-lower-priority work first
            while (not kv.can_alloc(need)) or \
                    (len(self._active) >= self.cfg.num_slots):
                victim = self._evictable_below(req.priority)
                if victim is None:
                    break
                self._evict(kv, victim)
            if not kv.can_alloc(need) or \
                    len(self._active) >= self.cfg.num_slots:
                break
            heapq.heappop(self._waiting)
            slot = next(i for i in range(self.cfg.num_slots)
                        if i not in self._active)
            kv.ensure(slot, len(req.prompt) +
                      self.cfg.decode_headroom_pages * kv.cfg.page_size)
            req.slot = slot
            req.phase = Phase.PREFILL
            req.prefill_pos = 0
            self._active[slot] = req
            admitted.append(req)
        return admitted

    # -- prefill phase ------------------------------------------------------

    def prefill_jobs(self) -> list[PrefillJob]:
        """This tick's chunked prefill work, oldest-admission first, capped
        by the token budget.  One chunk per request per tick keeps a long
        prompt from monopolizing the budget."""
        jobs, budget = [], self.cfg.prefill_token_budget
        for req in sorted((r for r in self._active.values()
                           if r.phase is Phase.PREFILL),
                          key=lambda r: r.arrival):
            if budget <= 0:
                break
            count = min(self.cfg.prefill_chunk,
                        len(req.prompt) - req.prefill_pos, budget)
            if count <= 0:
                continue
            jobs.append(PrefillJob(req=req, start=req.prefill_pos,
                                   count=count))
            budget -= count
        return jobs

    def finish_prefill_chunk(self, req: Request, count: int) -> None:
        req.prefill_pos += count
        if req.prefill_pos >= len(req.prompt):
            req.phase = Phase.DECODE

    # -- decode phase -------------------------------------------------------

    def ensure_decode_pages(self, kv: BlockPoolKV) -> list[Request]:
        """Map the next page for every decoding slot about to cross a page
        boundary; evicts lowest-priority work under page pressure (the
        needy slot itself evicts when IT is the lowest).  Returns evicted
        requests."""
        evicted = []
        for req in sorted(self.decoding(),
                          key=lambda r: (-r.priority, r.arrival)):
            if req.slot not in self._active:      # already evicted this tick
                continue
            target = int(kv.lengths[req.slot]) + 1
            while True:
                try:
                    kv.ensure(req.slot, target)
                    break
                except MemoryError:
                    # strictly-lower-priority work goes first; when none
                    # exists the needy slot evicts ITSELF (equal-priority
                    # peers are never targeted, per the admission contract)
                    victim = self._evictable_below(req.priority) or req
                    self._evict(kv, victim)
                    evicted.append(victim)
                    if victim is req:
                        break
        return evicted

    def finish(self, kv: BlockPoolKV, req: Request) -> None:
        kv.free_slot(req.slot)
        del self._active[req.slot]
        req.phase = Phase.FINISHED
        req.slot = -1
