"""Phase-aware request scheduler: prefill/decode disaggregation + priority
admission + preemption by page pressure.

Replaces the seed engine's FIFO slot round-robin with three explicit
phases per request:

  WAITING  -> admission by (priority desc, arrival asc); a request is only
              admitted when a slot is free AND the block pool can map its
              whole prompt (plus one decode page of headroom).  Admission
              CONSULTS THE PREFIX CACHE (``serving.prefix``) when one is
              wired in: the longest cached prefix of the prompt is mapped
              as read-only shared pages (plus an optional copy-on-write
              page when the match ends mid-page), the slot's length starts
              at the matched token count, and prefill covers only the
              SUFFIX — a cache-hit request skips straight past its
              matched prefix into chunked prefill of the rest.
  PREFILL  -> the prompt is consumed in fixed-size CHUNKS, budgeted per
              tick (``prefill_token_budget``), so one long prompt cannot
              starve the decode pool — the serving analogue of
              prefill/decode disaggregation.  Chunks of different requests
              interleave across ticks.
  DECODE   -> the whole slot pool advances one token per tick (one jitted
              SPMD step regardless of occupancy, as before).

Preemption: when the pool runs dry — either a high-priority arrival can't
be admitted or a decoding slot needs its next page — cold prefix-cache
pages are reclaimed FIRST (``BlockPoolKV.reserve`` runs the trie's
leaf-first LRU eviction hook); only then is the LOWEST-priority active
request evicted: its page REFERENCES are dropped (shared prefix pages
only decref — pages still held by the trie or a peer request survive; see
``BlockPoolKV.free_slot``) and the request re-enters WAITING with its
generated tokens folded into the prompt (vLLM-style recompute on
re-admission).  Eviction never targets ANOTHER
request with priority >= the one that needs the pages; when no strictly
lower-priority victim exists, a decoding slot that cannot grow evicts
ITSELF (equal-priority peers keep their progress).

Graceful degradation (all off by default — the seed behaviour is the
zero-config path):

  * deadlines — a request may carry ``deadline_tick``;
    :meth:`PhaseScheduler.expire_deadlines` evicts it (waiting OR active)
    once the engine's tick clock passes it, returning pages to the pool so
    one stuck request cannot hold capacity forever;
  * bounded admission retry with backoff — when ``admission_backoff`` is
    set, a request that fails admission stops blocking the queue head
    (lower-priority work behind it may fit) and retries after an
    exponentially growing hold-off; after ``max_admission_retries``
    failures it is SHED (``drain_shed``) instead of waiting forever;
  * load shedding — :meth:`shed_waiting` drops queued sub-priority work
    wholesale; the engine invokes it when pool pressure stays critical.

The scheduler is host-side control logic over :class:`~repro.serving.kv.
BlockPoolKV` — no jax imports — so policies are unit-testable in
microseconds.  The engine executes the plans it returns.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools

import numpy as np

from .kv import BlockPoolKV


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # int32 tokens, may grow on eviction
    priority: int = 0                  # larger = more urgent
    arrival: int = 0                   # submit order (FIFO tie-break)
    phase: Phase = Phase.WAITING
    slot: int = -1
    prefill_pos: int = 0               # tokens of prompt already cached
    generated: list[int] = dataclasses.field(default_factory=list)
    history: list[int] = dataclasses.field(default_factory=list)
    # ^ tokens generated before a preemption (folded into the prompt for
    #   recompute; still part of the request's output)
    max_new_tokens: int = 0
    preemptions: int = 0
    deadline_tick: int | None = None   # evict once engine tick passes this
    admit_attempts: int = 0            # failed admission tries so far
    next_admit_tick: int = 0           # backoff: don't retry before this
    cow: tuple[int, int, int] | None = None
    # ^ pending copy-on-write from a mid-page prefix-cache match:
    #   (src page, dst page, valid tokens) — the ENGINE executes the
    #   device copy before the request's first prefill chunk
    matched_tokens: int = 0            # prefix-cache tokens served for free

    @property
    def n_generated(self) -> int:
        return len(self.history) + len(self.generated)

    @property
    def output(self) -> list[int]:
        return self.history + self.generated

    @property
    def total_len(self) -> int:
        return len(self.prompt) + self.max_new_tokens - len(self.history)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    num_slots: int
    prefill_chunk: int = 32            # tokens per prefill call
    prefill_token_budget: int = 64     # prefill tokens per tick, all reqs
    decode_headroom_pages: int = 1     # reserved beyond the prompt at admit
    max_admission_retries: int = 0     # 0 = retry forever (seed behaviour)
    admission_backoff: int = 0         # base hold-off ticks; 0 = no backoff


@dataclasses.dataclass
class PrefillJob:
    req: Request
    start: int                         # chunk start within req.prompt
    count: int                         # valid tokens in this chunk


class PhaseScheduler:
    """Owns the request lifecycle; the engine owns the device arrays."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self._waiting: list[tuple[int, int, Request]] = []   # priority heap
        self._active: dict[int, Request] = {}                # slot -> req
        self._tie = itertools.count()
        self._shed: list[Request] = []     # retry budget blown / load shed

    # -- intake -------------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.phase = Phase.WAITING
        heapq.heappush(self._waiting, (-req.priority, next(self._tie), req))

    @property
    def has_work(self) -> bool:
        return bool(self._waiting or self._active)

    def active(self) -> list[Request]:
        return list(self._active.values())

    def decoding(self) -> list[Request]:
        return [r for r in self._active.values() if r.phase is Phase.DECODE]

    # -- admission + preemption ---------------------------------------------

    def _evictable_below(self, priority: int) -> Request | None:
        """Lowest-priority active request strictly below ``priority``
        (latest arrival breaks ties — it has the least sunk work)."""
        cands = [r for r in self._active.values() if r.priority < priority]
        if not cands:
            return None
        return min(cands, key=lambda r: (r.priority, -r.arrival))

    def _evict(self, kv: BlockPoolKV, req: Request) -> None:
        self._drop_cow(kv, req)
        kv.free_slot(req.slot, evicted=True)
        del self._active[req.slot]
        # recompute-on-readmission: generated tokens become prompt suffix
        if req.generated:
            req.prompt = np.concatenate(
                [req.prompt,
                 np.asarray(req.generated, req.prompt.dtype)])
            req.history.extend(req.generated)
            req.generated = []
        req.slot = -1
        req.prefill_pos = 0
        req.matched_tokens = 0
        req.preemptions += 1
        self.submit(req)

    def admit(self, kv: BlockPoolKV, *, now: int = 0,
              prefix=None) -> list[Request]:
        """Admit waiting requests in priority order; may evict lower-
        priority active requests when the pool is the binding constraint.
        Returns the newly admitted requests (now in PREFILL phase).

        ``prefix`` (a :class:`~repro.serving.prefix.RadixPrefixCache`)
        lets admission skip cached work: matched full pages are mapped
        shared, only the suffix needs private pages, and the request's
        ``prefill_pos``/slot length start at the matched token count.  A
        mid-page match is planned as a COW job on ``req.cow`` for the
        engine.  Page pressure drains cold cache pages (``kv.reserve``'s
        reclaim hook) before any live request is preempted.

        With ``admission_backoff``/``max_admission_retries`` configured, a
        request that fails admission no longer blocks the queue head: it is
        held off for ``admission_backoff * 2**(attempts-1)`` ticks (so the
        next-priority request gets a try) and shed outright once its retry
        budget is exhausted.  With both at 0 the seed head-of-line
        behaviour is preserved exactly."""
        retrying = (self.cfg.admission_backoff > 0
                    or self.cfg.max_admission_retries > 0)
        admitted: list[Request] = []
        deferred: list[tuple[int, int, Request]] = []
        while self._waiting:
            item = heapq.heappop(self._waiting)
            _, _, req = item
            if req.phase is not Phase.WAITING:   # expired while queued
                continue
            if req.next_admit_tick > now:        # backing off
                deferred.append(item)
                continue
            match = prefix.match(req.prompt) if prefix is not None else None
            shared = list(match.full_pages) if match is not None else []
            # PIN the matched pages (and a COW source) for the duration of
            # this attempt: the reclaim hook below must not evict the very
            # pages the match promised
            pinned = list(shared)
            if match is not None and match.cow is not None:
                pinned.append(match.cow[0])
            for p in pinned:
                kv.retain(p)
            need = kv.pages_for(len(req.prompt)) - len(shared) + \
                self.cfg.decode_headroom_pages
            # page pressure: reclaim cold cache pages (reserve's hook),
            # then evict strictly-lower-priority work
            while (not kv.reserve(need)) or \
                    (len(self._active) >= self.cfg.num_slots):
                victim = self._evictable_below(req.priority)
                if victim is None:
                    break
                self._evict(kv, victim)
            if not kv.reserve(need) or \
                    len(self._active) >= self.cfg.num_slots:
                for p in pinned:
                    kv.release(p)
                if not retrying:
                    deferred.append(item)
                    break                        # seed: head blocks
                req.admit_attempts += 1
                if 0 < self.cfg.max_admission_retries < req.admit_attempts:
                    req.phase = Phase.FINISHED   # retry budget blown: shed
                    self._shed.append(req)
                else:
                    req.next_admit_tick = now + max(
                        1, self.cfg.admission_backoff) * \
                        2 ** (req.admit_attempts - 1)
                    deferred.append(item)
                continue
            slot = next(i for i in range(self.cfg.num_slots)
                        if i not in self._active)
            if shared:
                kv.map_shared(slot, shared)
            kv.ensure(slot, len(req.prompt) +
                      self.cfg.decode_headroom_pages * kv.cfg.page_size)
            matched = match.matched if match is not None else 0
            kv.set_length(slot, matched)
            req.matched_tokens = matched
            if match is not None and match.cow is not None:
                # the COW source keeps ITS pin until the engine copies it
                # (consume_cow / _drop_cow release); the destination is
                # the request's first private page
                src, n_valid = match.cow
                dst = int(kv.page_table[slot, len(shared)])
                req.cow = (src, dst, n_valid)
                pinned.remove(src)
            for p in pinned:
                kv.release(p)        # slot mapping holds its own reference
            req.slot = slot
            req.phase = Phase.PREFILL
            req.prefill_pos = matched
            req.admit_attempts = 0
            self._active[slot] = req
            admitted.append(req)
        for item in deferred:
            heapq.heappush(self._waiting, item)
        return admitted

    @staticmethod
    def _drop_cow(kv: BlockPoolKV, req: Request) -> None:
        """Release a pending COW job's pin on its source page (the job is
        consumed by the engine's copy, or abandoned on evict/expiry)."""
        if req.cow is not None:
            kv.release(req.cow[0])
            req.cow = None

    # -- degradation: deadlines, shedding -----------------------------------

    def expire_deadlines(self, kv: BlockPoolKV, now: int) -> list[Request]:
        """Evict every request whose deadline has passed — active slots
        release their pages immediately (a stuck request must not hold
        capacity), waiting entries are dropped from the queue.  Returns the
        expired requests; the engine records their partial output."""
        expired: list[Request] = []
        for req in list(self._active.values()):
            if req.deadline_tick is not None and now >= req.deadline_tick:
                self._drop_cow(kv, req)
                kv.free_slot(req.slot, evicted=True)
                del self._active[req.slot]
                req.slot = -1
                req.phase = Phase.FINISHED
                expired.append(req)
        for _, _, req in self._waiting:
            if req.phase is Phase.WAITING and req.deadline_tick is not None \
                    and now >= req.deadline_tick:
                req.phase = Phase.FINISHED
                expired.append(req)
        if expired:
            self._waiting = [it for it in self._waiting
                             if it[2].phase is Phase.WAITING]
            heapq.heapify(self._waiting)
        return expired

    def cancel(self, kv: BlockPoolKV, rid: int) -> Request | None:
        """Withdraw one request wherever it lives: an active slot releases
        its pages (shared prefix pages only decref — the trie and peer
        slots keep theirs), a waiting entry leaves the queue.  The fleet's
        hedged dispatch uses this to retire the losing twin once the first
        copy finishes.  Returns the cancelled request, or None when the
        rid is unknown or already finished."""
        for req in list(self._active.values()):
            if req.rid == rid:
                self._drop_cow(kv, req)
                kv.free_slot(req.slot, evicted=True)
                del self._active[req.slot]
                req.slot = -1
                req.phase = Phase.FINISHED
                return req
        for _, _, req in self._waiting:
            if req.rid == rid and req.phase is Phase.WAITING:
                req.phase = Phase.FINISHED
                self._waiting = [it for it in self._waiting
                                 if it[2].phase is Phase.WAITING]
                heapq.heapify(self._waiting)
                return req
        return None

    def shed_waiting(self, *, below_priority: int) -> list[Request]:
        """Load-shed mode: drop every WAITING request with priority below
        the floor (admitted work keeps running — shedding protects the
        requests already holding pages)."""
        dropped = [req for _, _, req in self._waiting
                   if req.phase is Phase.WAITING
                   and req.priority < below_priority]
        for req in dropped:
            req.phase = Phase.FINISHED
        if dropped:
            self._waiting = [it for it in self._waiting
                             if it[2].phase is Phase.WAITING]
            heapq.heapify(self._waiting)
        self._shed.extend(dropped)
        return dropped

    def drain_shed(self) -> list[Request]:
        """Requests shed since the last drain (retry budget or load shed)."""
        out, self._shed = self._shed, []
        return out

    # -- prefill phase ------------------------------------------------------

    def prefill_jobs(self) -> list[PrefillJob]:
        """This tick's chunked prefill work, oldest-admission first, capped
        by the token budget.  One chunk per request per tick keeps a long
        prompt from monopolizing the budget."""
        jobs, budget = [], self.cfg.prefill_token_budget
        for req in sorted((r for r in self._active.values()
                           if r.phase is Phase.PREFILL),
                          key=lambda r: r.arrival):
            if budget <= 0:
                break
            count = min(self.cfg.prefill_chunk,
                        len(req.prompt) - req.prefill_pos, budget)
            if count <= 0:
                continue
            jobs.append(PrefillJob(req=req, start=req.prefill_pos,
                                   count=count))
            budget -= count
        return jobs

    def finish_prefill_chunk(self, req: Request, count: int) -> None:
        req.prefill_pos += count
        if req.prefill_pos >= len(req.prompt):
            req.phase = Phase.DECODE

    # -- decode phase -------------------------------------------------------

    def ensure_decode_pages(self, kv: BlockPoolKV) -> list[Request]:
        """Map the next page for every decoding slot about to cross a page
        boundary; evicts lowest-priority work under page pressure (the
        needy slot itself evicts when IT is the lowest).  Returns evicted
        requests."""
        evicted = []
        for req in sorted(self.decoding(),
                          key=lambda r: (-r.priority, r.arrival)):
            if req.slot not in self._active:      # already evicted this tick
                continue
            target = int(kv.lengths[req.slot]) + 1
            while True:
                try:
                    kv.ensure(req.slot, target)
                    break
                except MemoryError:
                    # strictly-lower-priority work goes first; when none
                    # exists the needy slot evicts ITSELF (equal-priority
                    # peers are never targeted, per the admission contract)
                    victim = self._evictable_below(req.priority) or req
                    self._evict(kv, victim)
                    evicted.append(victim)
                    if victim is req:
                        break
        return evicted

    def finish(self, kv: BlockPoolKV, req: Request) -> None:
        kv.free_slot(req.slot)
        del self._active[req.slot]
        req.phase = Phase.FINISHED
        req.slot = -1
