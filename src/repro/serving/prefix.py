"""Radix prefix cache: token-keyed trie over the block pool's KV pages.

The paper's FIFO-mesh thesis — promote LOCAL data to GLOBAL visibility so
nobody re-fetches it — applied to KV pages: the tokens of a shared system
prompt or few-shot preamble are prefilled ONCE, and every later request
whose prompt starts with the same tokens maps the already-computed pages
read-only instead of recomputing them.  The trie is the visibility
fabric: each node owns one page of the pool keyed by the page's token
content, a root-to-node path spells a cached prefix, and refcounts on the
underlying :class:`~repro.serving.kv.BlockPoolKV` pages tie the trie's
holdings into the pool's free-list accounting.

Sharing granularity:

  * a FULL page whose tokens exactly match the next ``page_size`` prompt
    tokens is mapped directly into the requesting slot (refcount + 1, the
    slot never writes inside it);
  * a PARTIAL match — the trie page's tokens and the prompt diverge
    mid-page, or the prompt (capped at ``len - 1``; the last token is
    always recomputed so admission has logits to sample from) ends inside
    the page — is served by COPY-ON-WRITE: the engine copies the page's
    KV into a fresh private page and the request prefills from the
    divergence offset.  A shared page is never mutated.

Lifetime: a finishing request INSERTS its computed pages (prompt +
generated tokens) into the trie, which takes one reference per adopted
page; the pages then survive the request until page pressure reclaims
them.  Eviction is LEAF-FIRST by least-recent-use and only touches pages
no live slot maps (refcount 1, held by the trie alone) — it is registered
as the pool's ``reclaim_hook`` so allocation pressure drains the cache
before anyone preempts a live request.

Like the scheduler, this module is jax-free host-side control logic: it
plans COW copies (src page, valid tokens) but the ENGINE executes them on
the device arrays.  Invariants are property-tested in
tests/test_prefix.py.
"""
from __future__ import annotations

import dataclasses
import itertools

from .kv import BlockPoolKV


class _Node:
    """One cached page: ``tokens`` (the page's token content, possibly a
    partial tail) + the physical ``page`` holding their KV.

    ``owner_host`` is only meaningful in the fleet's
    :class:`PageOwnershipDirectory`, where ``page`` is unused (the
    directory tracks WHICH HOST holds a prefix, not which pool page);
    single-host tries leave it at -1."""
    __slots__ = ("tokens", "page", "parent", "children", "last_use",
                 "owner_host")

    def __init__(self, tokens: tuple[int, ...], page: int,
                 parent: "_Node | None", owner_host: int = -1):
        self.tokens = tokens
        self.page = page
        self.parent = parent
        self.children: dict[tuple[int, ...], _Node] = {}
        self.last_use = 0
        self.owner_host = owner_host

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Admission plan for one prompt lookup.

    ``full_pages`` are mapped read-only (shared); ``cow`` = (source page,
    valid tokens) asks the engine to copy that page into the request's
    first private page before prefill.  ``matched`` tokens of KV arrive
    for free; prefill starts there (mid-page when ``cow`` is set)."""
    full_pages: tuple[int, ...] = ()
    matched_full: int = 0              # tokens covered by full_pages
    cow: tuple[int, int] | None = None  # (src page, valid tokens)

    @property
    def matched(self) -> int:
        return self.matched_full + (self.cow[1] if self.cow else 0)

    @property
    def hit(self) -> bool:
        return self.matched > 0


def _common_prefix(a, b) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class RadixPrefixCache:
    """Page-granular radix trie over ``kv``'s pool; registers itself as
    the pool's ``reclaim_hook``."""

    def __init__(self, kv: BlockPoolKV):
        self.kv = kv
        self.page_size = kv.cfg.page_size
        self.root = _Node((), BlockPoolKV.TRASH, None)
        self._clock = itertools.count(1)
        # counters (surfaced by stats(); bench_traffic reports them)
        self.lookups = 0
        self.hits = 0
        self.matched_tokens = 0
        self.matched_pages = 0          # full shared-page mappings
        self.cow_count = 0
        self.inserted_pages = 0
        self.evicted_pages = 0
        kv.reclaim_hook = self.evict

    # -- lookup -------------------------------------------------------------

    def match(self, tokens) -> PrefixMatch:
        """Longest cached prefix of ``tokens``, capped at ``len - 1`` so
        at least one prompt token is always prefilled (admission needs
        fresh last-token logits to sample the first output from)."""
        tokens = [int(t) for t in tokens]
        usable = len(tokens) - 1
        self.lookups += 1
        node, pos, pages = self.root, 0, []
        now = next(self._clock)
        while usable - pos >= self.page_size:
            child = node.children.get(tuple(tokens[pos:pos + self.page_size]))
            if child is None or child.n_tokens < self.page_size:
                break
            child.last_use = now
            pages.append(child.page)
            pos += self.page_size
            node = child
        cow = None
        if pos < usable:
            # best mid-page overlap among this node's children -> COW
            best, best_n = None, 0
            for child in node.children.values():
                n = _common_prefix(child.tokens, tokens[pos:usable])
                if n > best_n:
                    best, best_n = child, n
            if best is not None:
                best.last_use = now
                cow = (best.page, best_n)
                self.cow_count += 1
        m = PrefixMatch(full_pages=tuple(pages), matched_full=pos, cow=cow)
        if m.hit:
            self.hits += 1
            self.matched_tokens += m.matched
            self.matched_pages += len(pages)
        return m

    # -- insert -------------------------------------------------------------

    def insert(self, tokens, pages, n_tokens: int) -> int:
        """Adopt a finished request's cached sequence into the trie.

        ``tokens``: the request's full token stream (prompt + generated);
        ``pages``: the slot's page table entries covering it; only the
        first ``n_tokens`` are actually cached (the final sampled token's
        KV was never written).  Pages whose content is already in the trie
        are skipped (they stay slot-owned and free on release); new pages
        are RETAINED by the trie and survive the slot.  Returns the number
        of pages adopted."""
        tokens = [int(t) for t in tokens]
        node, pos, idx, adopted = self.root, 0, 0, 0
        now = next(self._clock)
        while pos < n_tokens:
            n = min(self.page_size, n_tokens - pos)
            seg = tuple(tokens[pos:pos + n])
            child = node.children.get(seg)
            if child is not None:
                child.last_use = now
                node, pos, idx = child, pos + n, idx + 1
                continue
            if n < self.page_size and any(
                    ch.tokens[:n] == seg for ch in node.children.values()):
                break   # a cached page already subsumes this partial tail
            page = int(pages[idx])
            self.kv.retain(page)
            new = _Node(seg, page, node)
            node.children[seg] = new
            new.last_use = now
            node, pos, idx = new, pos + n, idx + 1
            adopted += 1
        self.inserted_pages += adopted
        return adopted

    # -- fleet migration support -------------------------------------------

    def path_nodes(self, tokens, n_tokens: int) -> list["_Node"]:
        """The trie nodes spelling the first ``n_tokens`` of ``tokens``
        as FULL pages (migration source: these pages' KV gets exported).
        Stops at the first missing or partial page."""
        tokens = [int(t) for t in tokens]
        node, pos, out = self.root, 0, []
        while pos + self.page_size <= n_tokens:
            child = node.children.get(tuple(tokens[pos:pos + self.page_size]))
            if child is None or child.n_tokens < self.page_size:
                break
            out.append(child)
            node, pos = child, pos + self.page_size
        return out

    def adopt_segment(self, node: "_Node | None", seg: tuple[int, ...],
                      page: int) -> "_Node":
        """Graft one imported full page under ``node`` (None = root).
        The trie takes over the caller's reference to ``page`` (the
        importer allocated it via ``kv.adopt_page`` — no extra retain)."""
        parent = node or self.root
        if seg in parent.children:
            raise ValueError(f"segment {seg[:4]}... already cached")
        new = _Node(seg, page, parent)
        new.last_use = next(self._clock)
        parent.children[seg] = new
        self.inserted_pages += 1
        return new

    def drop_path(self, tokens, n_tokens: int) -> int:
        """Release the full-page path for ``tokens[:n_tokens]`` bottom-up
        (migration source, after a successful transfer: ownership moved,
        so the local copy is dropped).  Only nodes that are leaves with no
        other holder (refcount 1) are dropped — a path still feeding live
        slots or deeper cache entries survives.  Returns pages dropped."""
        dropped = 0
        for node in reversed(self.path_nodes(tokens, n_tokens)):
            if node.children or self.kv.refcount[node.page] != 1:
                break
            self.kv.release(node.page)
            del node.parent.children[node.tokens]
            dropped += 1
            self.evicted_pages += 1
        return dropped

    # -- eviction (the pool's reclaim hook) ---------------------------------

    def evict(self, n_pages: int) -> int:
        """Free at least ``n_pages`` by dropping trie leaves no live slot
        maps (page refcount 1 — held by the trie alone), least-recently
        used first.  Interior nodes become evictable as their subtrees
        drain, so the cache sheds leaf-first along cold paths.  Returns
        the number of pages actually freed."""
        freed = 0
        while freed < n_pages:
            victim = None
            for node in self._leaves():
                if self.kv.refcount[node.page] != 1:
                    continue        # pinned by a live slot
                if victim is None or node.last_use < victim.last_use:
                    victim = node
            if victim is None:
                break
            self.kv.release(victim.page)
            del victim.parent.children[victim.tokens]
            freed += 1
            self.evicted_pages += 1
        return freed

    def _leaves(self):
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            else:
                yield node

    # -- introspection ------------------------------------------------------

    def page_refs(self) -> dict[int, int]:
        """page -> number of trie references (for invariant audits:
        pool refcount == slot mappings + these)."""
        refs: dict[int, int] = {}
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            refs[node.page] = refs.get(node.page, 0) + 1
            stack.extend(node.children.values())
        return refs

    @property
    def n_pages(self) -> int:
        return len(self.page_refs())

    def stats(self) -> dict:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / self.lookups if self.lookups else 0.0,
            "matched_tokens": self.matched_tokens,
            "matched_pages": self.matched_pages,
            "cow_count": self.cow_count,
            "inserted_pages": self.inserted_pages,
            "evicted_pages": self.evicted_pages,
            "pages_held": self.n_pages,
        }

    def check_invariants(self) -> None:
        """Structural audit: page-aligned runs, no orphaned references,
        every non-tail node holds a full page."""
        stack = [(self.root, True)]
        while stack:
            node, _ = stack.pop()
            for child in node.children.values():
                assert child.parent is node
                assert 1 <= child.n_tokens <= self.page_size
                if child.children:
                    assert child.n_tokens == self.page_size, \
                        "interior trie node with a partial page"
                assert self.kv.refcount[child.page] >= 1, \
                    f"trie holds unreferenced page {child.page}"
                stack.append((child, False))
        self.kv.check_invariants(external_refs=self.page_refs())


@dataclasses.dataclass(frozen=True)
class DirectoryMatch:
    """One directory lookup: the longest run of full prompt pages with a
    LIVE owner, as parallel (token segment, owner host) tuples.  Matching
    stops at the first unpublished or tombstoned page, so ``segments`` is
    always the longest-SURVIVING-ancestor run the recovery path needs."""
    segments: tuple[tuple[int, ...], ...] = ()
    owners: tuple[int, ...] = ()

    @property
    def matched(self) -> int:
        return sum(len(s) for s in self.segments)

    @property
    def hit(self) -> bool:
        return bool(self.segments)


class PageOwnershipDirectory:
    """Router-side map from token prefixes to the host that OWNS their KV
    pages — the fleet analogue of the radix trie, with ``owner_host`` in
    place of a pool page.

    This is the paper's promote-local-to-global story one level up: each
    host's prefix cache is its local SRAM tile, and the directory is the
    mesh fabric that makes a page globally addressable without replicating
    it — a prefix is owned ONCE, and a request landing on another host
    triggers a point-to-point page migration instead of a re-prefill.

    Ownership rules:
      * first live publisher wins (``publish`` never steals from a live
        owner — pages are owned once);
      * a host death TOMBSTONES its entries (``tombstone_host``): the
        nodes stay so the structure under them is preserved, but lookups
        stop at them, which yields recompute-from-longest-surviving-
        ancestor for free;
      * a successful migration calls ``transfer`` to move ownership of
        the migrated path to the destination host;
      * re-publishing over a tombstoned entry revives it under the new
        owner (a survivor recomputed the prefix).
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _Node((), BlockPoolKV.TRASH, None)
        self.dead: set[int] = set()
        self._clock = itertools.count(1)
        self.lookups = 0
        self.hits = 0
        self.matched_tokens = 0
        self.published_pages = 0
        self.transferred_pages = 0
        self.tombstoned_pages = 0
        self.revived_pages = 0

    def _walk(self, tokens, limit: int):
        """Yield (node, segment) for each FULL page of ``tokens[:limit]``
        present in the directory, live or dead."""
        tokens = [int(t) for t in tokens]
        node, pos = self.root, 0
        while pos + self.page_size <= limit:
            seg = tuple(tokens[pos:pos + self.page_size])
            child = node.children.get(seg)
            if child is None:
                return
            yield child, seg
            node, pos = child, pos + self.page_size

    def publish(self, tokens, host: int, n_tokens: int | None = None) -> int:
        """Record ``host`` as owner of the full pages of
        ``tokens[:n_tokens]``.  Existing live entries keep their owner;
        tombstoned entries are revived under ``host``.  Returns the
        number of pages newly owned by ``host``."""
        if host in self.dead:
            raise ValueError(f"publish from tombstoned host {host}")
        tokens = [int(t) for t in tokens]
        limit = len(tokens) if n_tokens is None else n_tokens
        node, pos, owned, now = self.root, 0, 0, next(self._clock)
        while pos + self.page_size <= limit:
            seg = tuple(tokens[pos:pos + self.page_size])
            child = node.children.get(seg)
            if child is None:
                child = _Node(seg, BlockPoolKV.TRASH, node, owner_host=host)
                node.children[seg] = child
                self.published_pages += 1
                owned += 1
            elif child.owner_host in self.dead:
                child.owner_host = host
                self.revived_pages += 1
                owned += 1
            child.last_use = now
            node, pos = child, pos + self.page_size
        return owned

    def lookup(self, tokens) -> DirectoryMatch:
        """Longest live-owned full-page prefix of ``tokens``, capped at
        ``len - 1`` (same rule as the local trie: the last prompt token is
        always recomputed so admission has logits)."""
        self.lookups += 1
        segs, owners, now = [], [], next(self._clock)
        for node, seg in self._walk(tokens, len(tokens) - 1):
            if node.owner_host in self.dead:
                break
            node.last_use = now
            segs.append(seg)
            owners.append(node.owner_host)
        m = DirectoryMatch(segments=tuple(segs), owners=tuple(owners))
        if m.hit:
            self.hits += 1
            self.matched_tokens += m.matched
        return m

    def tombstone_host(self, host: int) -> int:
        """Mark every entry owned by ``host`` dead (host loss).  The
        nodes stay in place — children of a tombstoned page published by
        survivors stay reachable once the dead link is re-published."""
        self.dead.add(host)
        n = sum(1 for node in self._nodes() if node.owner_host == host)
        self.tombstoned_pages += n
        return n

    def transfer(self, tokens, n_tokens: int, new_host: int) -> int:
        """Reassign ownership of the full pages of ``tokens[:n_tokens]``
        to ``new_host`` (after a successful migration)."""
        if new_host in self.dead:
            raise ValueError(f"transfer to tombstoned host {new_host}")
        moved = 0
        for node, _ in self._walk(tokens, n_tokens):
            if node.owner_host != new_host:
                node.owner_host = new_host
                moved += 1
        self.transferred_pages += moved
        return moved

    def _nodes(self):
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            yield node

    def owners(self) -> dict[int, int]:
        """host -> live directory pages owned (tombstoned hosts excluded)."""
        out: dict[int, int] = {}
        for node in self._nodes():
            if node.owner_host not in self.dead:
                out[node.owner_host] = out.get(node.owner_host, 0) + 1
        return out

    def stats(self) -> dict:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / self.lookups if self.lookups else 0.0,
            "matched_tokens": self.matched_tokens,
            "published_pages": self.published_pages,
            "transferred_pages": self.transferred_pages,
            "tombstoned_pages": self.tombstoned_pages,
            "revived_pages": self.revived_pages,
            "live_pages": sum(self.owners().values()),
        }
