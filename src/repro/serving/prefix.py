"""Radix prefix cache: token-keyed trie over the block pool's KV pages.

The paper's FIFO-mesh thesis — promote LOCAL data to GLOBAL visibility so
nobody re-fetches it — applied to KV pages: the tokens of a shared system
prompt or few-shot preamble are prefilled ONCE, and every later request
whose prompt starts with the same tokens maps the already-computed pages
read-only instead of recomputing them.  The trie is the visibility
fabric: each node owns one page of the pool keyed by the page's token
content, a root-to-node path spells a cached prefix, and refcounts on the
underlying :class:`~repro.serving.kv.BlockPoolKV` pages tie the trie's
holdings into the pool's free-list accounting.

Sharing granularity:

  * a FULL page whose tokens exactly match the next ``page_size`` prompt
    tokens is mapped directly into the requesting slot (refcount + 1, the
    slot never writes inside it);
  * a PARTIAL match — the trie page's tokens and the prompt diverge
    mid-page, or the prompt (capped at ``len - 1``; the last token is
    always recomputed so admission has logits to sample from) ends inside
    the page — is served by COPY-ON-WRITE: the engine copies the page's
    KV into a fresh private page and the request prefills from the
    divergence offset.  A shared page is never mutated.

Lifetime: a finishing request INSERTS its computed pages (prompt +
generated tokens) into the trie, which takes one reference per adopted
page; the pages then survive the request until page pressure reclaims
them.  Eviction is LEAF-FIRST by least-recent-use and only touches pages
no live slot maps (refcount 1, held by the trie alone) — it is registered
as the pool's ``reclaim_hook`` so allocation pressure drains the cache
before anyone preempts a live request.

Like the scheduler, this module is jax-free host-side control logic: it
plans COW copies (src page, valid tokens) but the ENGINE executes them on
the device arrays.  Invariants are property-tested in
tests/test_prefix.py.
"""
from __future__ import annotations

import dataclasses
import itertools

from .kv import BlockPoolKV


class _Node:
    """One cached page: ``tokens`` (the page's token content, possibly a
    partial tail) + the physical ``page`` holding their KV."""
    __slots__ = ("tokens", "page", "parent", "children", "last_use")

    def __init__(self, tokens: tuple[int, ...], page: int,
                 parent: "_Node | None"):
        self.tokens = tokens
        self.page = page
        self.parent = parent
        self.children: dict[tuple[int, ...], _Node] = {}
        self.last_use = 0

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Admission plan for one prompt lookup.

    ``full_pages`` are mapped read-only (shared); ``cow`` = (source page,
    valid tokens) asks the engine to copy that page into the request's
    first private page before prefill.  ``matched`` tokens of KV arrive
    for free; prefill starts there (mid-page when ``cow`` is set)."""
    full_pages: tuple[int, ...] = ()
    matched_full: int = 0              # tokens covered by full_pages
    cow: tuple[int, int] | None = None  # (src page, valid tokens)

    @property
    def matched(self) -> int:
        return self.matched_full + (self.cow[1] if self.cow else 0)

    @property
    def hit(self) -> bool:
        return self.matched > 0


def _common_prefix(a, b) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class RadixPrefixCache:
    """Page-granular radix trie over ``kv``'s pool; registers itself as
    the pool's ``reclaim_hook``."""

    def __init__(self, kv: BlockPoolKV):
        self.kv = kv
        self.page_size = kv.cfg.page_size
        self.root = _Node((), BlockPoolKV.TRASH, None)
        self._clock = itertools.count(1)
        # counters (surfaced by stats(); bench_traffic reports them)
        self.lookups = 0
        self.hits = 0
        self.matched_tokens = 0
        self.matched_pages = 0          # full shared-page mappings
        self.cow_count = 0
        self.inserted_pages = 0
        self.evicted_pages = 0
        kv.reclaim_hook = self.evict

    # -- lookup -------------------------------------------------------------

    def match(self, tokens) -> PrefixMatch:
        """Longest cached prefix of ``tokens``, capped at ``len - 1`` so
        at least one prompt token is always prefilled (admission needs
        fresh last-token logits to sample the first output from)."""
        tokens = [int(t) for t in tokens]
        usable = len(tokens) - 1
        self.lookups += 1
        node, pos, pages = self.root, 0, []
        now = next(self._clock)
        while usable - pos >= self.page_size:
            child = node.children.get(tuple(tokens[pos:pos + self.page_size]))
            if child is None or child.n_tokens < self.page_size:
                break
            child.last_use = now
            pages.append(child.page)
            pos += self.page_size
            node = child
        cow = None
        if pos < usable:
            # best mid-page overlap among this node's children -> COW
            best, best_n = None, 0
            for child in node.children.values():
                n = _common_prefix(child.tokens, tokens[pos:usable])
                if n > best_n:
                    best, best_n = child, n
            if best is not None:
                best.last_use = now
                cow = (best.page, best_n)
                self.cow_count += 1
        m = PrefixMatch(full_pages=tuple(pages), matched_full=pos, cow=cow)
        if m.hit:
            self.hits += 1
            self.matched_tokens += m.matched
            self.matched_pages += len(pages)
        return m

    # -- insert -------------------------------------------------------------

    def insert(self, tokens, pages, n_tokens: int) -> int:
        """Adopt a finished request's cached sequence into the trie.

        ``tokens``: the request's full token stream (prompt + generated);
        ``pages``: the slot's page table entries covering it; only the
        first ``n_tokens`` are actually cached (the final sampled token's
        KV was never written).  Pages whose content is already in the trie
        are skipped (they stay slot-owned and free on release); new pages
        are RETAINED by the trie and survive the slot.  Returns the number
        of pages adopted."""
        tokens = [int(t) for t in tokens]
        node, pos, idx, adopted = self.root, 0, 0, 0
        now = next(self._clock)
        while pos < n_tokens:
            n = min(self.page_size, n_tokens - pos)
            seg = tuple(tokens[pos:pos + n])
            child = node.children.get(seg)
            if child is not None:
                child.last_use = now
                node, pos, idx = child, pos + n, idx + 1
                continue
            if n < self.page_size and any(
                    ch.tokens[:n] == seg for ch in node.children.values()):
                break   # a cached page already subsumes this partial tail
            page = int(pages[idx])
            self.kv.retain(page)
            new = _Node(seg, page, node)
            node.children[seg] = new
            new.last_use = now
            node, pos, idx = new, pos + n, idx + 1
            adopted += 1
        self.inserted_pages += adopted
        return adopted

    # -- eviction (the pool's reclaim hook) ---------------------------------

    def evict(self, n_pages: int) -> int:
        """Free at least ``n_pages`` by dropping trie leaves no live slot
        maps (page refcount 1 — held by the trie alone), least-recently
        used first.  Interior nodes become evictable as their subtrees
        drain, so the cache sheds leaf-first along cold paths.  Returns
        the number of pages actually freed."""
        freed = 0
        while freed < n_pages:
            victim = None
            for node in self._leaves():
                if self.kv.refcount[node.page] != 1:
                    continue        # pinned by a live slot
                if victim is None or node.last_use < victim.last_use:
                    victim = node
            if victim is None:
                break
            self.kv.release(victim.page)
            del victim.parent.children[victim.tokens]
            freed += 1
            self.evicted_pages += 1
        return freed

    def _leaves(self):
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            else:
                yield node

    # -- introspection ------------------------------------------------------

    def page_refs(self) -> dict[int, int]:
        """page -> number of trie references (for invariant audits:
        pool refcount == slot mappings + these)."""
        refs: dict[int, int] = {}
        stack = list(self.root.children.values())
        while stack:
            node = stack.pop()
            refs[node.page] = refs.get(node.page, 0) + 1
            stack.extend(node.children.values())
        return refs

    @property
    def n_pages(self) -> int:
        return len(self.page_refs())

    def stats(self) -> dict:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / self.lookups if self.lookups else 0.0,
            "matched_tokens": self.matched_tokens,
            "matched_pages": self.matched_pages,
            "cow_count": self.cow_count,
            "inserted_pages": self.inserted_pages,
            "evicted_pages": self.evicted_pages,
            "pages_held": self.n_pages,
        }

    def check_invariants(self) -> None:
        """Structural audit: page-aligned runs, no orphaned references,
        every non-tail node holds a full page."""
        stack = [(self.root, True)]
        while stack:
            node, _ = stack.pop()
            for child in node.children.values():
                assert child.parent is node
                assert 1 <= child.n_tokens <= self.page_size
                if child.children:
                    assert child.n_tokens == self.page_size, \
                        "interior trie node with a partial page"
                assert self.kv.refcount[child.page] >= 1, \
                    f"trie holds unreferenced page {child.page}"
                stack.append((child, False))
        self.kv.check_invariants(external_refs=self.page_refs())
