"""Serving engine: continuous batching over a dense OR paged KV cache.

Two KV modes behind one interface (``ServeConfig.kv_mode``):

``dense``
    The seed path, kept for tests and as the benchmark baseline: a fixed
    pool of ``batch`` slots, each reserving ``max_len`` KV up front;
    decode ticks run the whole pool (one jitted SPMD step regardless of
    occupancy).  Two seed inefficiencies are fixed here: prefill is JITTED
    with length-BUCKETED padding (power-of-two buckets + ``true_lengths``,
    so repeated admissions hit a handful of traces instead of retracing
    per prompt length), and the single-slot prefill cache template is
    allocated ONCE instead of per admission.  Slot writes are driven by
    the bundle's declared per-entry batch axes (``cache_batch_axes``)
    instead of a hardwired (L, B, ...) assumption.

``paged`` / ``paged_int8``
    The block-pool path, now a CONTINUOUS-BATCHING front-end: K/V live in
    fixed-size refcounted pages allocated from a global pool
    (``serving.kv.BlockPoolKV``), a radix prefix cache
    (``serving.prefix.RadixPrefixCache``, on by default) deduplicates
    shared prompt prefixes across requests — admission maps matched pages
    read-only, copy-on-write covers mid-page divergence, and prefill
    covers only the unmatched suffix — and the phase-aware scheduler
    (``serving.scheduler.PhaseScheduler``) admits/evicts PER TICK.  Each
    tick runs jitted ``paged_step`` over the pool's active rows grouped
    by padded length — wide prefill chunks in one call, decode rows and
    single-token cache-hit suffixes together in a ``T == 1`` call — so
    rows join and leave freely: a row may be mid-prefill (a chunk of
    ``counts[b]`` tokens) while its neighbours decode one token each — no
    phase epochs, no prefill convoy.  The per-row next-token gather and
    greedy argmax ride INSIDE the jitted step (one dispatch per call;
    host-side gathers dominate tick time otherwise).  The page-table
    view is sliced to a
    power-of-two page bucket covering the longest ACTIVE slot so compute
    and resident KV bytes scale with real sequence lengths, not
    ``batch x max_len``.  ``paged_int8`` keeps the pool quantized with
    per-(token, head) scale tables.

The engine's loop is exposed three ways: :meth:`run` drains everything
(the batch API), :meth:`step`/:meth:`pending` advance one tick (the
event-loop API the traffic benchmark drives), and :meth:`stream` returns
a per-request token GENERATOR that pulls ticks on demand — cooperative
streaming without threads, so interleaved consumers each see their tokens
the tick they are produced.

Sampling: greedy by default (``temperature == 0``); ``temperature`` plus
optional ``top_k`` switch decode to seeded host-side softmax sampling
(``sample_seed`` makes traces replayable).  Caches and steps follow
``repro.parallel.sharding`` (``paged_pool_specs`` for the pool); the
engine itself is host-side control logic and is exercised on CPU in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import get_telemetry

from .kv import BlockPoolKV, PagedKVConfig
from .prefix import RadixPrefixCache
from .scheduler import Phase, PhaseScheduler, Request, SchedulerConfig

KV_MODES = ("dense", "paged", "paged_int8")


class _TracedPrefix:
    """Engine-side proxy around the radix prefix cache: times ``match``
    as a ``prefix_match`` span (with hit/matched-token args) without the
    jax-free scheduler/prefix modules ever importing telemetry.  Every
    other attribute forwards to the wrapped cache."""

    def __init__(self, prefix: RadixPrefixCache, obs):
        self._prefix = prefix
        self._obs = obs

    def match(self, tokens):
        h = self._obs.begin("prefix_match", tokens=int(len(tokens)))
        m = self._prefix.match(tokens)
        self._obs.finish(h, matched=int(m.matched), hit=bool(m.hit))
        return m

    def __getattr__(self, name):
        return getattr(self._prefix, name)


@dataclasses.dataclass
class ServeConfig:
    batch: int              # slot pool size
    max_len: int
    max_new_tokens: int = 32
    eos_id: int = -1        # -1: never stop early
    temperature: float = 0.0        # 0: greedy; > 0: sampled decode
    top_k: int = 0                  # 0: full vocab; else sample top-k only
    sample_seed: int = 0            # host RNG seed (deterministic traces)
    kv_mode: str = "dense"          # dense | paged | paged_int8
    page_size: int = 16             # paged: tokens per page
    num_pages: int | None = None    # paged: pool size (None = dense capacity)
    prefill_chunk: int = 32         # paged: tokens per prefill call
    prefill_token_budget: int = 64  # paged: prefill tokens per tick
    prefix_cache: bool = True       # paged: radix prefix sharing + COW
    min_prefill_bucket: int = 8     # dense: smallest padded prompt bucket
    # graceful degradation (all off by default = seed behaviour):
    max_admission_retries: int = 0  # shed a request after N failed admits
    admission_backoff: int = 0      # base hold-off ticks between admits
    shed_pressure: float = 1.0      # pool-used fraction counted as critical
    shed_patience: int = 0          # critical ticks before load-shed (0=off)
    shed_min_priority: int = 1      # load-shed drops waiting prio < this


@dataclasses.dataclass
class _Slot:
    request_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    remaining: int = 0
    deadline_tick: int | None = None


def _pow2_at_least(n: int, lo: int = 1) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


# Module-level jits shared by every engine instance (a per-engine closure
# would give each engine its own compile cache, so benchmarks/tests that
# build fresh engines over the same bundle would re-trace identical
# shapes).  ``step`` is the bundle's paged_step, static so its identity
# keys the cache.
@jax.jit
def _copy_pool_page(pool, src, dst):
    """COW: copy one physical page (all layers, K+V+scales).  The page
    ids ride as traced scalars so every copy reuses one trace."""
    return {k: v.at[:, dst].set(v[:, src]) for k, v in pool.items()}


@jax.jit
def _write_pool_page(pool, dst, vals):
    """Land a MIGRATED page's payload (all layers, K+V+scales) at
    physical page ``dst`` — the receive half of fleet page migration.
    One trace serves every import: ``dst`` rides traced."""
    return {k: v.at[:, dst].set(vals[k].astype(v.dtype))
            for k, v in pool.items()}


def _pick_step(step, params, tokens, pool, pt, lens, counts):
    """paged_step plus the per-row next-token gather (each row's logits
    sit at ``counts[b] - 1``) and the greedy argmax, fused into ONE
    jitted dispatch — doing the gather outside jit costs more host time
    per tick than the step itself on small models."""
    logits, pool, _ = step(params, tokens, pool, pt, lens, counts)
    idx = jnp.maximum(counts, 1)[:, None, None] - 1
    rows = jnp.take_along_axis(logits, idx, axis=1)[:, 0]
    return rows, jnp.argmax(rows, axis=-1), pool


_pick_step = jax.jit(_pick_step, static_argnums=0)


class ServingEngine:
    """bundle must provide: init_cache(batch, max_len), prefill(params,
    tokens, cache, **extras), decode_step(params, tokens, cache); the paged
    modes additionally need init_paged_pool / paged_step /
    supports_paged_kv (the transformer family; see configs/base.py)."""

    # consecutive ticks with work queued but nothing executed before the
    # engine declares the scheduler wedged (admission backoff can idle a
    # bounded run of ticks legitimately)
    STALL_LIMIT = 4096

    def __init__(self, bundle: Any, params: Any, cfg: ServeConfig,
                 mesh: Any = None, telemetry: Any = None):
        if cfg.kv_mode not in KV_MODES:
            raise ValueError(f"kv_mode {cfg.kv_mode!r} not in {KV_MODES}")
        self.bundle = bundle
        self.params = params
        self.cfg = cfg
        self.mesh = mesh               # concrete Mesh: shard the page pool
        # telemetry: explicit Telemetry, or the process global (disabled
        # unless a launcher/bench called ``obs.enable()``)
        self.obs = telemetry if telemetry is not None else get_telemetry()
        self.results: dict[int, list[int]] = {}
        self.outcomes: dict[int, str] = {}   # rid -> ok | timeout | shed
        self._next_id = 0
        self._pressure_ticks = 0             # consecutive critical ticks
        self._shed_mode_ticks = 0
        self._stall_ticks = 0
        self._rng = np.random.default_rng(cfg.sample_seed)
        if cfg.kv_mode == "dense":
            self._init_dense()
        else:
            self._init_paged()

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------

    @property
    def _greedy(self) -> bool:
        return self.cfg.temperature <= 0.0

    def _pick(self, row) -> int:
        """Next token from one vocab-sized logit row (jax or numpy).

        Greedy at ``temperature == 0`` (the default — deterministic
        traces for tests/benchmarks; argmax stays on device so only a
        scalar crosses to the host); otherwise temperature-scaled
        softmax sampling, optionally restricted to the ``top_k``
        highest-logit tokens.  Sampling happens host-side from the
        engine's seeded RNG: only ACTIVE slots draw (in slot order), so
        a given (seed, trace) pair always replays the same tokens."""
        cfg = self.cfg
        if self._greedy:
            return int(jnp.argmax(row))
        z = np.asarray(row, np.float64) / cfg.temperature
        if 0 < cfg.top_k < z.size:
            kth = np.partition(z, -cfg.top_k)[-cfg.top_k]
            z = np.where(z >= kth, z, -np.inf)
        z = z - z.max()
        p = np.exp(z)
        return int(self._rng.choice(z.size, p=p / p.sum()))

    # ------------------------------------------------------------------
    # intake + the three loop surfaces (run / step / stream)
    # ------------------------------------------------------------------

    def submit(self, prompt_tokens: np.ndarray, priority: int = 0,
               deadline: int | None = None) -> int:
        """Queue a request.  ``priority`` (larger = more urgent) drives
        paged admission/preemption; the dense path keeps seed FIFO.
        ``deadline`` is a tick budget counted from NOW: a request still
        unfinished after that many engine ticks is evicted with whatever
        it has generated (``outcomes[rid] == "timeout"``)."""
        rid = self._next_id
        self._next_id += 1
        prompt = np.asarray(prompt_tokens, np.int32)
        total = len(prompt) + self.cfg.max_new_tokens
        if total > self.cfg.max_len:
            # dense would silently clamp cache writes at max_len-1 and
            # corrupt tokens; paged could deadlock admission — reject both
            raise ValueError(f"request {rid}: prompt+max_new {total} "
                             f"exceeds max_len {self.cfg.max_len}")
        if self.cfg.kv_mode == "dense":
            dl = None if deadline is None else self._dense_tick + deadline
            self.queue.append((rid, prompt, priority, dl))
            return rid
        req = Request(rid=rid, prompt=prompt, priority=priority,
                      arrival=rid, max_new_tokens=self.cfg.max_new_tokens,
                      deadline_tick=None if deadline is None
                      else self.ticks + deadline)
        need = self.kv.pages_for(total) + 1     # +1 decode headroom
        if need > self.kv.cfg.total_pages - 1:
            raise ValueError(f"request {rid}: needs {need} pages, pool has "
                             f"{self.kv.cfg.total_pages - 1}")
        self._requests[rid] = req
        self.sched.submit(req)
        return rid

    def reset_serving_state(self) -> None:
        """Drop all serving state — pool, scheduler, prefix trie, results,
        tick/pressure counters — while KEEPING the engine's compiled jit
        traces (they are keyed on the bundle, which survives the reset).
        Benchmarks use this to absorb compilation in an unmeasured warm
        pass and then measure a genuinely cold-cache serve: a fresh
        engine would re-trace every shape, a reset one does not."""
        self.results = {}
        self.outcomes = {}
        self._pressure_ticks = 0
        self._shed_mode_ticks = 0
        self._stall_ticks = 0
        self._rng = np.random.default_rng(self.cfg.sample_seed)
        if self.cfg.kv_mode == "dense":
            self._init_dense()
        else:
            self._init_paged()

    def pending(self) -> bool:
        """Whether any submitted request is still queued or in flight."""
        if self.cfg.kv_mode == "dense":
            return bool(self.queue) or \
                any(s.request_id is not None for s in self.slots)
        return self.sched.has_work

    def step(self) -> None:
        """Advance the engine ONE tick: expire deadlines, admit/evict,
        then one device step over the whole slot pool (prefill chunks and
        decode rows share it in the paged modes).  The event-loop API —
        callers interleave ``submit`` and ``step`` to serve an open-ended
        arrival stream (see benchmarks/bench_traffic.py)."""
        if self.cfg.kv_mode == "dense":
            self._step_dense()
        else:
            self._step_paged()

    def run(self, cache=None) -> dict[int, list[int]]:
        """Drain every queued/active request to completion."""
        if self.cfg.kv_mode == "dense" and cache is not None:
            self._dense_cache = cache
        while self.pending():
            self.step()
        return self.results

    def stream(self, rid: int) -> Iterator[int]:
        """Per-request token generator: yields ``rid``'s tokens as the
        continuous-batching loop produces them, driving :meth:`step` on
        demand when no new tokens are buffered.  Multiple streams
        interleave cooperatively — each tick's tokens are visible to
        every consumer immediately."""
        sent = 0
        while True:
            done = rid in self.results
            toks = self.results[rid] if done else self._partial_output(rid)
            while sent < len(toks):
                yield toks[sent]
                sent += 1
            if done:
                return
            if not self.pending():      # rid unknown / already reaped
                return
            self.step()

    def _partial_output(self, rid: int) -> list[int]:
        if self.cfg.kv_mode == "dense":
            for s in self.slots:
                if s.request_id == rid:
                    return list(s.generated)
            return []
        req = self._requests.get(rid)
        return req.output if req is not None else []

    # ------------------------------------------------------------------
    # dense path (seed behaviour + bucketed-jit prefill + declared axes)
    # ------------------------------------------------------------------

    def _init_dense(self) -> None:
        cfg = self.cfg
        self.slots = [_Slot() for _ in range(cfg.batch)]
        self.queue: list[tuple[int, np.ndarray, int, int | None]] = []
        self._dense_tick = 0
        self._dense_cache = None
        self._traffic = {"gb_read_tokens": 0, "dram_read_tokens": 0,
                         "written_tokens": 0}
        self._decode = jax.jit(self.bundle.decode_step)
        self._cache_axes: dict | None = None
        self._prefill_template = None       # built lazily, reused forever
        self._bucketed = bool(getattr(self.bundle,
                                      "prefill_supports_true_lengths", False))
        if self._bucketed:
            self._prefill = jax.jit(
                lambda p, t, c, tl: self.bundle.prefill(p, t, c,
                                                        true_lengths=tl))
        else:
            # exact-length fallback (families whose caches cannot absorb
            # padded prompts, e.g. SSM states): still jitted — repeated
            # admissions of the same prompt length reuse one trace — and
            # still template-reusing.
            self._prefill = jax.jit(
                lambda p, t, c: self.bundle.prefill(p, t, c))

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.request_id is None]

    def _prompt_bucket(self, n: int) -> int:
        return min(self.cfg.max_len,
                   _pow2_at_least(n, self.cfg.min_prefill_bucket))

    def _admit(self, cache):
        """Prefill queued requests into free slots (per-slot batch=1,
        length-bucketed so admissions reuse a handful of jit traces)."""
        for slot_idx in self._free_slots():
            if not self.queue:
                break
            rid, prompt, _, deadline = self.queue.pop(0)
            if self._prefill_template is None:
                self._prefill_template = self.bundle.init_cache(
                    1, self.cfg.max_len)
            toks = jnp.asarray(prompt, jnp.int32)[None]
            S = toks.shape[1]
            with self.obs.span("prefill", rid=rid, tokens=int(S)):
                if self._bucketed:
                    Sb = self._prompt_bucket(S)
                    toks = jnp.pad(toks, ((0, 0), (0, Sb - S)))
                    logits, c1 = self._prefill(
                        self.params, toks, self._prefill_template,
                        jnp.asarray([S], jnp.int32))
                else:
                    logits, c1 = self._prefill(self.params, toks,
                                               self._prefill_template)
            nxt = self._pick(logits[0, -1])
            cache = self._write_slot(cache, c1, slot_idx)
            s = self.slots[slot_idx]
            s.request_id = rid
            s.generated = [nxt]
            s.remaining = self.cfg.max_new_tokens - 1
            s.deadline_tick = deadline
        return cache

    def _write_slot(self, cache, one, idx):
        """Copy a batch=1 cache into slot ``idx`` of the pooled cache.

        The batch axis of each entry comes from the bundle's declared
        layout (``cache_batch_axes``) — e.g. recurrentgemma's grouped
        recurrent states carry batch at axis 2 — with the seed's
        axis-0-for-1D / axis-1-otherwise rule as the fallback for bundles
        that declare nothing."""
        if self._cache_axes is None:
            declare = getattr(self.bundle, "cache_batch_axes", None)
            if declare is not None:
                self._cache_axes = dict(declare(cache))
            else:
                self._cache_axes = {k: 0 if v.ndim == 1 else 1
                                    for k, v in cache.items()}
        out = {}
        for k, v in cache.items():
            ax = self._cache_axes[k]
            start = (0,) * ax + (idx,) + (0,) * (v.ndim - ax - 1)
            out[k] = jax.lax.dynamic_update_slice(
                v, one[k].astype(v.dtype), start)
        return out

    def _expire_dense(self) -> None:
        """Timeout eviction, dense flavour: queued requests past deadline
        never start; decoding slots past deadline free up with whatever
        they generated."""
        now = self._dense_tick
        kept = []
        for rid, prompt, prio, dl in self.queue:
            if dl is not None and now >= dl:
                self.results[rid] = []
                self.outcomes[rid] = "timeout"
            else:
                kept.append((rid, prompt, prio, dl))
        self.queue = kept
        for i, s in enumerate(self.slots):
            if s.request_id is not None and s.deadline_tick is not None \
                    and now >= s.deadline_tick:
                self.results[s.request_id] = s.generated
                self.outcomes[s.request_id] = "timeout"
                self.slots[i] = _Slot()

    def _step_dense(self) -> None:
        cfg = self.cfg
        if self._dense_cache is None:
            self._dense_cache = self.bundle.init_cache(cfg.batch, cfg.max_len)
        self._dense_tick += 1
        obs = self.obs
        self._expire_dense()
        with obs.span("admission", tick=self._dense_tick):
            self._dense_cache = self._admit(self._dense_cache)
        if not any(s.request_id is not None for s in self.slots):
            return
        with obs.span("decode", tick=self._dense_tick):
            # one decode tick for the whole pool
            last = np.zeros((cfg.batch, 1), np.int32)
            for i, s in enumerate(self.slots):
                if s.request_id is not None:
                    last[i, 0] = s.generated[-1]
            logits, self._dense_cache = self._decode(
                self.params, jnp.asarray(last), self._dense_cache)
            # greedy: batch argmax on device, ints cross to host; sampled:
            # one host copy of the active rows feeds the seeded picker
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1)) \
                if self._greedy else np.asarray(logits[:, 0])
        for i, s in enumerate(self.slots):
            if s.request_id is None:
                continue
            tok = int(nxt[i]) if self._greedy else self._pick(nxt[i])
            s.generated.append(tok)
            s.remaining -= 1
            if s.remaining <= 0 or tok == cfg.eos_id:
                self.results[s.request_id] = s.generated
                self.outcomes[s.request_id] = "ok"
                obs.counter("serve_requests", outcome="ok")
                obs.instant("complete", rid=s.request_id,
                            generated=len(s.generated))
                self.slots[i] = _Slot()

    # ------------------------------------------------------------------
    # paged path (block pool + prefix cache + continuous batching)
    # ------------------------------------------------------------------

    def _init_paged(self) -> None:
        cfg = self.cfg
        if not getattr(self.bundle, "supports_paged_kv", False):
            raise ValueError("bundle does not support the paged KV path "
                             "(needs init_paged_pool/paged_step)")
        mcfg = self.bundle.cfg
        quant = cfg.kv_mode == "paged_int8"
        kv_dtype = jnp.int8 if quant else None
        kv_bytes = 1 if quant else jnp.dtype(mcfg.dtype).itemsize
        pages_per_slot = -(-cfg.max_len // cfg.page_size)
        num_pages = cfg.num_pages or cfg.batch * pages_per_slot + 1
        if self.mesh is not None:
            # the page axis shards over the data axes — round the pool up
            # so every shard gets whole pages (same axis inventory the
            # pool specs use, so rounding and sharding can't diverge)
            from repro.parallel.sharding import _data_axes
            dsz = 1
            for a in _data_axes(self.mesh):
                dsz *= self.mesh.shape[a]
            num_pages = -(-num_pages // dsz) * dsz
        self.kv = BlockPoolKV(PagedKVConfig(
            num_slots=cfg.batch, max_len=cfg.max_len,
            page_size=cfg.page_size, num_pages=num_pages,
            n_layers=mcfg.n_layers, kv_heads=mcfg.n_kv_heads,
            head_dim=mcfg.dh, kv_bytes=kv_bytes, quantize=quant))
        # the radix prefix cache registers itself as the pool's reclaim
        # hook: page pressure drains cold cached prefixes before anyone
        # preempts a live request
        self.prefix = RadixPrefixCache(self.kv) if cfg.prefix_cache else None
        self.sched = PhaseScheduler(SchedulerConfig(
            num_slots=cfg.batch, prefill_chunk=cfg.prefill_chunk,
            prefill_token_budget=cfg.prefill_token_budget,
            max_admission_retries=cfg.max_admission_retries,
            admission_backoff=cfg.admission_backoff))
        self.pool = self.bundle.init_paged_pool(num_pages, cfg.page_size,
                                                kv_dtype=kv_dtype)
        if self.mesh is not None:
            # pool lives across the mesh: page axis over data, head
            # structure over model (repro.parallel.sharding)
            from repro.parallel.sharding import paged_pool_specs
            specs = paged_pool_specs(self.mesh, kv_heads=mcfg.n_kv_heads,
                                     head_dim=mcfg.dh)
            self.pool = {
                k: jax.device_put(
                    v, jax.sharding.NamedSharding(self.mesh, specs[k]))
                for k, v in self.pool.items()}
        self._requests: dict[int, Request] = {}
        self.cow_copies = 0
        self.ticks = 0
        # live KV traffic: token-exact attended context (the paper's
        # global-buffer level) and page-granular pool reads (DRAM level),
        # accumulated in _exec_rows.  Plain int adds — always on; the
        # roofline accountant compares them against the closed-form
        # prediction (obs.roofline_live.predict_paged_decode_traffic).
        self._traffic = {"gb_read_tokens": 0, "dram_read_tokens": 0,
                         "written_tokens": 0}

    def _pages_view(self, max_tokens: int) -> int:
        """Power-of-two page-table slice covering ``max_tokens`` — the
        static shape buckets that let gather/attention cost track actual
        lengths while reusing a log number of jit traces."""
        per_slot = self.kv.cfg.pages_per_slot
        return min(per_slot, _pow2_at_least(self.kv.pages_for(max_tokens)))

    def _mesh_ctx(self):
        from repro.runtime import compat
        return compat.set_mesh(self.mesh) if self.mesh is not None else None

    def _exec_step(self, tokens: np.ndarray, counts: np.ndarray, mp: int):
        """Run one jitted paged_step + row-gather + argmax over the whole
        slot pool (inside the ambient mesh context when the pool is
        sharded, so paged_step's sharding constraints resolve).  Returns
        ``(rows, picked)``: each slot's next-token logits and their
        argmax, both still on device."""
        pt = self.kv.page_table[:, :mp]
        lens = self.kv.lengths.astype(np.int32)
        ctx = self._mesh_ctx()
        try:
            if ctx is not None:
                ctx.__enter__()
            rows, picked, self.pool = _pick_step(
                self.bundle.paged_step, self.params, tokens, self.pool,
                pt, lens, counts.astype(np.int32))
        finally:
            if ctx is not None:
                ctx.__exit__(None, None, None)
        return rows, picked

    def _exec_cow(self, req: Request) -> None:
        """Execute a pending copy-on-write: duplicate the matched page's
        KV into the request's first private page, then release the pin
        admission held on the source."""
        src, dst, _ = req.cow
        ctx = self._mesh_ctx()
        try:
            if ctx is not None:
                ctx.__enter__()
            self.pool = _copy_pool_page(self.pool,
                                        jnp.asarray(src, jnp.int32),
                                        jnp.asarray(dst, jnp.int32))
        finally:
            if ctx is not None:
                ctx.__exit__(None, None, None)
        self.cow_copies += 1
        self.sched._drop_cow(self.kv, req)

    def _finish(self, req: Request) -> None:
        """Reap a completed request: adopt its cached pages into the
        prefix trie (they outlive the request until page pressure evicts
        them leaf-first), then release the slot."""
        if self.prefix is not None:
            n_cached = int(self.kv.lengths[req.slot])
            seq = np.concatenate(
                [req.prompt, np.asarray(req.generated, np.int32)])[:n_cached]
            self.prefix.insert(seq, self.kv.slot_pages(req.slot), n_cached)
        self.results[req.rid] = req.output
        self.outcomes[req.rid] = "ok"
        self.obs.counter("serve_requests", outcome="ok")
        self.obs.instant("complete", rid=req.rid,
                         generated=req.n_generated)
        self.sched.finish(self.kv, req)

    def _degrade_tick(self) -> None:
        """Per-tick degradation bookkeeping for the paged path: deadline
        eviction, shed collection, and load-shed mode when page-pool
        pressure stays critical for ``shed_patience`` consecutive ticks."""
        cfg = self.cfg
        for req in self.sched.expire_deadlines(self.kv, self.ticks):
            self.results[req.rid] = req.output
            self.outcomes[req.rid] = "timeout"
            self.obs.counter("serve_requests", outcome="timeout")
            self.obs.instant("timeout", rid=req.rid)
        if cfg.shed_patience > 0:
            st = self.kv.stats()
            frac = st["pages_used"] / max(1, st["pages_total"] - 1)
            if frac >= cfg.shed_pressure:
                self._pressure_ticks += 1
            else:
                self._pressure_ticks = 0
            if self._pressure_ticks >= cfg.shed_patience:
                self._shed_mode_ticks += 1
                self.sched.shed_waiting(
                    below_priority=cfg.shed_min_priority)

    def _step_paged(self) -> None:
        """One continuous-batching tick: admit (consulting the prefix
        cache), execute pending COW copies, grow decode pages, then run
        jitted ``paged_step`` over the tick's active rows grouped by
        padded length — wide prefill chunks in one call, decode rows
        (and single-token cache-hit suffix prefills) in a ``T == 1``
        call that keeps the Pallas decode path and never pays the
        chunk padding.  Requests join and leave the batch per tick;
        there are no phase epochs."""
        cfg = self.cfg
        if not self.sched.has_work:
            return
        self.ticks += 1
        obs = self.obs
        self._degrade_tick()
        with obs.span("admission", tick=self.ticks):
            prefix = self.prefix
            if obs.enabled and prefix is not None:
                prefix = _TracedPrefix(prefix, obs)
            admitted = self.sched.admit(self.kv, now=self.ticks,
                                        prefix=prefix)
            for req in admitted:
                if obs.enabled:
                    obs.instant("admit", rid=req.rid,
                                prompt=int(len(req.prompt)),
                                matched=int(req.matched_tokens))
                if req.cow is not None:
                    self._exec_cow(req)
        shed = self.sched.drain_shed()
        for req in shed:
            self.results[req.rid] = req.output
            self.outcomes[req.rid] = "shed"
            obs.counter("serve_requests", outcome="shed")
            obs.instant("shed", rid=req.rid)

        # decode rows claim their next page BEFORE the batch is built —
        # under page pressure this may evict actives (prefill included),
        # so jobs are selected afterwards
        with obs.span("reclaim", tick=self.ticks):
            preempted = self.sched.ensure_decode_pages(self.kv)
        for req in preempted or ():
            obs.counter("serve_preemptions")
            obs.instant("preempt", rid=req.rid,
                        preemptions=req.preemptions)
        jobs = self.sched.prefill_jobs()
        decoding = self.sched.decoding()
        if not jobs and not decoding:
            # stall valve: work is queued but nothing ran this tick
            self._stall_ticks = 0 if (admitted or shed) else \
                self._stall_ticks + 1
            if self._stall_ticks > self.STALL_LIMIT:
                raise RuntimeError("paged scheduler made no progress")
            return
        self._stall_ticks = 0

        # group rows by padded length: wide chunks would drag decode rows
        # through a T-padded trace (the T > 1 path attends with the XLA
        # fallback over the whole page view), so decode only shares a
        # call with prefills that are themselves single-token
        chunk_t = _pow2_at_least(max((j.count for j in jobs), default=1))
        if chunk_t == 1:
            groups = [(jobs, decoding)]
        else:
            groups = [(jobs, []), ([], decoding)]
        for g_jobs, g_decode in groups:
            if g_jobs or g_decode:
                with obs.span("prefill" if g_jobs else "decode",
                              tick=self.ticks, prefill_rows=len(g_jobs),
                              decode_rows=len(g_decode)):
                    self._exec_rows(g_jobs, g_decode)

    def _exec_rows(self, jobs, decoding) -> None:
        """Build one padded (B, T) batch from the given prefill jobs +
        decode rows, run it through ``paged_step``, and harvest: advance
        lengths, sample next tokens, finish completed requests."""
        cfg = self.cfg
        B = cfg.batch
        T = _pow2_at_least(max([j.count for j in jobs], default=1))
        tokens = np.zeros((B, T), np.int32)
        counts = np.zeros((B,), np.int32)
        for j in jobs:
            tokens[j.req.slot, :j.count] = \
                j.req.prompt[j.start:j.start + j.count]
            counts[j.req.slot] = j.count
        for r in decoding:
            tokens[r.slot, 0] = r.generated[-1]
            counts[r.slot] = 1
        mp = self._pages_view(max(
            int(self.kv.lengths[s]) + int(counts[s])
            for s in range(B) if counts[s] > 0))
        rows_dev, picked_dev = self._exec_step(tokens, counts, mp)
        picked = np.asarray(picked_dev) if self._greedy \
            else np.asarray(rows_dev)

        by_slot = {j.req.slot: j for j in jobs}
        tr, page = self._traffic, self.kv.cfg.page_size
        for slot in range(B):
            if counts[slot] == 0:
                continue
            job = by_slot.get(slot)
            if job is not None:                      # prefill chunk
                req = job.req
                self.kv.advance(slot, job.count)
                ctx = int(self.kv.lengths[slot])     # attended context
                tr["gb_read_tokens"] += ctx
                tr["dram_read_tokens"] += self.kv.pages_for(ctx) * page
                tr["written_tokens"] += job.count
                self.sched.finish_prefill_chunk(req, job.count)
                if req.phase is not Phase.DECODE:
                    continue                         # more chunks to go
            else:                                    # decode row
                req = next(r for r in decoding if r.slot == slot)
                self.kv.advance(slot, 1)
                ctx = int(self.kv.lengths[slot])
                tr["gb_read_tokens"] += ctx
                tr["dram_read_tokens"] += self.kv.pages_for(ctx) * page
                tr["written_tokens"] += 1
            tok = int(picked[slot]) if self._greedy \
                else self._pick(picked[slot])
            req.generated.append(tok)
            if req.n_generated >= req.max_new_tokens or tok == cfg.eos_id:
                self._finish(req)

    # ------------------------------------------------------------------
    # fleet surface (serving.fleet): cancel, in-flight audit, migration
    # ------------------------------------------------------------------

    def inflight(self) -> list[int]:
        """rids submitted but not yet reaped into ``results`` — on host
        loss the router re-admits exactly these on the survivors."""
        if self.cfg.kv_mode == "dense":
            live = [item[0] for item in self.queue]
            live += [s.request_id for s in self.slots
                     if s.request_id is not None]
            return [rid for rid in live if rid not in self.results]
        return [rid for rid in self._requests if rid not in self.results]

    def cancel(self, rid: int) -> bool:
        """Withdraw one unfinished request, releasing its pages (shared
        prefix pages only decref).  The fleet retires the losing twin of
        a hedged dispatch this way.  Returns True when something was
        actually cancelled."""
        if rid in self.results:
            return False
        if self.cfg.kv_mode == "dense":
            for i, item in enumerate(self.queue):
                if item[0] == rid:
                    del self.queue[i]
                    self.results[rid] = []
                    self.outcomes[rid] = "cancelled"
                    return True
            for i, s in enumerate(self.slots):
                if s.request_id == rid:
                    self.results[rid] = list(s.generated)
                    self.outcomes[rid] = "cancelled"
                    self.slots[i] = _Slot()
                    return True
            return False
        req = self._requests.get(rid)
        if req is None or self.sched.cancel(self.kv, rid) is None:
            return False
        self.results[rid] = req.output
        self.outcomes[rid] = "cancelled"
        self.obs.counter("serve_requests", outcome="cancelled")
        return True

    def export_prefix_pages(self, tokens, n_tokens: int):
        """Migration SOURCE: the KV payloads of the full-page cached
        prefix of ``tokens[:n_tokens]``, as (segment tokens, {pool entry:
        np.ndarray}) pairs in path order.  Stops at the first uncached or
        partial page — callers migrate what exists and recompute the
        rest."""
        if getattr(self, "prefix", None) is None:
            return []
        out = []
        for node in self.prefix.path_nodes(tokens, n_tokens):
            vals = {k: np.asarray(v[:, node.page])
                    for k, v in self.pool.items()}
            out.append((node.tokens, vals))
        return out

    def import_prefix_pages(self, segments) -> int:
        """Migration TARGET: graft exported page payloads into this
        host's pool + trie so the next lookup serves them locally —
        the page is TRANSFERRED, never re-prefilled.  Segments already
        cached here are skipped; a dry pool ends the import early
        (partial import is fine, the remainder is recomputed).  Returns
        the prefix tokens now cached locally."""
        if getattr(self, "prefix", None) is None:
            return 0
        node, matched = self.prefix.root, 0
        ps = self.kv.cfg.page_size
        for seg, vals in segments:
            seg = tuple(int(t) for t in seg)
            if len(seg) != ps:
                break                        # only full pages migrate
            child = node.children.get(seg)
            if child is not None and child.n_tokens == ps:
                node, matched = child, matched + ps
                continue
            try:
                page = self.kv.adopt_page()
            except MemoryError:
                break
            ctx = self._mesh_ctx()
            try:
                if ctx is not None:
                    ctx.__enter__()
                self.pool = _write_pool_page(
                    self.pool, jnp.asarray(page, jnp.int32),
                    {k: jnp.asarray(v) for k, v in vals.items()})
            finally:
                if ctx is not None:
                    ctx.__exit__(None, None, None)
            node = self.prefix.adopt_segment(node, seg, page)
            matched += ps
        return matched

    def drop_prefix_path(self, tokens, n_tokens: int) -> int:
        """Migration SOURCE, after a successful transfer: drop the local
        trie path for the migrated prefix (ownership moved — pages are
        owned once).  Pages still feeding live slots survive."""
        if getattr(self, "prefix", None) is None:
            return 0
        return self.prefix.drop_path(tokens, n_tokens)

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------

    def degradation_stats(self) -> dict:
        """Outcome counters + load-shed bookkeeping (all modes)."""
        counts = {"ok": 0, "timeout": 0, "shed": 0}
        for v in self.outcomes.values():
            counts[v] = counts.get(v, 0) + 1
        counts["shed_mode_ticks"] = self._shed_mode_ticks
        return counts

    def prefix_stats(self) -> dict:
        """Radix-cache counters (hit rate, matched tokens/pages, COW and
        eviction counts); empty when the cache is off or the mode dense."""
        if getattr(self, "prefix", None) is None:
            return {}
        st = self.prefix.stats()
        st["cow_copies"] = self.cow_copies
        return st

    def check_kv(self) -> None:
        """Full pool + trie invariant audit (tests): every page's refcount
        must equal its slot mappings plus trie references."""
        if getattr(self, "prefix", None) is not None:
            self.prefix.check_invariants()
        else:
            self.kv.check_invariants()

    def traffic_stats(self) -> dict:
        """Observed KV traffic (tokens + bytes) at the paper's two fetch
        levels: ``gb_*`` is token-exact attended context (global-buffer
        level), ``dram_*`` is page-granular pool reads.  Paged modes
        only; dense reports zeros (its cache is a flat reservation)."""
        tr = dict(self._traffic)
        if self.cfg.kv_mode != "dense":
            bpt = self.kv.cfg.page_bytes / self.kv.cfg.page_size
        else:
            bpt = 0.0
        tr["gb_read_bytes"] = tr["gb_read_tokens"] * bpt
        tr["dram_read_bytes"] = tr["dram_read_tokens"] * bpt
        tr["written_bytes"] = tr["written_tokens"] * bpt
        return tr

    def telemetry(self) -> dict:
        """One structured snapshot of everything the engine knows —
        request outcomes, KV-pool utilization, prefix-cache hit rate,
        observed traffic — mirrored into the metrics registry as
        ``serve.*`` gauges (the pull half of the obs design) and returned
        as a plain dict (the ``/stats`` surface)."""
        snap = {
            "mode": self.cfg.kv_mode,
            "ticks": getattr(self, "ticks", None) if
            self.cfg.kv_mode != "dense" else self._dense_tick,
            "outcomes": self.degradation_stats(),
            "kv": self.kv_stats(),
            "prefix": self.prefix_stats(),
            "traffic": self.traffic_stats(),
        }
        m = self.obs.metrics
        m.absorb(snap["outcomes"], prefix="serve.outcomes.")
        m.absorb(snap["kv"], prefix="serve.kv.")
        m.absorb(snap["prefix"], prefix="serve.prefix.")
        m.absorb(snap["traffic"], prefix="serve.traffic.")
        return snap

    def kv_stats(self) -> dict:
        """Resident-KV accounting (benchmarks): paged modes report pool
        counters; dense reports the up-front reservation."""
        if self.cfg.kv_mode != "dense":
            return self.kv.stats()
        leaves = jax.tree_util.tree_leaves(
            jax.eval_shape(lambda: self.bundle.init_cache(
                self.cfg.batch, self.cfg.max_len)))
        total = int(sum(np.prod(l.shape) * l.dtype.itemsize for l in leaves))
        return {"bytes_resident": total, "peak_bytes": total,
                "pages_total": 0, "pages_used": 0, "utilization": 1.0,
                "fragmentation": 0.0, "evictions": 0}
