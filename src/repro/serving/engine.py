"""Batched serving engine: slot-based continuous batching.

A fixed pool of `batch` slots; requests occupy a slot, prefill fills its
cache region, decode steps run for the WHOLE pool every tick (SPMD-friendly:
one jitted decode_step regardless of occupancy), finished slots are recycled
for queued requests. Greedy sampling (temperature hook provided).

Caches and decode_step shardings follow repro.parallel.sharding — the
engine itself is host-side control logic and is exercised on CPU in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ServeConfig:
    batch: int              # slot pool size
    max_len: int
    max_new_tokens: int = 32
    eos_id: int = -1        # -1: never stop early
    temperature: float = 0.0


@dataclasses.dataclass
class _Slot:
    request_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    remaining: int = 0


class ServingEngine:
    """bundle must provide: init_cache(batch, max_len), prefill(params,
    tokens, cache, **extras), decode_step(params, tokens, cache)."""

    def __init__(self, bundle: Any, params: Any, cfg: ServeConfig):
        self.bundle = bundle
        self.params = params
        self.cfg = cfg
        self.slots = [_Slot() for _ in range(cfg.batch)]
        self.queue: list[tuple[int, np.ndarray]] = []
        self.results: dict[int, list[int]] = {}
        self._next_id = 0
        self._decode = jax.jit(bundle.decode_step)

    def submit(self, prompt_tokens: np.ndarray) -> int:
        rid = self._next_id
        self._next_id += 1
        self.queue.append((rid, prompt_tokens))
        return rid

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.request_id is None]

    def _admit(self, cache):
        """Prefill queued requests into free slots (one batch prefill for
        simplicity: slots prefill independently via per-slot batch=1)."""
        for slot_idx in self._free_slots():
            if not self.queue:
                break
            rid, prompt = self.queue.pop(0)
            toks = jnp.asarray(prompt, jnp.int32)[None]
            c1 = self.bundle.init_cache(1, self.cfg.max_len)
            logits, c1 = self.bundle.prefill(self.params, toks, c1)
            nxt = int(jnp.argmax(logits[0, -1]))
            cache = self._write_slot(cache, c1, slot_idx)
            s = self.slots[slot_idx]
            s.request_id = rid
            s.generated = [nxt]
            s.remaining = self.cfg.max_new_tokens - 1
        return cache

    @staticmethod
    def _write_slot(cache, one, idx):
        """Copy a batch=1 cache into slot `idx` of the pooled cache."""
        out = {}
        for k, v in cache.items():
            s = one[k]
            if k == "length":
                out[k] = v.at[idx].set(s[0])
            else:
                # pooled (L, B, ...) <- single (L, 1, ...)
                out[k] = jax.lax.dynamic_update_slice(
                    v, s.astype(v.dtype),
                    (0, idx) + (0,) * (v.ndim - 2))
        return out

    def run(self, cache=None) -> dict[int, list[int]]:
        """Drain queue + all slots to completion; returns {rid: tokens}."""
        cfg = self.cfg
        if cache is None:
            cache = self.bundle.init_cache(cfg.batch, cfg.max_len)
        while self.queue or any(s.request_id is not None for s in self.slots):
            cache = self._admit(cache)
            # one decode tick for the whole pool
            last = np.zeros((cfg.batch, 1), np.int32)
            for i, s in enumerate(self.slots):
                if s.request_id is not None:
                    last[i, 0] = s.generated[-1]
            logits, cache = self._decode(self.params, jnp.asarray(last),
                                         cache)
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for i, s in enumerate(self.slots):
                if s.request_id is None:
                    continue
                tok = int(nxt[i])
                s.generated.append(tok)
                s.remaining -= 1
                if s.remaining <= 0 or tok == cfg.eos_id:
                    self.results[s.request_id] = s.generated
                    self.slots[i] = _Slot()
        return self.results
