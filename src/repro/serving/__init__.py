from .engine import KV_MODES, ServeConfig, ServingEngine
from .fleet import FleetConfig, LocalFleet
from .kv import BlockPoolKV, PagedKVConfig
from .prefix import (DirectoryMatch, PageOwnershipDirectory, PrefixMatch,
                     RadixPrefixCache)
from .scheduler import (Phase, PhaseScheduler, PrefillJob, Request,
                        SchedulerConfig)

__all__ = ["KV_MODES", "ServeConfig", "ServingEngine",
           "FleetConfig", "LocalFleet",
           "BlockPoolKV", "PagedKVConfig",
           "DirectoryMatch", "PageOwnershipDirectory",
           "PrefixMatch", "RadixPrefixCache",
           "Phase", "PhaseScheduler", "PrefillJob", "Request",
           "SchedulerConfig"]
