from .engine import KV_MODES, ServeConfig, ServingEngine
from .kv import BlockPoolKV, PagedKVConfig
from .prefix import PrefixMatch, RadixPrefixCache
from .scheduler import (Phase, PhaseScheduler, PrefillJob, Request,
                        SchedulerConfig)

__all__ = ["KV_MODES", "ServeConfig", "ServingEngine",
           "BlockPoolKV", "PagedKVConfig",
           "PrefixMatch", "RadixPrefixCache",
           "Phase", "PhaseScheduler", "PrefillJob", "Request",
           "SchedulerConfig"]
