from .engine import KV_MODES, ServeConfig, ServingEngine
from .kv import BlockPoolKV, PagedKVConfig
from .scheduler import (Phase, PhaseScheduler, PrefillJob, Request,
                        SchedulerConfig)

__all__ = ["KV_MODES", "ServeConfig", "ServingEngine",
           "BlockPoolKV", "PagedKVConfig",
           "Phase", "PhaseScheduler", "PrefillJob", "Request",
           "SchedulerConfig"]
