"""Block-pool KV manager: fixed-size pages + per-slot page tables.

The serving analogue of the paper's exchange mesh: a slot's KV history is
broken into fixed-size PAGES (the local SRAM tiles) allocated from one
GLOBAL pool, and the per-slot page table is the exchange fabric that makes
any page globally addressable — no slot ever reserves ``max_len`` tokens of
dense KV up front, so resident bytes track the tokens actually cached.

This module is deliberately jax-free: the page table, free list and
counters are host-side numpy/python state (cheap, synchronous, property-
testable), while the page POOL arrays themselves (``k_pages``/``v_pages``
per layer) are device arrays owned by the engine and indexed by the table
this manager maintains.  Physical page 0 is reserved as the TRASH page:
pad-token writes land there and no slot is ever mapped to it, so masked
scatters never corrupt live history.

Pool sizing/accounting knows the per-page byte cost (layers x page_size x
kv_heads x head_dim x dtype, doubled for K+V, plus f32 scale tables when
the pool is int8-quantized) so ``bytes_resident()`` reports the real HBM
footprint of the cached tokens.  Shardings for the device-side pool follow
``repro.parallel.sharding.paged_pool_specs``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    """Geometry of one paged pool (shared across every slot)."""
    num_slots: int                 # decode pool width (continuous batching)
    max_len: int                   # per-slot token capacity ceiling
    page_size: int = 16            # tokens per page
    num_pages: int | None = None   # total pool pages incl. trash page 0
    n_layers: int = 1              # byte accounting only
    kv_heads: int = 1
    head_dim: int = 1
    kv_bytes: int = 2              # bf16 = 2; int8 pools pass 1
    quantize: bool = False         # adds f32 scale tables to accounting

    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_len // self.page_size)

    @property
    def total_pages(self) -> int:
        if self.num_pages is not None:
            return self.num_pages
        # default: full reservation + trash (degenerates to dense capacity)
        return self.num_slots * self.pages_per_slot + 1

    @property
    def page_bytes(self) -> int:
        """HBM bytes one resident page costs (K + V, + scales when int8)."""
        elems = self.n_layers * self.page_size * self.kv_heads
        b = 2 * elems * self.head_dim * self.kv_bytes
        if self.quantize:
            b += 2 * elems * 4          # f32 scale per (token, head)
        return b


class BlockPoolKV:
    """Free-list page allocator with per-slot page tables.

    Invariants (property-tested in tests/test_serving.py):
      * a physical page is mapped by at most one slot at any time;
      * page 0 (trash) is never allocated;
      * free + sum(per-slot pages) == total_pages - 1 always.
    """

    TRASH = 0

    def __init__(self, cfg: PagedKVConfig):
        self.cfg = cfg
        n = cfg.total_pages
        if n < 2:
            raise ValueError("pool needs at least one page beyond trash")
        # LIFO free list: recently freed pages are re-used first (keeps the
        # hot working set dense in the pool — the fragmentation counter
        # below measures how well that works).
        self._free: list[int] = list(range(n - 1, 0, -1))
        self._slot_pages: list[list[int]] = [[] for _ in range(cfg.num_slots)]
        self.lengths = np.zeros((cfg.num_slots,), np.int64)
        self.page_table = np.zeros((cfg.num_slots, cfg.pages_per_slot),
                                   np.int32)
        # counters
        self.alloc_count = 0
        self.free_count = 0
        self.evict_count = 0
        self.peak_pages = 0

    # -- queries ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.cfg.total_pages - 1) - len(self._free)

    def slot_pages(self, slot: int) -> tuple[int, ...]:
        return tuple(self._slot_pages[slot])

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.cfg.page_size)

    def can_alloc(self, n_pages: int) -> bool:
        return n_pages <= len(self._free)

    def capacity(self, slot: int) -> int:
        """Token capacity currently mapped for ``slot``."""
        return len(self._slot_pages[slot]) * self.cfg.page_size

    # -- mutation -----------------------------------------------------------

    def ensure(self, slot: int, target_len: int) -> int:
        """Map enough pages for ``target_len`` tokens; returns pages added.

        Raises ``MemoryError`` when the free list can't cover the growth —
        the scheduler turns that into an eviction decision."""
        if target_len > self.cfg.max_len:
            raise ValueError(f"target_len {target_len} > max_len "
                             f"{self.cfg.max_len}")
        need = self.pages_for(target_len) - len(self._slot_pages[slot])
        if need <= 0:
            return 0
        if need > len(self._free):
            raise MemoryError(
                f"pool dry: slot {slot} needs {need} pages, "
                f"{len(self._free)} free")
        added = 0
        for _ in range(need):
            page = self._free.pop()
            idx = len(self._slot_pages[slot])
            self._slot_pages[slot].append(page)
            self.page_table[slot, idx] = page
            added += 1
        self.alloc_count += added
        self.peak_pages = max(self.peak_pages, self.used_pages)
        return added

    def advance(self, slot: int, n_tokens: int) -> None:
        """Record ``n_tokens`` more tokens resident in ``slot``.

        Capacity must already be mapped (``ensure``)."""
        new_len = int(self.lengths[slot]) + n_tokens
        if new_len > self.capacity(slot):
            raise RuntimeError(
                f"slot {slot}: length {new_len} exceeds mapped capacity "
                f"{self.capacity(slot)} — call ensure() first")
        self.lengths[slot] = new_len

    def free_slot(self, slot: int, *, evicted: bool = False) -> int:
        """Unmap every page of ``slot`` back to the free list."""
        pages = self._slot_pages[slot]
        released = len(pages)
        self._free.extend(reversed(pages))
        pages.clear()
        self.page_table[slot, :] = self.TRASH
        self.lengths[slot] = 0
        self.free_count += released
        if evicted:
            self.evict_count += 1
        return released

    # -- accounting ---------------------------------------------------------

    def bytes_resident(self) -> int:
        return self.used_pages * self.cfg.page_bytes

    def stats(self) -> dict:
        """Utilization (tokens cached / token capacity mapped) and pool
        fragmentation (mapped-but-unfilled tail tokens / mapped capacity)."""
        cap = sum(len(p) for p in self._slot_pages) * self.cfg.page_size
        toks = int(self.lengths.sum())
        return {
            "pages_total": self.cfg.total_pages - 1,
            "pages_used": self.used_pages,
            "pages_free": self.free_pages,
            "peak_pages": self.peak_pages,
            "tokens_resident": toks,
            "bytes_resident": self.bytes_resident(),
            "peak_bytes": self.peak_pages * self.cfg.page_bytes,
            "utilization": toks / cap if cap else 0.0,
            "fragmentation": (cap - toks) / cap if cap else 0.0,
            "allocs": self.alloc_count,
            "frees": self.free_count,
            "evictions": self.evict_count,
        }

    def check_invariants(self) -> None:
        """Cheap structural audit (used by the property tests)."""
        seen: set[int] = set()
        for slot, pages in enumerate(self._slot_pages):
            for i, p in enumerate(pages):
                assert p != self.TRASH, f"slot {slot} mapped to trash"
                assert p not in seen, f"page {p} double-assigned"
                assert self.page_table[slot, i] == p
                seen.add(p)
            assert (self.page_table[slot, len(pages):] == self.TRASH).all()
            assert self.lengths[slot] <= len(pages) * self.cfg.page_size
        free = set(self._free)
        assert len(free) == len(self._free), "free list duplicates"
        assert not (free & seen), "page both free and mapped"
        assert self.TRASH not in free, "trash page entered the free list"
        assert len(free) + len(seen) == self.cfg.total_pages - 1
