"""Block-pool KV manager: refcounted fixed-size pages + per-slot page tables.

The serving analogue of the paper's exchange mesh: a slot's KV history is
broken into fixed-size PAGES (the local SRAM tiles) allocated from one
GLOBAL pool, and the per-slot page table is the exchange fabric that makes
any page globally addressable — no slot ever reserves ``max_len`` tokens of
dense KV up front, so resident bytes track the tokens actually cached.

Pages are REFCOUNTED: a physical page may be mapped read-only by several
slots at once and/or held by the radix prefix cache
(:mod:`repro.serving.prefix`), which is exactly the paper's
"promote local data to global visibility" applied to KV — a shared system
prompt's pages are computed once and then served from the pool instead of
being re-fetched (re-prefilled) per request.  A slot only ever WRITES
pages it owns exclusively (refcount 1 via :meth:`ensure`); sharing a
partially filled page goes through copy-on-write at the engine level.
Releasing a slot decrements refcounts and returns only orphaned pages to
the free list, so preempting a request that shares prefix pages can never
free pages still referenced by the trie or a peer request.

When the free list runs dry, :meth:`reserve` first invokes the registered
``reclaim_hook`` (the prefix cache's leaf-first LRU eviction) before the
caller has to preempt live requests.

This module is deliberately jax-free: the page table, free list, refcounts
and counters are host-side numpy/python state (cheap, synchronous,
property-testable), while the page POOL arrays themselves (``k_pages``/
``v_pages`` per layer) are device arrays owned by the engine and indexed
by the table this manager maintains.  Physical page 0 is reserved as the
TRASH page: pad-token writes land there and no slot is ever mapped to it,
so masked scatters never corrupt live history.

Pool sizing/accounting knows the per-page byte cost (layers x page_size x
kv_heads x head_dim x dtype, doubled for K+V, plus f32 scale tables when
the pool is int8-quantized) so ``bytes_resident()`` reports the real HBM
footprint of the cached tokens.  Shardings for the device-side pool follow
``repro.parallel.sharding.paged_pool_specs``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    """Geometry of one paged pool (shared across every slot)."""
    num_slots: int                 # decode pool width (continuous batching)
    max_len: int                   # per-slot token capacity ceiling
    page_size: int = 16            # tokens per page
    num_pages: int | None = None   # total pool pages incl. trash page 0
    n_layers: int = 1              # byte accounting only
    kv_heads: int = 1
    head_dim: int = 1
    kv_bytes: int = 2              # bf16 = 2; int8 pools pass 1
    quantize: bool = False         # adds f32 scale tables to accounting

    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_len // self.page_size)

    @property
    def total_pages(self) -> int:
        if self.num_pages is not None:
            return self.num_pages
        # default: full reservation + trash (degenerates to dense capacity)
        return self.num_slots * self.pages_per_slot + 1

    @property
    def page_bytes(self) -> int:
        """HBM bytes one resident page costs (K + V, + scales when int8)."""
        elems = self.n_layers * self.page_size * self.kv_heads
        b = 2 * elems * self.head_dim * self.kv_bytes
        if self.quantize:
            b += 2 * elems * 4          # f32 scale per (token, head)
        return b


class BlockPoolKV:
    """Free-list page allocator with refcounts and per-slot page tables.

    Invariants (property-tested in tests/test_prefix.py):
      * every page with refcount > 0 is absent from the free list and
        every free page has refcount 0;
      * free_pages + referenced pages == total_pages - 1 always;
      * page 0 (trash) is never allocated and never enters the free list;
      * a page's refcount equals the number of slot-table mappings plus
        the number of external (prefix-trie) references;
      * a slot only writes pages it owns exclusively — shared (refcount
        > 1) pages are mapped strictly BEFORE a slot's private tail, and
        the slot's write positions never reach them.
    """

    TRASH = 0

    def __init__(self, cfg: PagedKVConfig):
        self.cfg = cfg
        n = cfg.total_pages
        if n < 2:
            raise ValueError("pool needs at least one page beyond trash")
        # LIFO free list: recently freed pages are re-used first (keeps the
        # hot working set dense in the pool — the fragmentation counter
        # below measures how well that works).
        self._free: list[int] = list(range(n - 1, 0, -1))
        self.refcount = np.zeros((n,), np.int32)
        self._slot_pages: list[list[int]] = [[] for _ in range(cfg.num_slots)]
        # per-slot count of SHARED (read-only, prefix-cache) leading pages
        self._slot_shared: list[int] = [0] * cfg.num_slots
        self.lengths = np.zeros((cfg.num_slots,), np.int64)
        self.page_table = np.zeros((cfg.num_slots, cfg.pages_per_slot),
                                   np.int32)
        # invoked with the page deficit when the free list runs dry; must
        # return the number of pages it actually freed (the prefix cache
        # registers its leaf-first LRU eviction here)
        self.reclaim_hook: Callable[[int], int] | None = None
        # counters
        self.alloc_count = 0
        self.free_count = 0
        self.evict_count = 0
        self.share_count = 0           # shared-page mappings (cache hits)
        self.peak_pages = 0

    # -- queries ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.cfg.total_pages - 1) - len(self._free)

    def slot_pages(self, slot: int) -> tuple[int, ...]:
        return tuple(self._slot_pages[slot])

    def shared_prefix_pages(self, slot: int) -> int:
        """Leading pages of ``slot`` mapped read-only from the prefix
        cache (the slot never writes positions inside them)."""
        return self._slot_shared[slot]

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.cfg.page_size)

    def can_alloc(self, n_pages: int) -> bool:
        return n_pages <= len(self._free)

    def reserve(self, n_pages: int) -> bool:
        """Like :meth:`can_alloc`, but allowed to RECLAIM cold cache pages
        through ``reclaim_hook`` (prefix-trie leaf-first LRU eviction)
        before answering — preempting live requests is the caller's last
        resort, not its first."""
        deficit = n_pages - len(self._free)
        if deficit > 0 and self.reclaim_hook is not None:
            self.reclaim_hook(deficit)
        return n_pages <= len(self._free)

    def capacity(self, slot: int) -> int:
        """Token capacity currently mapped for ``slot``."""
        return len(self._slot_pages[slot]) * self.cfg.page_size

    # -- refcounting --------------------------------------------------------

    def retain(self, page: int) -> None:
        """Add one external reference to a LIVE page (prefix-trie insert,
        shared-slot mapping)."""
        if page == self.TRASH:
            raise ValueError("cannot retain the trash page")
        if self.refcount[page] <= 0:
            raise ValueError(f"retain of unreferenced page {page}")
        self.refcount[page] += 1

    def release(self, page: int) -> bool:
        """Drop one reference; returns True when the page was orphaned and
        went back to the free list."""
        if self.refcount[page] <= 0:
            raise ValueError(f"release of unreferenced page {page}")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(page)
            self.free_count += 1
            return True
        return False

    # -- mutation -----------------------------------------------------------

    def _alloc_page(self) -> int:
        page = self._free.pop()
        self.refcount[page] = 1
        self.alloc_count += 1
        return page

    def adopt_page(self) -> int:
        """Allocate one page held by an EXTERNAL owner (no slot table
        entry) — the landing pad for a KV page migrated in from another
        host, which the importer then hands to the prefix trie.  The
        caller owns the single reference and must ``release`` it (or
        ``retain`` on the trie's behalf, then ``release``) to balance.
        Runs ``reclaim_hook`` first so a warm cache does not starve
        migrations."""
        if not self._free and self.reclaim_hook is not None:
            self.reclaim_hook(1)
        if not self._free:
            raise MemoryError("pool dry: cannot adopt a migrated page")
        page = self._alloc_page()
        self.peak_pages = max(self.peak_pages, self.used_pages)
        return page

    def map_shared(self, slot: int, pages: list[int]) -> None:
        """Map prefix-cache pages read-only at the FRONT of an empty
        slot's table (cache-hit admission).  The slot takes one reference
        per page; it must never write positions inside them."""
        if self._slot_pages[slot]:
            raise RuntimeError(f"slot {slot}: map_shared on non-empty slot")
        for i, page in enumerate(pages):
            self.retain(page)
            self._slot_pages[slot].append(page)
            self.page_table[slot, i] = page
        self._slot_shared[slot] = len(pages)
        self.share_count += len(pages)
        self.peak_pages = max(self.peak_pages, self.used_pages)

    def ensure(self, slot: int, target_len: int) -> int:
        """Map enough PRIVATE pages for ``target_len`` tokens; returns
        pages added.  Tries ``reclaim_hook`` (cold prefix-cache pages)
        before raising ``MemoryError`` — the scheduler turns that into an
        eviction decision."""
        if target_len > self.cfg.max_len:
            raise ValueError(f"target_len {target_len} > max_len "
                             f"{self.cfg.max_len}")
        need = self.pages_for(target_len) - len(self._slot_pages[slot])
        if need <= 0:
            return 0
        if need > len(self._free) and self.reclaim_hook is not None:
            self.reclaim_hook(need - len(self._free))
        if need > len(self._free):
            raise MemoryError(
                f"pool dry: slot {slot} needs {need} pages, "
                f"{len(self._free)} free")
        added = 0
        for _ in range(need):
            page = self._alloc_page()
            idx = len(self._slot_pages[slot])
            self._slot_pages[slot].append(page)
            self.page_table[slot, idx] = page
            added += 1
        self.peak_pages = max(self.peak_pages, self.used_pages)
        return added

    def advance(self, slot: int, n_tokens: int) -> None:
        """Record ``n_tokens`` more tokens resident in ``slot``.

        Capacity must already be mapped (``ensure``)."""
        new_len = int(self.lengths[slot]) + n_tokens
        if new_len > self.capacity(slot):
            raise RuntimeError(
                f"slot {slot}: length {new_len} exceeds mapped capacity "
                f"{self.capacity(slot)} — call ensure() first")
        self.lengths[slot] = new_len

    def set_length(self, slot: int, n_tokens: int) -> None:
        """Set a slot's resident length directly (cache-hit admission:
        the matched prefix is already cached in the mapped shared pages)."""
        if n_tokens > self.capacity(slot):
            raise RuntimeError(
                f"slot {slot}: length {n_tokens} exceeds mapped capacity "
                f"{self.capacity(slot)}")
        self.lengths[slot] = n_tokens

    def free_slot(self, slot: int, *, evicted: bool = False) -> int:
        """Unmap every page of ``slot``, dropping one reference each.
        Only orphaned pages (refcount 0) return to the free list — pages
        still referenced by the prefix trie or a peer slot survive.
        Returns the number of pages actually freed."""
        pages = self._slot_pages[slot]
        released = 0
        for page in reversed(pages):
            if self.release(page):
                released += 1
        pages.clear()
        self._slot_shared[slot] = 0
        self.page_table[slot, :] = self.TRASH
        self.lengths[slot] = 0
        if evicted:
            self.evict_count += 1
        return released

    # -- accounting ---------------------------------------------------------

    def bytes_resident(self) -> int:
        return self.used_pages * self.cfg.page_bytes

    def stats(self) -> dict:
        """Utilization (tokens cached / token capacity mapped) and pool
        fragmentation (mapped-but-unfilled tail tokens / mapped capacity).
        Shared pages count once in pool terms (``pages_used``) but once
        per mapping in slot terms — ``pages_shared`` is the dedup win."""
        cap = sum(len(p) for p in self._slot_pages) * self.cfg.page_size
        toks = int(self.lengths.sum())
        return {
            "pages_total": self.cfg.total_pages - 1,
            "pages_used": self.used_pages,
            "pages_free": self.free_pages,
            "pages_shared": int((self.refcount > 1).sum()),
            "peak_pages": self.peak_pages,
            "tokens_resident": toks,
            "bytes_resident": self.bytes_resident(),
            "peak_bytes": self.peak_pages * self.cfg.page_bytes,
            "utilization": toks / cap if cap else 0.0,
            "fragmentation": (cap - toks) / cap if cap else 0.0,
            "allocs": self.alloc_count,
            "frees": self.free_count,
            "shares": self.share_count,
            "evictions": self.evict_count,
        }

    def check_invariants(self, external_refs: dict[int, int] | None = None
                         ) -> None:
        """Cheap structural audit (used by the property tests).

        ``external_refs`` maps page -> reference count held OUTSIDE slot
        tables (the prefix trie's holdings, from
        ``RadixPrefixCache.page_refs()``).  When given, every page's
        refcount must EQUAL slot mappings + external refs; when omitted
        (callers that cannot see the trie) refcounts must merely cover
        the slot mappings."""
        slot_refs: dict[int, int] = {}
        for slot, pages in enumerate(self._slot_pages):
            for i, p in enumerate(pages):
                assert p != self.TRASH, f"slot {slot} mapped to trash"
                assert self.page_table[slot, i] == p
                slot_refs[p] = slot_refs.get(p, 0) + 1
            shared = self._slot_shared[slot]
            assert shared <= len(pages), f"slot {slot} shared > mapped"
            for p in pages[shared:]:
                # private tail pages are exclusively owned iff nothing
                # external pinned them; sharing happens only via the
                # shared prefix — never checked here because the trie may
                # legitimately hold a finished slot's tail pages
                assert self.refcount[p] >= 1
            assert (self.page_table[slot, len(pages):] == self.TRASH).all()
            assert self.lengths[slot] <= len(pages) * self.cfg.page_size
        free = set(self._free)
        assert len(free) == len(self._free), "free list duplicates"
        assert self.TRASH not in free, "trash page entered the free list"
        referenced = {int(p) for p in np.nonzero(self.refcount > 0)[0]}
        assert not (free & referenced), "page both free and referenced"
        assert free | referenced == set(range(1, self.cfg.total_pages)), \
            "page leaked: neither free nor referenced"
        assert len(free) + len(referenced) == self.cfg.total_pages - 1
        for p in slot_refs:
            assert self.refcount[p] >= slot_refs[p], \
                f"page {p}: refcount {self.refcount[p]} < slot maps"
        if external_refs is not None:
            for p in referenced:
                want = slot_refs.get(p, 0) + external_refs.get(p, 0)
                assert self.refcount[p] == want, \
                    (f"page {p}: refcount {self.refcount[p]} != "
                     f"{slot_refs.get(p, 0)} slot + "
                     f"{external_refs.get(p, 0)} external refs")
            for p, n in external_refs.items():
                assert n == 0 or self.refcount[p] > 0, \
                    f"page {p} externally referenced but unallocated"
