"""Real-fleet plumbing: worker identity, heartbeat files, stripe exchange.

The simulated fleet inside ``launch/train.py`` (peers as synthetic
heartbeats on a virtual clock) proved the recovery *logic*; this module
is the glue that turns peers into actual processes:

* :class:`FleetWorker` — a worker process's identity and channels: its
  (process_id, num_processes) coordinates, the shared ``fleet_dir`` it
  heartbeats into (one atomic JSON per rank, watched by the supervisor's
  hang detector), the optional jax.distributed coordinator, and the
  stripe-exchange ports for striped multi-host restore.
* :class:`TcpStripeExchange` / :class:`LocalStripeExchange` — all-gather
  of byte payloads across the fleet.  The striped restore in
  ``checkpoint/manager.py`` has each host read only its 1/N byte stripe
  of a shard file and obtain the rest from peers — restore I/O becomes
  traffic over the host mesh (the paper's FIFO-mesh "promote local data
  to global visibility" story applied to checkpoint bytes) instead of N
  redundant full reads.  The TCP implementation is the real-process
  transport (loopback or NIC); the Local one drives the same code path
  with simulated hosts (threads) in tests and benchmarks.
* :class:`TcpPageExchange` / :class:`LocalPageExchange` — POINT-TO-POINT
  migration of serving KV pages between fleet hosts (the serving fleet in
  ``serving/fleet.py``).  Where the stripe exchange all-gathers checkpoint
  bytes, the page exchange moves one prefix's pages from the host that
  OWNS them to the host that needs them — the paper's FIFO-mesh
  promote-local-to-global story at page granularity.  Frames carry a CRC
  per page (:func:`encode_page_frame`/:func:`decode_page_frame`);
  :class:`PageExchangeTimeout` (unreachable peer / netsplit) is
  deliberately distinct from :class:`PageCorruptError` (bad bytes) so the
  router can tell "retry elsewhere" from "recompute".
* :func:`tree_fingerprint` — an order-stable CRC over a pytree's leaf
  bytes, so two processes (or two runs) can assert bit-identical params
  by exchanging 16 hex chars instead of gigabytes.

Everything here is dependency-light (no jax import at module scope) so
the supervisor — which never touches an accelerator — starts fast.
"""
from __future__ import annotations

import dataclasses
import json
import os
import socket
import struct
import threading
import time
import zlib

HEARTBEAT_DIR = "hb"
_LEN = struct.Struct(">Q")


def allocate_ports(n: int, host: str = "127.0.0.1") -> list[int]:
    """Reserve ``n`` distinct ephemeral TCP ports (bind-0 then release).

    A small race window exists between release and the worker's bind;
    acceptable for a single-machine fleet (a collision crashes the
    worker, which the supervisor restarts on a fresh gang).
    """
    socks, ports = [], []
    try:
        for _ in range(n):
            s = socket.socket()
            s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            s.bind((host, 0))
            socks.append(s)
            ports.append(s.getsockname()[1])
    finally:
        for s in socks:
            s.close()
    return ports


def _write_json_atomic(path: str, obj: dict) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


def heartbeat_path(fleet_dir: str, tag: int) -> str:
    return os.path.join(fleet_dir, HEARTBEAT_DIR, f"rank_{tag}.json")


def read_heartbeat(fleet_dir: str, tag: int) -> dict | None:
    """Latest heartbeat of worker ``tag`` with the file's mtime attached
    (``_mtime``; the supervisor judges staleness by mtime, not by the
    worker's own clock).  None when the worker never heartbeat."""
    path = heartbeat_path(fleet_dir, tag)
    try:
        with open(path) as f:
            hb = json.load(f)
        hb["_mtime"] = os.stat(path).st_mtime
        return hb
    except (OSError, json.JSONDecodeError, ValueError):
        return None


def tree_fingerprint(tree) -> str:
    """Order-stable CRC32 over leaf (path, dtype, shape, bytes) — cheap
    cross-process bit-identity evidence.  Imports jax lazily (the
    supervisor never needs it)."""
    import jax
    import numpy as np
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    crc = 0
    for path, leaf in flat:
        arr = np.ascontiguousarray(np.asarray(leaf))
        head = f"{jax.tree_util.keystr(path)}|{arr.dtype}|{arr.shape}|"
        crc = zlib.crc32(head.encode(), crc)
        crc = zlib.crc32(arr.tobytes(), crc)
    return f"{crc:08x}"


# ---------------------------------------------------------------------------
# Stripe exchange: all-gather byte payloads across the fleet
# ---------------------------------------------------------------------------

class StripeExchangeTimeout(TimeoutError):
    """A peer never produced (or never served) its stripe in time.

    Deliberately NOT a :class:`~repro.checkpoint.CheckpointCorruptError`:
    the bytes on disk may be fine — the caller should fail the collective
    restore (and retry / fall back to full reads), not walk to an older
    checkpoint and silently lose steps.
    """


class LocalStripeExchange:
    """In-process all-gather for simulated hosts (threads) — the same
    interface the TCP transport provides, minus the sockets, so tests
    and benchmarks drive the striped-restore code path deterministically."""

    def __init__(self, world: int, *, timeout_s: float = 30.0):
        self.world = world
        self.timeout_s = timeout_s
        self._cv = threading.Condition()
        self._slots: dict[str, dict[int, bytes]] = {}

    def allgather(self, key: str, rank: int, world: int,
                  payload: bytes) -> list[bytes]:
        assert world == self.world, (world, self.world)
        deadline = time.monotonic() + self.timeout_s
        with self._cv:
            self._slots.setdefault(key, {})[rank] = payload
            self._cv.notify_all()
            while len(self._slots[key]) < world:
                left = deadline - time.monotonic()
                if left <= 0 or not self._cv.wait(timeout=left):
                    missing = sorted(set(range(world))
                                     - set(self._slots[key]))
                    raise StripeExchangeTimeout(
                        f"allgather {key!r}: ranks {missing} never arrived")
            return [self._slots[key][r] for r in range(world)]

    def close(self) -> None:
        with self._cv:
            self._slots.clear()
            self._cv.notify_all()


class TcpStripeExchange:
    """All-gather over loopback/NIC TCP: rank r serves its own payloads on
    ``ports[r]`` (daemon accept loop) and fetches each peer's from theirs.

    Protocol per connection: one request line ``<key>\\n``; the server
    blocks until it has published that key (bounded by its own timeout),
    then answers ``>Q`` length + payload.  Clients retry refused
    connections until the deadline — gang members reach the restore point
    at different times.
    """

    # extra seconds granted ONCE per fetch when the peer RESETS the
    # connection (a restarting peer is not a missing peer; refused /
    # plain timeouts get no grace — the peer was never there)
    RECONNECT_GRACE_S = 5.0

    def __init__(self, rank: int, ports: list[int], *,
                 host: str = "127.0.0.1", timeout_s: float = 60.0):
        self.rank = rank
        self.ports = list(ports)
        self.host = host
        self.timeout_s = timeout_s
        self.reconnects = 0             # reset-triggered deadline extensions
        self._cv = threading.Condition()
        self._published: dict[str, bytes] = {}
        self._closed = False
        self._srv = socket.socket()
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, self.ports[rank]))
        self._srv.listen(max(4, 2 * len(ports)))
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._thread.start()

    # -- server side --------------------------------------------------------

    def _serve(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return                          # socket closed
            threading.Thread(target=self._answer, args=(conn,),
                             daemon=True).start()

    def _answer(self, conn: socket.socket) -> None:
        try:
            conn.settimeout(self.timeout_s)
            buf = b""
            while not buf.endswith(b"\n"):
                chunk = conn.recv(256)
                if not chunk:
                    return
                buf += chunk
            key = buf[:-1].decode()
            deadline = time.monotonic() + self.timeout_s
            with self._cv:
                while key not in self._published and not self._closed:
                    left = deadline - time.monotonic()
                    if left <= 0 or not self._cv.wait(timeout=left):
                        return                  # requester will time out too
                payload = self._published.get(key)
            if payload is None:
                return
            conn.sendall(_LEN.pack(len(payload)) + payload)
        except OSError:
            pass
        finally:
            conn.close()

    # -- client side --------------------------------------------------------

    def _fetch(self, peer: int, key: str, deadline: float) -> bytes:
        last_err: Exception | None = None
        reconnected = False
        while time.monotonic() < deadline:
            try:
                with socket.create_connection(
                        (self.host, self.ports[peer]),
                        timeout=max(0.1, deadline - time.monotonic())) as c:
                    c.sendall(key.encode() + b"\n")
                    c.settimeout(max(0.1, deadline - time.monotonic()))
                    head = self._recv_exact(c, _LEN.size)
                    return self._recv_exact(c, _LEN.unpack(head)[0])
            except (ConnectionResetError, BrokenPipeError) as e:
                # the peer WAS there and dropped us mid-exchange — likely a
                # restart (supervisor bounce during striped restore).  One
                # bounded reconnect: extend the deadline once so a transient
                # bounce doesn't cost the caller a full-read fallback.
                last_err = e
                if not reconnected:
                    reconnected = True
                    self.reconnects += 1
                    deadline = max(deadline, time.monotonic() +
                                   min(self.RECONNECT_GRACE_S,
                                       self.timeout_s))
                time.sleep(0.05)
            except OSError as e:                # refused / timeout
                last_err = e
                time.sleep(0.05)
        raise StripeExchangeTimeout(
            f"rank {self.rank}: no stripe {key!r} from peer {peer} within "
            f"{self.timeout_s:.0f}s ({last_err})")

    @staticmethod
    def _recv_exact(conn: socket.socket, n: int) -> bytes:
        out = b""
        while len(out) < n:
            chunk = conn.recv(min(1 << 20, n - len(out)))
            if not chunk:
                raise OSError("peer closed mid-payload")
            out += chunk
        return out

    def allgather(self, key: str, rank: int, world: int,
                  payload: bytes) -> list[bytes]:
        assert rank == self.rank and world == len(self.ports), \
            (rank, self.rank, world, len(self.ports))
        with self._cv:
            self._published[key] = payload
            self._cv.notify_all()
        deadline = time.monotonic() + self.timeout_s
        out: list[bytes | None] = [None] * world
        out[rank] = payload
        for peer in range(world):
            if peer != rank:
                out[peer] = self._fetch(peer, key, deadline)
        return out  # type: ignore[return-value]

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        try:
            self._srv.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# Page exchange: point-to-point migration of serving KV pages
# ---------------------------------------------------------------------------

_PAGE_MAGIC = b"PGX1"


class PageExchangeTimeout(TimeoutError):
    """The owning host never served the migrated pages in time (dead peer,
    netsplit).  The router should fall back to recompute-from-longest-
    surviving-ancestor — the page CONTENT is not suspect."""


class PageCorruptError(RuntimeError):
    """A migrated page frame failed its CRC: the bytes that arrived are
    not the bytes that left.  Deliberately NOT a timeout — retrying the
    same transfer may succeed, but this copy must never enter the pool."""


def encode_page_frame(tokens, arrays) -> bytes:
    """One migrated page as a self-describing wire frame: magic, the
    page's token content, each pool entry's (key, dtype, shape, bytes),
    and a trailing CRC32 over everything after the magic.  The CRC makes
    corruption detectable at the RECEIVER, before the page touches the
    pool — the serving analogue of the checkpoint commit-marker CRC."""
    import numpy as np
    toks = [int(t) for t in tokens]
    body = bytearray()
    body += struct.pack(">I", len(toks))
    if toks:
        body += struct.pack(f">{len(toks)}i", *toks)
    body += struct.pack(">I", len(arrays))
    for key in sorted(arrays):
        arr = np.ascontiguousarray(np.asarray(arrays[key]))
        kb, db = key.encode(), str(arr.dtype).encode()
        body += struct.pack(">H", len(kb)) + kb
        body += struct.pack(">H", len(db)) + db
        body += struct.pack(">B", arr.ndim)
        if arr.ndim:
            body += struct.pack(f">{arr.ndim}I", *arr.shape)
        raw = arr.tobytes()
        body += struct.pack(">Q", len(raw)) + raw
    return _PAGE_MAGIC + bytes(body) + struct.pack(
        ">I", zlib.crc32(bytes(body)))


def decode_page_frame(frame: bytes):
    """Inverse of :func:`encode_page_frame`; raises
    :class:`PageCorruptError` on any structural or CRC mismatch.
    Returns ``(tokens, {key: np.ndarray})``."""
    import numpy as np
    if len(frame) < len(_PAGE_MAGIC) + 4 or \
            not frame.startswith(_PAGE_MAGIC):
        raise PageCorruptError("page frame: bad magic/header")
    body, (crc,) = frame[len(_PAGE_MAGIC):-4], struct.unpack(
        ">I", frame[-4:])
    if zlib.crc32(body) != crc:
        raise PageCorruptError("page frame: CRC mismatch")
    off = 0

    def take(n: int) -> bytes:
        nonlocal off
        if off + n > len(body):
            raise PageCorruptError("page frame: truncated")
        out = body[off:off + n]
        off += n
        return out

    (n_toks,) = struct.unpack(">I", take(4))
    tokens = struct.unpack(f">{n_toks}i", take(4 * n_toks)) \
        if n_toks else ()
    (n_arr,) = struct.unpack(">I", take(4))
    arrays = {}
    for _ in range(n_arr):
        (kl,) = struct.unpack(">H", take(2))
        key = take(kl).decode()
        (dl,) = struct.unpack(">H", take(2))
        dtype = take(dl).decode()
        (nd,) = struct.unpack(">B", take(1))
        shape = struct.unpack(f">{nd}I", take(4 * nd)) if nd else ()
        (nb,) = struct.unpack(">Q", take(8))
        arrays[key] = np.frombuffer(take(nb), dtype=dtype).reshape(shape)
    return tokens, arrays


def flip_frame_byte(frame: bytes) -> bytes:
    """XOR one mid-body byte (the ``pagecorrupt`` chaos payload) — the
    deterministic damage the receiver's CRC must catch."""
    off = len(_PAGE_MAGIC) + (len(frame) - len(_PAGE_MAGIC) - 4) // 2
    return frame[:off] + bytes([frame[off] ^ 0xFF]) + frame[off + 1:]


class LocalPageExchange:
    """In-process page-migration channel between the LocalFleet's hosts —
    same decode/CRC path the TCP transport exercises, with injectable
    fault hooks: ``blackout(host)`` (netsplit chaos: the transfer raises
    :class:`PageExchangeTimeout`) and ``corrupt_hook()`` (pagecorrupt
    chaos: one frame byte is flipped in flight).  Byte/frame counters
    feed the fleet's ``page_exchange_bytes`` metric."""

    def __init__(self):
        self.blackout = None            # callable(host) -> bool
        self.corrupt_hook = None        # callable() -> bool
        self.bytes_sent = 0
        self.frames_sent = 0

    def transfer(self, src_host: int, dst_host: int, frames):
        """Move encoded frames ``src -> dst``; returns the decoded
        ``(tokens, arrays)`` list.  Counts bytes before decoding — the
        wire carried them whether or not the CRC holds."""
        if self.blackout is not None and (self.blackout(src_host)
                                          or self.blackout(dst_host)):
            raise PageExchangeTimeout(
                f"netsplit: page channel {src_host}->{dst_host} is black")
        out = []
        for frame in frames:
            if self.corrupt_hook is not None and self.corrupt_hook():
                frame = flip_frame_byte(frame)
            self.bytes_sent += len(frame)
            self.frames_sent += 1
            out.append(decode_page_frame(frame))
        return out


class TcpPageExchange(TcpStripeExchange):
    """Point-to-point page migration over the stripe-exchange wire
    protocol: the source PUBLISHES its encoded frames under a migration
    key, the target FETCHES them from the source's port — no all-gather
    barrier (migration is point-to-point, like the paper's mesh hops).
    Inherits the server loop, length-prefixed framing, and the bounded
    reconnect-on-reset from :class:`TcpStripeExchange`."""

    def __init__(self, rank: int, ports: list[int], *,
                 host: str = "127.0.0.1", timeout_s: float = 60.0):
        super().__init__(rank, ports, host=host, timeout_s=timeout_s)
        self.bytes_sent = 0
        self.frames_sent = 0

    def publish(self, key: str, frames) -> None:
        payload = struct.pack(">I", len(frames)) + b"".join(
            _LEN.pack(len(f)) + f for f in frames)
        with self._cv:
            self._published[key] = payload
            self._cv.notify_all()

    def fetch(self, peer: int, key: str, *,
              timeout_s: float | None = None):
        """Decoded ``(tokens, arrays)`` frames published by ``peer``
        under ``key``; :class:`PageExchangeTimeout` when the peer never
        serves them."""
        deadline = time.monotonic() + (timeout_s if timeout_s is not None
                                       else self.timeout_s)
        try:
            payload = self._fetch(peer, key, deadline)
        except StripeExchangeTimeout as e:
            raise PageExchangeTimeout(str(e)) from None
        (n,) = struct.unpack(">I", payload[:4])
        off, frames = 4, []
        for _ in range(n):
            (ln,) = _LEN.unpack(payload[off:off + _LEN.size])
            off += _LEN.size
            frames.append(payload[off:off + ln])
            off += ln
        self.bytes_sent += sum(len(f) for f in frames)
        self.frames_sent += len(frames)
        return [decode_page_frame(f) for f in frames]


# ---------------------------------------------------------------------------
# Worker identity
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetWorker:
    """One worker process's view of the fleet (built from the CLI flags
    the supervisor passes to ``repro.launch.train``)."""

    process_id: int
    num_processes: int
    fleet_dir: str | None = None
    tag: int | None = None              # stable id across re-mesh renumbering
    coordinator: str | None = None
    stripe_ports: tuple[int, ...] = ()
    striped_restore: bool = False
    distributed: str = "none"           # none | jax
    result_out: str | None = None
    dist_ok: bool = False               # set after distributed_initialize

    def __post_init__(self):
        if self.tag is None:
            self.tag = self.process_id

    def heartbeat(self, step: int) -> None:
        """Atomically publish (step, wall time); the supervisor's hang
        detector watches the file's mtime."""
        if not self.fleet_dir:
            return
        os.makedirs(os.path.join(self.fleet_dir, HEARTBEAT_DIR),
                    exist_ok=True)
        try:
            _write_json_atomic(heartbeat_path(self.fleet_dir, self.tag),
                               {"rank": self.process_id, "step": int(step),
                                "wall": time.time()})
        except OSError:
            pass                        # a lost heartbeat must not kill a step

    def make_exchange(self, *, timeout_s: float = 60.0):
        """The stripe-exchange transport for this worker, or None when the
        supervisor allotted no ports (solo restart -> full-read restore)."""
        if len(self.stripe_ports) != self.num_processes \
                or self.num_processes < 2:
            return None
        return TcpStripeExchange(self.process_id, list(self.stripe_ports),
                                 timeout_s=timeout_s)

    def write_result(self, payload: dict) -> None:
        if self.result_out:
            os.makedirs(os.path.dirname(os.path.abspath(self.result_out)),
                        exist_ok=True)
            _write_json_atomic(self.result_out,
                               {"rank": self.process_id, "tag": self.tag,
                                "world": self.num_processes, **payload})
