"""Deterministic, seedable fault injection for the pod runtime.

The recovery paths in this repo (checkpoint fallback, elastic re-mesh,
nonfinite-grad skip) are only trustworthy if they are EXERCISED — a
recovery path that has never run is a second bug waiting behind the first.
This module injects the failures the training and serving stacks will
actually see, as a pure function of (spec, step, seed), so every chaos
scenario replays bit-identically in tests and CI.

Fault taxonomy (spec strings, parsed by :func:`parse_chaos`):

  ``kill@N``                       process death entering step N — raises
                                   :class:`ChaosKilled` (a ``SystemExit``
                                   with exit code 43, so ``--chaos kill@N``
                                   kills the launcher like a real preempt)
  ``silence@N:host=H,duration=D``  host H's heartbeats go dark for D steps
                                   starting at N (default: forever) — the
                                   monitor must evict it and the loop must
                                   re-mesh over the survivors
  ``slow@N:host=H,factor=F,duration=D``
                                   host H reports step times inflated by F
                                   (straggler; default forever) — the
                                   monitor's straggler logic must evict it
  ``nan@N:duration=D``             grads are scaled by NaN for D steps
                                   (default 1) starting at N — the train
                                   step's finite guard must skip the update
  ``corrupt@N:mode=flip|truncate,host=H``
                                   the checkpoint saved at train step N is
                                   corrupted on disk right after it lands
                                   (one flipped byte, or the shard cut in
                                   half) — restore must detect it by CRC
                                   and fall back to an older intact step

Serving-fleet faults (the multi-host serving fleet; see
``repro.serving.fleet``, tick-indexed on the FLEET's tick clock):

  ``die@T:host=H``                 serving host H dies entering fleet tick
                                   T — the router must tombstone its
                                   directory entries and re-admit its
                                   in-flight requests on survivors
                                   (worker mode: raises ChaosKilled so a
                                   real serve process exits 43 and the
                                   supervisor restarts it)
  ``netsplit@T:host=H,duration=D`` the page-migration channel to/from
                                   host H is black for D ticks starting
                                   at T — migrations raise
                                   PageExchangeTimeout and the router
                                   must fall back to prefix recompute
  ``pagecorrupt@T``                the next migrated KV page at tick >= T
                                   arrives with a flipped byte — the
                                   receiver's per-page CRC must reject it
                                   (PageCorruptError) and recompute

Process-level faults (the real-fleet runtime; see
``repro.runtime.supervisor``):

  ``sigkill@N:host=H``             SUPERVISOR-side: SIGKILL worker H once
                                   its heartbeat reports step >= N — an
                                   uncatchable death (no grace, no atexit)
                                   exercising the crash-restart path as a
                                   kernel would deliver it
  ``partition@N:host=H,duration=D``
                                   worker H stops publishing heartbeats
                                   for D steps starting at N (coordinator
                                   partition) — the supervisor's hang
                                   detector must SIGKILL + restart it
  ``diskfull@N``                   the checkpoint write at train step N
                                   fails with ENOSPC — training must log
                                   the failed save and CONTINUE (a full
                                   disk costs recovery-point age, never
                                   the run)

``kill``/``sigkill``/``partition`` specs target host 1 by default (host 0
writes the checkpoint manifests; drilling a non-primary is the common
case) — in the single-process simulated fleet ``kill`` fires regardless
of target because the only real process IS every host.

Usage::

    with ChaosInjector(["kill@12", "nan@5"], seed=0) as chaos:
        train.run(..., chaos=chaos)

or from the CLI: ``python -m repro.launch.train --arch qwen3-4b \
--chaos kill@12 --chaos nan@5``.  The injector records every fault it
fires in ``.fired`` so tests can assert the scenario actually happened.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

# SystemExit code for an injected kill: distinguishable from crashes (1)
# and clean exits (0) so restart harnesses can tell "chaos killed me" apart
# from "I am broken".
KILL_EXIT_CODE = 43

KINDS = ("kill", "silence", "slow", "nan", "corrupt",
         "sigkill", "partition", "diskfull",
         "die", "netsplit", "pagecorrupt")

# Kinds the process supervisor applies itself (everything else is handed
# through to the worker processes' --chaos flags).
SUPERVISOR_KINDS = ("sigkill",)

# How long a fault stays active when the spec gives no duration: a NaN
# burst is one step, but silence/slowness persist until eviction.
_FOREVER = 1 << 30
_DEFAULT_DURATION = {"kill": 1, "silence": _FOREVER, "slow": _FOREVER,
                     "nan": 1, "corrupt": 1, "sigkill": 1,
                     "partition": _FOREVER, "diskfull": 1,
                     "die": 1, "netsplit": 4, "pagecorrupt": 1}


class ChaosKilled(SystemExit):
    """Injected process death. Subclasses SystemExit so an unhandled kill
    exits the interpreter with :data:`KILL_EXIT_CODE`; tests catch it."""

    def __init__(self, step: int):
        super().__init__(KILL_EXIT_CODE)
        self.step = step

    def __str__(self) -> str:  # SystemExit.__str__ would print "43"
        return f"chaos: killed at step {self.step}"


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    kind: str                    # one of KINDS
    step: int                    # first step the fault is active
    host: int = -1               # target host (silence/slow) or shard
    #                              (corrupt); -1 -> host 1 / shard 0
    duration: int = 0            # steps active; 0 -> per-kind default
    factor: float = 4.0          # step-time inflation (slow)
    mode: str = "flip"           # corrupt: flip | truncate

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if self.duration == 0:
            object.__setattr__(self, "duration",
                               _DEFAULT_DURATION[self.kind])
        if self.host < 0:
            # silence/slow/kill/sigkill/partition/die/netsplit target a
            # PEER by default (host 0 is "us" / the manifest writer /
            # the serving fleet's first host); corrupt targets our own
            # shard 0, diskfull our own writer, pagecorrupt the channel
            object.__setattr__(self, "host",
                               0 if self.kind in ("corrupt", "diskfull",
                                                  "pagecorrupt")
                               else 1)

    def active(self, step: int) -> bool:
        return self.step <= step < self.step + self.duration


def parse_chaos(text: str) -> ChaosSpec:
    """``kind@step[:k=v,...]`` -> ChaosSpec (see module docstring)."""
    kind, sep, rest = text.partition("@")
    if not sep or not rest:
        raise ValueError(f"chaos spec {text!r}: expected 'kind@step[:opts]'")
    step_s, _, opts = rest.partition(":")
    kw: dict = {"kind": kind.strip(), "step": int(step_s)}
    for pair in filter(None, opts.split(",")):
        k, sep, v = pair.partition("=")
        if not sep:
            raise ValueError(f"chaos spec {text!r}: bad option {pair!r}")
        k = k.strip()
        if k in ("host", "duration"):
            kw[k] = int(v)
        elif k == "factor":
            kw[k] = float(v)
        elif k == "mode":
            kw[k] = v.strip()
        else:
            raise ValueError(f"chaos spec {text!r}: unknown option {k!r}")
    return ChaosSpec(**kw)


def split_spec_strings(specs) -> tuple[list[str], list[str]]:
    """Partition raw ``--chaos`` strings into (supervisor-side,
    worker-side) halves; the supervisor keeps ``sigkill`` for itself and
    forwards the rest to the worker processes' own ``--chaos`` flags."""
    sup, wrk = [], []
    for s in specs:
        (sup if parse_chaos(s).kind in SUPERVISOR_KINDS else wrk).append(s)
    return sup, wrk


def corrupt_checkpoint(ckpt_dir: str, step: int, *, host_id: int = 0,
                       mode: str = "flip", seed: int = 0) -> str:
    """Damage the shard ``host_id`` of checkpoint ``step`` on disk.

    ``flip`` XORs one byte in the middle third of the file (the CRC in the
    commit marker no longer matches); ``truncate`` cuts the file in half
    (np.load would die even without the CRC).  Returns the damaged path.
    """
    shard = os.path.join(ckpt_dir, f"step_{step:08d}",
                         f"shard_{host_id}.npz")
    size = os.path.getsize(shard)
    if mode == "truncate":
        with open(shard, "r+b") as f:
            f.truncate(size // 2)
    elif mode == "flip":
        rng = np.random.default_rng(seed)
        off = int(rng.integers(size // 3, 2 * size // 3))
        with open(shard, "r+b") as f:
            f.seek(off)
            byte = f.read(1)
            f.seek(off)
            f.write(bytes([byte[0] ^ 0xFF]))
    else:
        raise ValueError(f"unknown corrupt mode {mode!r}")
    return shard


class ChaosInjector:
    """Consulted by the train loop at its fault points; pure host state.

    Every query is a deterministic function of (specs, step, seed); the
    injector never holds clocks or randomness that would make a scenario
    unrepeatable.  ``fired`` logs each event once, in firing order.
    """

    def __init__(self, specs=(), *, seed: int = 0):
        self.specs = [parse_chaos(s) if isinstance(s, str) else s
                      for s in specs]
        self.seed = seed
        self.fired: list[str] = []

    # -- context manager (tests) -------------------------------------------

    def __enter__(self) -> "ChaosInjector":
        return self

    def __exit__(self, *exc) -> None:
        return None

    # -- internals ----------------------------------------------------------

    def _log(self, event: str) -> None:
        if event not in self.fired:
            self.fired.append(event)

    def _active(self, kind: str, step: int):
        return (sp for sp in self.specs
                if sp.kind == kind and sp.active(step))

    # -- fault points (one per taxonomy row) --------------------------------

    def maybe_kill(self, step: int, rank: int | None = None) -> None:
        """Raise :class:`ChaosKilled` when a kill spec is active.

        ``rank=None`` (the single-process simulated fleet) dies on ANY
        active kill — the one real process is every host.  A real fleet
        worker passes its rank and dies only when targeted (``host=``
        defaults to 1, a peer of the manifest-writing rank 0)."""
        for sp in self._active("kill", step):
            if rank is not None and sp.host != rank:
                continue
            self._log(f"kill@{step}")
            raise ChaosKilled(step)

    def partitioned(self, step: int, rank: int) -> bool:
        """True while ``rank`` must suppress its heartbeats (coordinator
        partition); the supervisor's hang detector takes it from there."""
        for sp in self._active("partition", step):
            if sp.host == rank:
                self._log(f"partition@{sp.step}:host={rank}")
                return True
        return False

    def checkpoint_write_hook(self, saved_step: int) -> None:
        """Installed as ``CheckpointManager(fault_hook=...)``: fails the
        write of step ``saved_step`` with ENOSPC when a diskfull spec
        targets it.  Runs on the manager's background writer thread; the
        error surfaces at the train loop's next ``wait()``."""
        import errno
        for sp in self.specs:
            if sp.kind == "diskfull" and sp.step == saved_step:
                self._log(f"diskfull@{saved_step}")
                raise OSError(errno.ENOSPC,
                              f"chaos: disk full writing checkpoint step "
                              f"{saved_step}")

    def supervisor_specs(self) -> list[ChaosSpec]:
        return [sp for sp in self.specs if sp.kind in SUPERVISOR_KINDS]

    def heartbeat_silenced(self, host: int, step: int) -> bool:
        for sp in self._active("silence", step):
            if sp.host == host:
                self._log(f"silence@{sp.step}:host={host}")
                return True
        return False

    def step_time_factor(self, host: int, step: int) -> float:
        f = 1.0
        for sp in self._active("slow", step):
            if sp.host == host:
                self._log(f"slow@{sp.step}:host={host}")
                f *= sp.factor
        return f

    def grad_scale(self, step: int) -> float:
        for sp in self._active("nan", step):
            self._log(f"nan@{step}")
            return float("nan")
        return 1.0

    def wants_corrupt(self, saved_step: int) -> bool:
        return any(sp.step == saved_step for sp in self.specs
                   if sp.kind == "corrupt")

    def maybe_corrupt(self, ckpt_dir: str, saved_step: int) -> None:
        """Called by the train loop right after checkpoint ``saved_step``
        is fully on disk (the loop waits for the async save first)."""
        for sp in self.specs:
            if sp.kind == "corrupt" and sp.step == saved_step:
                corrupt_checkpoint(ckpt_dir, saved_step, host_id=sp.host,
                                   mode=sp.mode, seed=self.seed)
                self._log(f"corrupt@{saved_step}:mode={sp.mode}")

    # -- serving-fleet fault points (fleet tick clock) ----------------------

    def should_die(self, tick: int, host: int) -> bool:
        """True exactly when serving host ``host`` must die entering fleet
        tick ``tick`` (the router's view: it marks the host dead and starts
        recovery).  Unlike ``maybe_kill`` this never raises — the in-process
        LocalFleet has no process to kill, only an engine to drop."""
        for sp in self._active("die", tick):
            if sp.host == host:
                self._log(f"die@{sp.step}:host={host}")
                return True
        return False

    def maybe_die(self, tick: int, host: int) -> None:
        """Worker-process flavour of ``should_die``: raises ChaosKilled so
        a real serve worker exits with :data:`KILL_EXIT_CODE` and the
        supervisor's restart policy takes over."""
        if self.should_die(tick, host):
            raise ChaosKilled(tick)

    def netsplit_active(self, tick: int, host: int) -> bool:
        """True while the page-migration channel to/from ``host`` is black
        (netsplit window).  The PageExchange consults this on both send and
        receive so a migration across the split times out symmetrically."""
        for sp in self._active("netsplit", tick):
            if sp.host == host:
                self._log(f"netsplit@{sp.step}:host={host}")
                return True
        return False

    def corrupt_next_page(self, tick: int) -> bool:
        """True ONCE per pagecorrupt spec, the first time it is consulted
        at tick >= the spec's step: the next migrated page frame gets one
        byte flipped in flight, and the receiver's CRC must catch it."""
        for sp in self.specs:
            if sp.kind != "pagecorrupt" or tick < sp.step:
                continue
            event = f"pagecorrupt@{sp.step}"
            if event not in self.fired:
                self.fired.append(event)
                return True
        return False
