"""Fault tolerance runtime: heartbeats, straggler detection, elastic re-mesh.

At cluster scale these hooks are driven by the coordinator (GCS / k8s / SLURM
plugin); the decision logic below is pure and unit-tested here, and the train
loop consumes it: on a failure the loop (1) stops, (2) restores the latest
checkpoint, (3) calls ``plan_elastic_remesh`` for the surviving host set,
(4) re-shards params/opt-state via checkpoint.restore(sharding_fn=...), and
(5) re-shards the data loader (ShardedLoader.reshard) — no data is lost
because the stream is indexable by step.

Straggler mitigation: hosts whose step time exceeds `straggler_factor` x the
fleet median for `patience` consecutive steps are treated as failed (evict +
elastic re-mesh) — the standard large-fleet remedy, cheaper than work
stealing for SPMD jobs where the collective pace is set by the slowest host.
"""
from __future__ import annotations

import dataclasses
import os
import time


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    step_times: list[float] = dataclasses.field(default_factory=list)
    slow_strikes: int = 0
    alive: bool = True


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    """Eviction thresholds — note the TWO time bases, easy to confuse:

    * ``heartbeat_timeout_s`` is measured on the monitor's CLOCK — wall
      seconds under the process supervisor, but *virtual steps* (the
      train loop ticks its clock 1.0 per step) in the simulated in-loop
      fleet.  A host whose last heartbeat is older than this is dead.
    * Straggler detection is STEP-RELATIVE and clock-free: a host is
      struck when its last *reported step time* exceeds
      ``straggler_factor`` x the median of its peers' step times, and
      evicted after ``patience`` consecutive strikes.  Rescaling the
      clock changes heartbeat timeouts but never straggler verdicts.

    Env overrides (read by :meth:`from_env`, used by the train launcher
    when no explicit value is passed): ``REPRO_HEARTBEAT_TIMEOUT``
    (float, clock units), ``REPRO_STRAGGLER_FACTOR`` (float),
    ``REPRO_STRAGGLER_PATIENCE`` (int).
    """

    heartbeat_timeout_s: float = 60.0
    straggler_factor: float = 2.0
    patience: int = 5

    @classmethod
    def from_env(cls, heartbeat_timeout_s: float | None = None,
                 straggler_factor: float | None = None,
                 patience: int | None = None,
                 default: "StragglerPolicy | None" = None
                 ) -> "StragglerPolicy":
        """Resolve each field as: explicit argument > env var > ``default``
        (a policy carrying the caller's baseline; class defaults if None).
        """
        base = default if default is not None else cls()

        def pick(explicit, env_name, cast, fallback):
            if explicit is not None:
                return explicit
            raw = os.environ.get(env_name)
            return cast(raw) if raw not in (None, "") else fallback

        return cls(
            heartbeat_timeout_s=pick(heartbeat_timeout_s,
                                     "REPRO_HEARTBEAT_TIMEOUT", float,
                                     base.heartbeat_timeout_s),
            straggler_factor=pick(straggler_factor,
                                  "REPRO_STRAGGLER_FACTOR", float,
                                  base.straggler_factor),
            patience=pick(patience, "REPRO_STRAGGLER_PATIENCE", int,
                          base.patience))


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """New mesh + data shard assignment after a host-set change."""

    n_hosts: int
    data_parallel: int
    model_parallel: int
    host_ranks: dict[int, int]     # host_id -> new rank


class HeartbeatMonitor:
    def __init__(self, host_ids: list[int],
                 policy: StragglerPolicy = StragglerPolicy(),
                 clock=time.monotonic):
        self._clock = clock
        self.policy = policy
        now = clock()
        self.hosts = {h: HostState(h, now) for h in host_ids}

    def heartbeat(self, host_id: int, step_time_s: float | None = None):
        st = self.hosts[host_id]
        st.last_heartbeat = self._clock()
        if step_time_s is not None:
            st.step_times.append(step_time_s)
            if len(st.step_times) > 32:
                st.step_times.pop(0)

    def _median_step(self, exclude: int | None = None) -> float | None:
        """Fleet median of the latest step times, optionally EXCLUDING one
        host: a host must be judged against its peers, not against a
        median its own sample drags — with n=2 the self-inclusive median
        of (fast, slow) sits at the slow sample and the straggler judges
        itself normal forever."""
        times = [st.step_times[-1] for st in self.hosts.values()
                 if st.alive and st.step_times and st.host_id != exclude]
        if not times:
            return None
        times.sort()
        return times[len(times) // 2]

    def check(self) -> list[int]:
        """Returns newly-failed/evicted host ids."""
        now = self._clock()
        failed = []
        for st in self.hosts.values():
            if not st.alive:
                continue
            if now - st.last_heartbeat > self.policy.heartbeat_timeout_s:
                st.alive = False
                failed.append(st.host_id)
                continue
            med = self._median_step(exclude=st.host_id)
            if med and st.step_times and \
                    st.step_times[-1] > self.policy.straggler_factor * med:
                st.slow_strikes += 1
                if st.slow_strikes >= self.policy.patience:
                    st.alive = False
                    failed.append(st.host_id)
            else:
                st.slow_strikes = 0
        return failed

    def alive_hosts(self) -> list[int]:
        return sorted(h for h, st in self.hosts.items() if st.alive)


def plan_elastic_remesh(alive_hosts: list[int], *, chips_per_host: int,
                        model_parallel: int) -> ElasticPlan:
    """Largest usable data-parallel extent over surviving hosts.

    Keeps the model-parallel extent fixed (param shards must still fit) and
    trims data-parallel to the largest power-of-two of surviving capacity —
    surplus hosts become hot spares. Global batch is preserved by the data
    layer (each host's slice grows); per-step time grows proportionally,
    which beats a dead cluster.
    """
    n = len(alive_hosts)
    total_chips = n * chips_per_host
    assert total_chips >= model_parallel, "not enough chips for model shards"
    dp = 1
    while dp * 2 * model_parallel <= total_chips:
        dp *= 2
    used_hosts = max(1, dp * model_parallel // chips_per_host)
    ranks = {h: i for i, h in enumerate(alive_hosts[:used_hosts])}
    return ElasticPlan(n_hosts=used_hosts, data_parallel=dp,
                       model_parallel=model_parallel, host_ranks=ranks)
