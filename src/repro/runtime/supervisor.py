"""Process supervisor: restart policy, chaos SIGKILL, elastic gang re-mesh.

The in-loop recovery machine (``launch/train.py``) heals a run from
INSIDE the process; this module is the layer a real fleet needs OUTSIDE
it — the thing systemd/k8s/SLURM would be, specialised to this repo's
failure taxonomy.  It spawns ``nprocs`` worker processes (rank R of W via
``repro.launch.train --process-id R --num-processes W``) and supervises
them against a restart policy keyed on EXIT STATUS::

    exit 0             worker finished its horizon          -> done
    exit 43            injected preemption (ChaosKilled)    -> restart
    other / signal     crash (SIGKILL, OOM, bug)            -> restart

Restarts are bounded: per-rank exponential backoff with deterministic
jitter (seeded by (chaos_seed, rank, attempt) so drills replay), a
per-rank restart cap after which the rank is EVICTED — the supervisor
SIGTERMs the surviving gang and relaunches it re-meshed over the
survivors via :func:`repro.runtime.fault.plan_elastic_remesh` (power-of-
two trim; surplus survivors park as hot spares) — and a global failure
budget after which everything is torn down cleanly, reporting the newest
COMMITTED checkpoint step so the operator knows the recovery point.

Liveness is judged from worker heartbeat files (``fleet_dir/hb/``, mtime
on the supervisor's clock): a worker that has heartbeat once and then
gone quiet for ``hang_timeout_s`` — e.g. chaos ``partition@N`` — is
SIGKILLed and takes the normal crash-restart path.  Supervisor-side
chaos (``sigkill@N:host=H``) kills rank H's process the moment its
heartbeat reaches step N: a REAL uncatchable death, no preemption grace.

Restarted workers get NO chaos flags — step-deterministic faults would
re-fire on every replay of the same step and the run would never finish.
Gang relaunches over an existing checkpoint pass ``--striped-restore``
(each rank reads 1/W of the shard bytes, peers exchange the rest);
solo restarts fall back to full reads because striping is collective.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import subprocess
import time

from repro.obs import REGISTRY

from .chaos import (KILL_EXIT_CODE, ChaosSpec, parse_chaos,
                    split_spec_strings)
from .fault import plan_elastic_remesh
from .fleet import HEARTBEAT_DIR, allocate_ports, read_heartbeat

__all__ = ["LaunchSpec", "RestartPolicy", "Supervisor", "KILL_EXIT_CODE"]


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """Bounds on self-healing; defaults sized for CI-scale drills."""

    max_restarts_per_rank: int = 2     # then: evict + gang re-mesh
    max_total_failures: int = 6        # then: clean shutdown
    backoff_base_s: float = 0.25
    backoff_max_s: float = 8.0
    backoff_jitter: float = 0.25       # +[0, jitter) * base, deterministic
    hang_timeout_s: float = 30.0       # quiet-heartbeat SIGKILL threshold
    term_grace_s: float = 5.0          # SIGTERM -> SIGKILL escalation
    max_wall_s: float = 0.0            # whole-run ceiling; 0 = unbounded

    def backoff_s(self, attempt: int, *, seed: int = 0,
                  rank: int = 0) -> float:
        """Exponential in ``attempt`` (1-based), capped, with jitter that
        is a pure function of (seed, rank, attempt) — string-seeded so it
        is stable across processes regardless of PYTHONHASHSEED."""
        base = min(self.backoff_max_s,
                   self.backoff_base_s * (2 ** max(0, attempt - 1)))
        rng = random.Random(f"{seed}:{rank}:{attempt}")
        return base * (1.0 + self.backoff_jitter * rng.random())


@dataclasses.dataclass(frozen=True)
class LaunchSpec:
    """What the command builder needs to know to exec one worker."""

    rank: int                       # rank in the CURRENT gang
    world: int                      # current gang size
    tag: int                        # stable id (initial rank) across re-mesh
    attempt: int                    # 1-based launch count for this tag
    with_chaos: bool                # pass --chaos flags (first launch only)
    striped: bool                   # gang restore may stripe shard reads
    stripe_ports: tuple[int, ...] = ()


@dataclasses.dataclass
class _Worker:
    tag: int
    rank: int
    state: str = "new"         # new|running|backoff|done|evicted|spare
    proc: subprocess.Popen | None = None
    log: object = None
    attempts: int = 0          # launches
    restarts: int = 0          # failures so far (attempts - 1 on relaunch)
    resume_at: float = 0.0
    launched_at: float = 0.0
    exit_history: list = dataclasses.field(default_factory=list)


class Supervisor:
    """Drive a gang of worker processes to completion under the policy.

    ``cmd_builder(spec: LaunchSpec) -> list[str]`` supplies the argv —
    the supervisor owns WHEN processes run, the launcher owns WHAT runs,
    so tests can supervise trivial stand-in scripts."""

    def __init__(self, nprocs: int, cmd_builder, *, fleet_dir: str,
                 policy: RestartPolicy | None = None,
                 chaos_specs=(), chaos_seed: int = 0,
                 ckpt_dir: str | None = None, poll_s: float = 0.05,
                 striped_restore: str = "auto"):
        assert nprocs >= 1
        assert striped_restore in ("auto", "always", "never")
        self.nprocs = nprocs
        self.cmd_builder = cmd_builder
        self.fleet_dir = fleet_dir
        self.policy = policy or RestartPolicy()
        self.chaos_seed = chaos_seed
        self.ckpt_dir = ckpt_dir
        self.poll_s = poll_s
        self.striped_restore = striped_restore
        sup_specs, _ = split_spec_strings(chaos_specs)
        self._sigkill_specs: list[ChaosSpec] = [parse_chaos(s)
                                                for s in sup_specs]
        self._sigkill_fired: set[int] = set()
        self.workers = [_Worker(tag=r, rank=r) for r in range(nprocs)]
        self.events: list[dict] = []
        self.total_failures = 0
        self.last_plan = None
        self._escalated = False
        os.makedirs(os.path.join(fleet_dir, HEARTBEAT_DIR), exist_ok=True)

    # -- bookkeeping --------------------------------------------------------

    def _event(self, kind: str, **kw) -> None:
        ev = {"kind": kind, "t": time.time(), **kw}
        self.events.append(ev)
        REGISTRY.counter("supervisor_events", kind=kind)
        detail = " ".join(f"{k}={v}" for k, v in kw.items())
        print(f"[supervisor] {kind} {detail}".rstrip())

    def _gang_world(self) -> list[_Worker]:
        """Members of the current gang (anything not evicted/spare)."""
        return [w for w in self.workers
                if w.state not in ("evicted", "spare")]

    def _ckpt_exists(self) -> bool:
        if not self.ckpt_dir or not os.path.isdir(self.ckpt_dir):
            return False
        return any(d.startswith("step_") and "tmp" not in d
                   for d in os.listdir(self.ckpt_dir))

    # -- launching ----------------------------------------------------------

    def _launch(self, w: _Worker, *, world: int, with_chaos: bool,
                striped: bool, stripe_ports: tuple[int, ...] = ()) -> None:
        w.attempts += 1
        spec = LaunchSpec(rank=w.rank, world=world, tag=w.tag,
                          attempt=w.attempts, with_chaos=with_chaos,
                          striped=striped, stripe_ports=stripe_ports)
        argv = self.cmd_builder(spec)
        log_path = os.path.join(self.fleet_dir,
                                f"log_rank{w.tag}_a{w.attempts}.log")
        w.log = open(log_path, "wb")
        w.proc = subprocess.Popen(argv, stdout=w.log, stderr=w.log)
        w.launched_at = time.time()
        w.state = "running"
        self._event("launch", tag=w.tag, rank=w.rank, world=world,
                    attempt=w.attempts, pid=w.proc.pid,
                    chaos=with_chaos, striped=striped)

    def _reap(self, w: _Worker) -> None:
        if w.log is not None:
            try:
                w.log.close()
            except OSError:
                pass
            w.log = None

    def _gang_launch(self, members: list[_Worker], *,
                     with_chaos: bool) -> None:
        world = len(members)
        if self.striped_restore == "always":
            striped = world > 1
        elif self.striped_restore == "never":
            striped = False
        else:
            striped = world > 1 and self._ckpt_exists()
        ports = tuple(allocate_ports(world)) if striped else ()
        for w in members:
            self._launch(w, world=world, with_chaos=with_chaos,
                         striped=striped, stripe_ports=ports)

    # -- failure handling ---------------------------------------------------

    def _classify(self, rc: int) -> str:
        if rc == 0:
            return "done"
        if rc == KILL_EXIT_CODE:
            return "chaos_exit"
        return "crash"

    def _on_exit(self, w: _Worker, rc: int) -> None:
        self._reap(w)
        w.proc = None
        kind = self._classify(rc)
        w.exit_history.append(rc)
        if kind == "done":
            w.state = "done"
            self._event("worker_done", tag=w.tag, rank=w.rank)
            return
        self.total_failures += 1
        self._event("worker_failed", tag=w.tag, rank=w.rank, rc=rc,
                    cause=kind, total_failures=self.total_failures)
        if self.total_failures > self.policy.max_total_failures:
            self._escalate("failure budget exhausted")
            return
        w.restarts += 1
        if w.restarts > self.policy.max_restarts_per_rank:
            self._evict_and_remesh(w)
            return
        delay = self.policy.backoff_s(w.restarts, seed=self.chaos_seed,
                                      rank=w.tag)
        w.state = "backoff"
        w.resume_at = time.time() + delay
        self._event("backoff", tag=w.tag, restarts=w.restarts,
                    delay_s=round(delay, 3))

    def _kill_worker(self, w: _Worker, *, graceful: bool) -> None:
        if w.proc is None or w.proc.poll() is not None:
            return
        try:
            if graceful:
                w.proc.terminate()
                try:
                    w.proc.wait(timeout=self.policy.term_grace_s)
                except subprocess.TimeoutExpired:
                    w.proc.kill()
                    w.proc.wait()
            else:
                w.proc.kill()
                w.proc.wait()
        except OSError:
            pass

    def _evict_and_remesh(self, dead: _Worker) -> None:
        """Repeated failure of one rank: stop paying its restarts.  Evict
        it, SIGTERM the surviving gang (their world size is stale), and
        relaunch re-meshed over the survivors."""
        dead.state = "evicted"
        self._event("evict", tag=dead.tag, restarts=dead.restarts)
        # spares rejoin the pool here — that is what they are for; done
        # workers already reached the horizon and stay finished
        survivors = [w for w in self.workers if w is not dead
                     and w.state in ("running", "backoff", "new", "spare")]
        for w in survivors:
            if w.state == "running":
                self._kill_worker(w, graceful=True)
                if w.proc is not None:
                    w.exit_history.append(w.proc.returncode)
                    w.proc = None
                self._reap(w)
        if not survivors:
            # nobody left NEEDING work — peers that already finished keep
            # their results (degraded), and if no one finished either the
            # outcome resolves to "failed"; both are judged at exit, not
            # escalated as a budget problem
            self._event("no_survivors", evicted=dead.tag)
            return
        plan = plan_elastic_remesh(sorted(w.tag for w in survivors),
                                   chips_per_host=1, model_parallel=1)
        self.last_plan = dataclasses.asdict(plan)
        gang = []
        for w in survivors:
            if w.tag in plan.host_ranks:
                w.rank = plan.host_ranks[w.tag]
                w.state = "new"
                gang.append(w)
            else:
                w.state = "spare"     # power-of-two trim: hot spare
                self._event("spare", tag=w.tag)
        self._event("remesh", survivors=[w.tag for w in gang],
                    world=len(gang), dp=plan.data_parallel)
        self._gang_launch(sorted(gang, key=lambda w: w.rank),
                          with_chaos=False)

    def _escalate(self, reason: str) -> None:
        """Global failure budget blown: stop burning the fleet.  Tear
        everything down gracefully (SIGTERM grace lets in-flight saves
        land) and leave the newest committed checkpoint as the recovery
        point."""
        self._event("escalate", reason=reason)
        for w in self.workers:
            if w.state == "running":
                self._kill_worker(w, graceful=True)
                if w.proc is not None:
                    w.exit_history.append(w.proc.returncode)
                    w.proc = None
                self._reap(w)
            if w.state in ("running", "backoff", "new"):
                w.state = "evicted"
        self._escalated = True

    # -- liveness -----------------------------------------------------------

    def _apply_sigkill_chaos(self, w: _Worker, now: float) -> None:
        for idx, sp in enumerate(self._sigkill_specs):
            if idx in self._sigkill_fired or sp.host != w.tag:
                continue
            hb = read_heartbeat(self.fleet_dir, w.tag)
            if hb is None or hb.get("_mtime", 0) < w.launched_at:
                continue                  # stale file from a prior attempt
            if hb.get("step", -1) >= sp.step:
                self._sigkill_fired.add(idx)
                self._event("chaos_sigkill", tag=w.tag, step=hb["step"],
                            spec_step=sp.step)
                self._kill_worker(w, graceful=False)

    def _check_hang(self, w: _Worker, now: float) -> None:
        """A worker that heartbeat once and then went dark (chaos
        ``partition``, a livelock, a wedged I/O) is indistinguishable
        from dead — SIGKILL it onto the ordinary crash-restart path.
        Judged only on heartbeats newer than this launch, so slow startup
        (jit warmup) is never mistaken for a hang."""
        hb = read_heartbeat(self.fleet_dir, w.tag)
        if hb is None or hb.get("_mtime", 0) < w.launched_at:
            return
        if now - hb["_mtime"] > self.policy.hang_timeout_s:
            self._event("hang_kill", tag=w.tag, last_step=hb.get("step"),
                        quiet_s=round(now - hb["_mtime"], 2))
            self._kill_worker(w, graceful=False)

    # -- main loop ----------------------------------------------------------

    def run(self) -> dict:
        t0 = time.time()
        self._escalated = False
        self._gang_launch(self._gang_world(), with_chaos=True)
        try:
            while any(w.state in ("running", "backoff", "new")
                      for w in self.workers):
                now = time.time()
                if 0 < self.policy.max_wall_s < now - t0:
                    # a serving fleet wedged on a dead coordinator or a
                    # migration loop must not hold CI hostage — same
                    # clean-teardown path as the failure budget
                    self._escalate("wall-clock ceiling "
                                   f"{self.policy.max_wall_s:.0f}s")
                    break
                for w in self.workers:
                    if w.state == "backoff" and now >= w.resume_at:
                        # solo relaunch: same gang geometry, no chaos,
                        # full-read restore (striping is collective)
                        self._launch(w, world=len(self._gang_world()),
                                     with_chaos=False, striped=False)
                    elif w.state == "running":
                        rc = w.proc.poll()
                        if rc is None:
                            self._apply_sigkill_chaos(w, now)
                            self._check_hang(w, now)
                        else:
                            self._on_exit(w, rc)
                time.sleep(self.poll_s)
        finally:
            for w in self.workers:      # never leak processes
                self._kill_worker(w, graceful=False)
                self._reap(w)
        if self._escalated:
            outcome = "budget_exhausted"
        elif all(w.state == "done" for w in self.workers):
            outcome = "completed"
        elif any(w.state == "done" for w in self.workers):
            outcome = "degraded"        # finished minus evicted/spares
        else:
            outcome = "failed"
        report = {
            "outcome": outcome,
            "nprocs": self.nprocs,
            "total_failures": self.total_failures,
            "wall_s": time.time() - t0,
            "plan": self.last_plan,
            "final_checkpoint_step": self._final_checkpoint_step(),
            "workers": [{"tag": w.tag, "rank": w.rank, "state": w.state,
                         "attempts": w.attempts, "restarts": w.restarts,
                         "exit_history": w.exit_history}
                        for w in self.workers],
            "events": self.events,
        }
        self._event("report", outcome=outcome,
                    failures=self.total_failures,
                    final_ckpt=report["final_checkpoint_step"])
        return report

    def _final_checkpoint_step(self) -> int | None:
        """Newest CRC-verified step — the committed recovery point the
        report promises.  Imported lazily: the supervisor itself never
        needs jax unless asked for this audit."""
        if not self.ckpt_dir:
            return None
        try:
            from repro.checkpoint import verified_steps
            steps = verified_steps(self.ckpt_dir)
            return steps[-1] if steps else None
        except Exception as e:
            self._event("ckpt_audit_error", error=str(e))
            return None


def write_report(path: str, report: dict) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(report, f, indent=2)
    os.replace(tmp, path)
