from . import compat
from .fault import (ElasticPlan, HeartbeatMonitor, HostState, StragglerPolicy,
                    plan_elastic_remesh)

__all__ = ["ElasticPlan", "HeartbeatMonitor", "HostState", "StragglerPolicy",
           "compat", "plan_elastic_remesh"]
