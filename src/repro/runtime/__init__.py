from . import chaos, compat
from .chaos import ChaosInjector, ChaosKilled, ChaosSpec, parse_chaos
from .fault import (ElasticPlan, HeartbeatMonitor, HostState, StragglerPolicy,
                    plan_elastic_remesh)

__all__ = ["ChaosInjector", "ChaosKilled", "ChaosSpec", "ElasticPlan",
           "HeartbeatMonitor", "HostState", "StragglerPolicy", "chaos",
           "compat", "parse_chaos", "plan_elastic_remesh"]
