from . import chaos, compat, fleet, supervisor
from .chaos import (ChaosInjector, ChaosKilled, ChaosSpec, parse_chaos,
                    split_spec_strings)
from .fault import (ElasticPlan, HeartbeatMonitor, HostState, StragglerPolicy,
                    plan_elastic_remesh)
from .fleet import (FleetWorker, LocalPageExchange, LocalStripeExchange,
                    PageCorruptError, PageExchangeTimeout,
                    StripeExchangeTimeout, TcpPageExchange,
                    TcpStripeExchange, allocate_ports, decode_page_frame,
                    encode_page_frame, read_heartbeat, tree_fingerprint)
from .supervisor import LaunchSpec, RestartPolicy, Supervisor

__all__ = ["ChaosInjector", "ChaosKilled", "ChaosSpec", "ElasticPlan",
           "FleetWorker", "HeartbeatMonitor", "HostState", "LaunchSpec",
           "LocalPageExchange", "LocalStripeExchange", "PageCorruptError",
           "PageExchangeTimeout", "RestartPolicy", "StragglerPolicy",
           "StripeExchangeTimeout", "Supervisor", "TcpPageExchange",
           "TcpStripeExchange", "allocate_ports", "chaos", "compat",
           "decode_page_frame", "encode_page_frame", "fleet",
           "parse_chaos", "plan_elastic_remesh", "read_heartbeat",
           "split_spec_strings", "supervisor", "tree_fingerprint"]
