"""Version-adaptive JAX portability layer.

Every symbol that drifted across the JAX releases this repo supports is
resolved ONCE here, at import time, and the rest of the codebase imports
from this module instead of touching the drifting API directly.  The
supported range is jax 0.4.37 (the pinned container baseline) through the
current ≥ 0.6/0.7 API family; each shim prefers the NEW spelling when it
exists and falls back to an equivalent on older releases, so the same
source runs unmodified on both ends of the range.

Shim inventory (new spelling -> introduced -> old fallback):

``make_mesh(axis_shapes, axis_names)``
    ``jax.make_mesh`` (added 0.4.35).  Fallback: build the device array
    with ``jax.experimental.mesh_utils.create_device_mesh`` and wrap it
    in ``jax.sharding.Mesh`` — identical semantics, no device reordering
    heuristics beyond what mesh_utils already applies.

``set_mesh(mesh)``
    Ambient-mesh context manager.  Prefers ``jax.set_mesh`` (promoted to
    the top level around 0.7, usable as a context manager), then
    ``jax.sharding.use_mesh`` (the experimental spelling added ~0.5.x).
    Fallback (0.4.x): a ``contextmanager`` that (a) records the concrete
    mesh in a module thread-local so :func:`get_abstract_mesh` can see it
    and (b) enters the legacy ``with mesh:`` resource env, which is what
    makes ``jax.lax.with_sharding_constraint(x, PartitionSpec(...))``
    accept bare PartitionSpecs on 0.4.x (outside a resource env that call
    raises ``RuntimeError: ... requires a non-empty mesh``).

``get_abstract_mesh()``
    ``jax.sharding.get_abstract_mesh`` (added ~0.5.0; returns an
    ``AbstractMesh``, empty when no ambient mesh is set).  Fallback: the
    thread-local *concrete* Mesh recorded by :func:`set_mesh`, or ``None``
    when no mesh context is active.  Callers therefore must treat "no
    mesh" as ``mesh is None or getattr(mesh, "empty", False)`` — both
    representations satisfy that test, and a concrete Mesh supports the
    same ``axis_names`` / ``shape`` lookups the call sites use.

``shard_map(f, *, mesh, in_specs, out_specs, ...)``
    ``jax.shard_map`` (public at the top level since ~0.6).  Fallback:
    ``jax.experimental.shard_map.shard_map`` with ``check_rep=False`` —
    0.4.x's replication checker predates the vma/pvary typing the new
    call sites rely on (they mark carries varying via :func:`pcast`,
    which is an identity on 0.4.x), so the old checker would reject
    otherwise-correct programs.  Disabling it trades away a static check
    and some transpose efficiency, never numerics.

``pcast(x, axes, to="varying")`` / ``vma(x)`` / ``match_vma(x, like)``
    The varying-manual-axes type system: ``jax.lax.pcast`` (0.7; 0.6
    spelled the varying direction ``jax.lax.pvary``) and
    ``jax.typeof(x).vma`` (0.6).  Fallback: ``pcast`` is the identity and
    ``vma`` returns ``frozenset()`` — on 0.4.x (with ``check_rep=False``)
    nothing tracks replication, so "already matches" is the correct
    degenerate answer.  ``match_vma(x, like)`` is the common idiom
    (promote ``x`` to carry every varying axis ``like`` has) packaged so
    call sites don't reimplement the set arithmetic.

``Element(n)`` / ``element_block_spec(block_shape, index_map)``
    Per-dimension element-indexed Pallas blocks: ``pl.Element`` (added
    with the BlockSpec indexing rework, ~0.6; the same rework REMOVED the
    0.4.x ``indexing_mode=`` argument, so the two spellings are mutually
    exclusive).  ``Element`` here is always this module's int-subclass
    marker; :func:`element_block_spec` translates it per version:

    * new JAX: marker dims become real ``pl.Element(n)`` dims and the
      user index map passes through untouched (element offsets for
      Element dims, block indices for Blocked dims);
    * 0.4.x: the whole spec is lowered to ``indexing_mode=pl.Unblocked()``
      (element offsets for EVERY dim) and the index map is wrapped to
      rescale the Blocked dims' block indices by their block sizes.
      Semantics are identical; only the index arithmetic moves.

``prefetch_scalar_grid_spec(...)``
    TPU scalar-prefetch grid spec (index maps may read prefetched scalar
    refs — how the paged-attention kernel chases its page table).  The
    class has lived at ``pltpu.PrefetchScalarGridSpec`` across the whole
    supported range but is resolved lazily here (no eager
    ``pallas.tpu`` import for sim-only entry points) and probed at both
    its TPU-module and core-pallas homes so a future relocation lands in
    one place.

``tpu_compiler_params(**kwargs)``
    ``pltpu.CompilerParams`` (renamed ~0.6/0.7) vs ``TPUCompilerParams``
    (0.4.x–0.5.x).  Returns a ``{"compiler_params": ...}`` kwargs dict
    ready to splat into ``pl.pallas_call``, or ``{}`` when neither class
    exists or the signature rejects the request (signature drift) — the
    params are a performance hint, so dropping them is always safe.

``cost_analysis(compiled)``
    ``Compiled.cost_analysis()`` returns a per-module ``dict`` on ≥ 0.5
    but a one-element ``list`` of dicts on 0.4.x.  This wrapper always
    returns the flat dict (``{}`` for an empty list).

``memory_stats(compiled)``
    Normalized ``Compiled.memory_analysis()`` byte counts.  The analysis
    object's availability and attribute spellings vary by backend and
    release (some backends return ``None``, some raise, TPU adds fields
    CPU lacks), so this wrapper always returns the same four-key dict
    with zeros for anything missing — callers treat it as best-effort
    telemetry (dry-run tables, ring benchmarks, residual-size tests).

``tree_map`` / ``tree_leaves`` / ``tree_flatten`` / ``tree_unflatten``
    ``jax.tree.*`` (added 0.4.25, the preferred spelling; the historical
    ``jax.tree_map`` aliases were deleted in 0.6).  Fallback:
    ``jax.tree_util.tree_*``, which exist everywhere.

``random_key(seed)``
    Typed PRNG keys: ``jax.random.key`` (0.4.16).  Fallback:
    ``jax.random.PRNGKey`` (raw uint32 keys).  Both feed every
    ``jax.random`` sampler in the supported range.

``distributed_initialize(coordinator, num_processes, process_id)``
    Multi-process runtime bring-up (``jax.distributed.initialize``).
    The core three keywords are stable across the supported range, but
    the surrounding signature drifts (0.6 added
    ``cluster_detection_method``; ``initialization_timeout`` moved) — so
    the call is filtered against the live signature and failure is a
    WARNED ``False``, never an exception: a fleet worker whose
    distributed runtime cannot come up still runs its local replica, it
    just reports ``dist_ok=False``.  ``distributed_shutdown()`` is the
    matching best-effort teardown.

Import-order note: the Pallas shims resolve ``jax.experimental.pallas``
lazily on first use (cached thereafter), so sim/benchmark entry points
that never touch a kernel don't pay the Pallas import; nothing in this
module touches device state, so importing it cannot pin a backend.
"""
from __future__ import annotations

import contextlib
import inspect
import threading
import warnings
from typing import Any, Callable, Sequence

import jax

__all__ = [
    "JAX_VERSION",
    "make_mesh", "set_mesh", "get_abstract_mesh", "shard_map",
    "pcast", "vma", "match_vma",
    "Element", "element_block_spec", "prefetch_scalar_grid_spec",
    "tpu_compiler_params",
    "cost_analysis", "memory_stats",
    "tree_map", "tree_leaves", "tree_flatten", "tree_unflatten",
    "random_key",
    "distributed_initialize", "distributed_shutdown",
]

JAX_VERSION: tuple[int, ...] = tuple(
    int(p) for p in jax.__version__.split(".")[:3] if p.isdigit())


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------

if hasattr(jax, "make_mesh"):
    make_mesh = jax.make_mesh
else:  # pragma: no cover - exercised only on jax < 0.4.35
    def make_mesh(axis_shapes: Sequence[int],
                  axis_names: Sequence[str]) -> jax.sharding.Mesh:
        from jax.experimental import mesh_utils
        devices = mesh_utils.create_device_mesh(tuple(axis_shapes))
        return jax.sharding.Mesh(devices, tuple(axis_names))


# ---------------------------------------------------------------------------
# Ambient mesh context: set_mesh / get_abstract_mesh
# ---------------------------------------------------------------------------

_tls = threading.local()

if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
elif hasattr(jax.sharding, "use_mesh"):
    set_mesh = jax.sharding.use_mesh
else:
    @contextlib.contextmanager
    def set_mesh(mesh: jax.sharding.Mesh):
        prev = getattr(_tls, "mesh", None)
        _tls.mesh = mesh
        try:
            # legacy resource env: lets with_sharding_constraint resolve
            # bare PartitionSpecs against `mesh` while tracing inside.
            with mesh:
                yield mesh
        finally:
            _tls.mesh = prev


if hasattr(jax.sharding, "get_abstract_mesh"):
    get_abstract_mesh = jax.sharding.get_abstract_mesh
else:
    def get_abstract_mesh():
        return getattr(_tls, "mesh", None)


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def shard_map(f: Callable, *, mesh=None, in_specs, out_specs, **kwargs):
        kwargs.setdefault("check_rep", False)
        return _shard_map_experimental(f, mesh=mesh, in_specs=in_specs,
                                       out_specs=out_specs, **kwargs)


# ---------------------------------------------------------------------------
# Varying-manual-axes (vma) typing: pcast / vma / match_vma
# ---------------------------------------------------------------------------

if hasattr(jax.lax, "pcast"):
    pcast = jax.lax.pcast
elif hasattr(jax.lax, "pvary"):
    def pcast(x, axes, to: str = "varying"):
        if to != "varying":
            raise NotImplementedError(
                f"pcast(to={to!r}) has no jax-0.6 equivalent shimmed here")
        return jax.lax.pvary(x, axes)
else:
    def pcast(x, axes, to: str = "varying"):
        return x


def vma(x) -> frozenset:
    """The varying manual axes of ``x``'s type; empty pre-0.6 (untracked)."""
    try:
        return frozenset(jax.typeof(x).vma)
    except AttributeError:
        return frozenset()


def match_vma(x, like):
    """Promote ``x`` to carry every varying axis ``like`` carries.

    Inside shard_map on ≥ 0.6, scan/loop carries must be typed with the
    same varying axes as the values they combine with; pre-0.6 this is a
    no-op because nothing is tracked."""
    want = vma(like) - vma(x)
    if want:
        x = pcast(x, tuple(want), to="varying")
    return x


# ---------------------------------------------------------------------------
# Pallas: element-indexed BlockSpecs
# ---------------------------------------------------------------------------

_pallas_mod = None


def _pallas():
    """Lazy, cached ``jax.experimental.pallas`` — kernels are the only
    consumers, so pure-sim entry points never pay this import."""
    global _pallas_mod
    if _pallas_mod is None:
        from jax.experimental import pallas
        _pallas_mod = pallas
    return _pallas_mod


class Element(int):
    """Marker for a block dim whose index-map output is an ELEMENT offset
    (halo/overlapping windows), not a block index.  Use only inside
    :func:`element_block_spec` block shapes."""


def element_block_spec(block_shape: Sequence[int],
                       index_map: Callable[..., tuple]):
    """BlockSpec mixing :class:`Element` (element-indexed) and plain int
    (block-indexed) dims.  ``index_map`` follows the NEW JAX convention:
    element offsets for Element dims, block indices for the rest."""
    pl = _pallas()
    pl_element = getattr(pl, "Element", None)
    if pl_element is not None:
        shape = tuple(pl_element(int(d)) if isinstance(d, Element) else d
                      for d in block_shape)
        return pl.BlockSpec(shape, index_map)
    sizes = tuple(int(d) for d in block_shape)
    is_element = tuple(isinstance(d, Element) for d in block_shape)

    def as_element_offsets(*grid_idx):
        idx = index_map(*grid_idx)
        return tuple(i if e else i * s
                     for i, e, s in zip(idx, is_element, sizes))

    return pl.BlockSpec(sizes, as_element_offsets,
                        indexing_mode=pl.Unblocked())


# ---------------------------------------------------------------------------
# Pallas: scalar-prefetch grid specs
# ---------------------------------------------------------------------------

def prefetch_scalar_grid_spec(*, num_scalar_prefetch: int, grid,
                              in_specs, out_specs, scratch_shapes=()):
    """Grid spec whose first ``num_scalar_prefetch`` operands are scalar
    arrays prefetched before the kernel runs and passed to every index map
    (trailing arguments) and to the kernel body (leading refs).  This is
    the mechanism behind page-table indirection in the paged-attention
    kernel.  Resolved lazily; probed in both ``pallas.tpu`` and core
    ``pallas`` so a relocation upstream is a one-line fix here."""
    from jax.experimental.pallas import tpu as pltpu
    cls = (getattr(pltpu, "PrefetchScalarGridSpec", None)
           or getattr(_pallas(), "PrefetchScalarGridSpec", None))
    if cls is None:  # pragma: no cover - no release in range lacks it
        raise NotImplementedError(
            "PrefetchScalarGridSpec not found in this JAX; the paged "
            "attention kernel needs scalar prefetch")
    return cls(num_scalar_prefetch=num_scalar_prefetch, grid=grid,
               in_specs=in_specs, out_specs=out_specs,
               scratch_shapes=scratch_shapes)


# ---------------------------------------------------------------------------
# Pallas: TPU compiler params
# ---------------------------------------------------------------------------

def tpu_compiler_params(**kwargs) -> dict[str, Any]:
    """``{"compiler_params": <params>}`` to splat into ``pl.pallas_call``,
    or ``{}`` when the class is missing or its signature rejects ``kwargs``
    (params are a scheduling hint — dropping them is always safe)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = (getattr(pltpu, "CompilerParams", None)
           or getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:
        return {}
    try:
        return {"compiler_params": cls(**kwargs)}
    except TypeError:
        return {}


# ---------------------------------------------------------------------------
# Compiled-artifact introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict[str, float]:
    """Flat cost dict from a ``Compiled`` object (0.4.x returns a
    one-element list of dicts; ≥ 0.5 returns the dict directly)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def memory_stats(compiled) -> dict[str, int]:
    """Normalized ``Compiled.memory_analysis()`` numbers, in bytes.

    Always returns ``{"argument_bytes", "output_bytes", "temp_bytes",
    "peak_bytes"}`` with zeros when the backend offers no analysis or an
    attribute is missing.  ``peak_bytes`` is arguments + temporaries:
    donated outputs alias their inputs on TPU, so args+temp approximates
    the device peak (the CPU backend ignores donation, hence not
    args+temp+out)."""
    try:
        mem = compiled.memory_analysis()
    except Exception:  # noqa: BLE001 — backend without the analysis
        mem = None

    def _get(name: str) -> int:
        try:
            return int(getattr(mem, name, 0) or 0)
        except Exception:  # noqa: BLE001 — non-numeric drift
            return 0

    arg = _get("argument_size_in_bytes")
    out = _get("output_size_in_bytes")
    tmp = _get("temp_size_in_bytes")
    return {"argument_bytes": arg, "output_bytes": out,
            "temp_bytes": tmp, "peak_bytes": arg + tmp}


# ---------------------------------------------------------------------------
# Distributed runtime: multi-process peers
# ---------------------------------------------------------------------------

def distributed_initialize(coordinator_address: str, num_processes: int,
                           process_id: int, *,
                           timeout_s: float | None = None,
                           **extra) -> bool:
    """Bring up the multi-process runtime; ``True`` iff peers are joined.

    Filters the request against the live ``jax.distributed.initialize``
    signature (keywords around the stable core drift across 0.4.x/0.6.x)
    and degrades to a warned ``False`` on any failure — callers treat the
    distributed runtime as an upgrade, not a requirement.  A second call
    in an already-initialized process returns ``True``.
    """
    dist = getattr(jax, "distributed", None)
    init = getattr(dist, "initialize", None)
    if init is None:  # pragma: no cover - every release in range has it
        warnings.warn("jax.distributed.initialize not found; running "
                      "without a distributed runtime", RuntimeWarning)
        return False
    kwargs: dict[str, Any] = {"coordinator_address": coordinator_address,
                              "num_processes": int(num_processes),
                              "process_id": int(process_id), **extra}
    if timeout_s is not None:
        kwargs["initialization_timeout"] = int(timeout_s)
    try:
        params = inspect.signature(init).parameters
        if not any(p.kind is inspect.Parameter.VAR_KEYWORD
                   for p in params.values()):
            kwargs = {k: v for k, v in kwargs.items() if k in params}
    except (TypeError, ValueError):  # pragma: no cover - C-level signature
        pass
    try:
        init(**kwargs)
        return True
    except Exception as e:  # noqa: BLE001 — availability probe by contract
        if "already" in str(e).lower():
            return True
        warnings.warn(f"jax distributed runtime failed to initialize "
                      f"({type(e).__name__}: {e}); continuing single-process",
                      RuntimeWarning)
        return False


def distributed_shutdown() -> None:
    """Best-effort ``jax.distributed.shutdown`` (no-op when never up)."""
    try:
        jax.distributed.shutdown()
    except Exception:  # noqa: BLE001 — teardown must never mask exit status
        pass


# ---------------------------------------------------------------------------
# Tree / random aliases
# ---------------------------------------------------------------------------

if hasattr(jax, "tree") and hasattr(jax.tree, "map"):
    tree_map = jax.tree.map
    tree_leaves = jax.tree.leaves
    tree_flatten = jax.tree.flatten
    tree_unflatten = jax.tree.unflatten
else:  # pragma: no cover - exercised only on jax < 0.4.25
    from jax import tree_util as _tree_util
    tree_map = _tree_util.tree_map
    tree_leaves = _tree_util.tree_leaves
    tree_flatten = _tree_util.tree_flatten
    tree_unflatten = _tree_util.tree_unflatten

random_key = getattr(jax.random, "key", None) or jax.random.PRNGKey
