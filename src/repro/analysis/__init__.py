from .roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, RooflineReport,
                       collective_bytes, model_flops_decode,
                       model_flops_train)

__all__ = ["HBM_BW", "ICI_BW", "PEAK_FLOPS", "RooflineReport",
           "collective_bytes", "model_flops_decode", "model_flops_train"]
