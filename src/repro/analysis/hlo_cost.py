"""Scan-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, which makes
scan-over-layers models look ~L x cheaper than they are. This parser walks
the HLO module, multiplies loop bodies by their ``known_trip_count`` (XLA
annotates it in backend_config), and produces three totals per module:

  * flops            — 2*prod(out)*prod(contracted) per dot (+ convolutions)
  * traffic bytes    — per top-level op: operands + outputs, fusion
                        internals ignored (they live in registers/VMEM),
                        dynamic-(update-)slice counted at slice size
  * collective bytes — output bytes of all-gather/all-reduce/reduce-scatter/
                        all-to-all/collective-permute, x enclosing trip counts

All three are PER-DEVICE quantities when the module was SPMD-partitioned
(shapes in optimized HLO are already the per-partition shapes).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*\))?\s*->.*{")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "reshape", "after-all", "partition-id",
               "replica-id", "iota", "broadcast"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    opcode: str
    rest: str          # operand list + attrs


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list[_Op]
    symtab: dict[str, str]     # value name -> shape string


def parse_module(hlo: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if line.endswith("{") and "->" in line:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = _Computation(m.group(1), [], {})
                comps[cur.name] = cur
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape, opcode, rest = m.groups()
        cur.ops.append(_Op(name, shape, opcode, rest))
        cur.symtab[name] = shape
    return comps


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    collective_bytes: float
    collectives: dict[str, float]


def _dot_flops(op: _Op, symtab: dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(op.shape):
        out_elems *= d
    mc = _CONTRACT_RE.search(op.rest)
    operands = _OPERAND_RE.findall(op.rest.split(")")[0])
    contracted = 1
    if mc and operands:
        lhs_shape = symtab.get(operands[0], "")
        dims = _shape_dims(lhs_shape)
        for idx in (int(i) for i in mc.group(1).split(",") if i):
            if idx < len(dims):
                contracted *= dims[idx]
    return 2.0 * out_elems * contracted


def _conv_flops(op: _Op, symtab: dict[str, str]) -> float:
    out_elems = 1
    for d in _shape_dims(op.shape):
        out_elems *= d
    operands = _OPERAND_RE.findall(op.rest.split(")")[0])
    k_elems = 1
    kdims = _shape_dims(symtab.get(operands[1], "")) if len(operands) > 1 \
        else []
    for d in kdims:
        k_elems *= d
    ofeat = kdims[-1] if kdims else 1        # HWIO convention
    return 2.0 * out_elems * (k_elems / max(1, ofeat))


def _fusion_input_bytes(comp: _Computation) -> float:
    """Bytes a fused computation actually READS.

    A fusion operand that is only consumed by dynamic-slice ops inside the
    fusion contributes the SLICE bytes, not the full array — this is what
    keeps scan-over-stacked-params models from looking like they re-stream
    the whole parameter stack every layer.
    """
    params: dict[str, str] = {}
    for op in comp.ops:
        if op.opcode == "parameter":
            params[op.name] = op.shape
    # consumers
    sliced_bytes: dict[str, float] = {}
    full_needed: set[str] = set()
    for op in comp.ops:
        if op.opcode == "parameter":
            continue
        for o in _OPERAND_RE.findall(op.rest.split(")")[0]):
            if o not in params:
                continue
            if op.opcode == "dynamic-slice":
                sliced_bytes[o] = sliced_bytes.get(o, 0.0) + \
                    _shape_bytes(op.shape)
            else:
                full_needed.add(o)
    total = 0.0
    for name, shape in params.items():
        if name in full_needed or name not in sliced_bytes:
            total += _shape_bytes(shape)
        else:
            total += sliced_bytes[name]
    return total


def _op_bytes(op: _Op, symtab: dict[str, str]) -> float:
    if op.opcode in _SKIP_BYTES:
        return 0.0
    out_b = _shape_bytes(op.shape)
    if op.opcode in ("dynamic-slice",):
        return 2.0 * out_b
    if op.opcode in ("dynamic-update-slice",):
        # read+write of the update slice; locate the update operand (2nd)
        operands = _OPERAND_RE.findall(op.rest.split(")")[0])
        upd = _shape_bytes(symtab.get(operands[1], "")) if len(operands) > 1 \
            else out_b
        return 2.0 * upd
    in_b = 0.0
    for o in _OPERAND_RE.findall(op.rest.split(")")[0]):
        in_b += _shape_bytes(symtab.get(o, ""))
    return out_b + in_b


def _cost_of(comp_name: str, comps: dict[str, _Computation],
             memo: dict[str, HloCost]) -> HloCost:
    if comp_name in memo:
        return memo[comp_name]
    comp = comps.get(comp_name)
    if comp is None:
        return HloCost(0, 0, 0, {})
    fl = by = cb = 0.0
    cd: dict[str, float] = {}
    for op in comp.ops:
        if op.opcode == "dot":
            fl += _dot_flops(op, comp.symtab)
            by += _op_bytes(op, comp.symtab)
        elif op.opcode == "convolution":
            fl += _conv_flops(op, comp.symtab)
            by += _op_bytes(op, comp.symtab)
        elif op.opcode == "while":
            trip = 1
            mt = _TRIP_RE.search(op.rest)
            if mt:
                trip = int(mt.group(1))
            body = _CALL_RE.search(op.rest)
            cond = _COND_RE.search(op.rest)
            if body:
                sub = _cost_of(body.group(1), comps, memo)
                fl += trip * sub.flops
                by += trip * sub.bytes
                cb += trip * sub.collective_bytes
                for k, v in sub.collectives.items():
                    cd[k] = cd.get(k, 0.0) + trip * v
            if cond:
                sub = _cost_of(cond.group(1), comps, memo)
                fl += trip * sub.flops
                by += trip * sub.bytes
        elif op.opcode in ("fusion", "call", "custom-call", "reduce",
                           "sort", "scatter", "map", "reduce-window",
                           "select-and-scatter"):
            m = _CALL_RE.search(op.rest)
            if op.opcode == "fusion" and m and m.group(1) in comps:
                by += _shape_bytes(op.shape) + \
                    _fusion_input_bytes(comps[m.group(1)])
            else:
                by += _op_bytes(op, comp.symtab)
            if m:
                sub = _cost_of(m.group(1), comps, memo)
                fl += sub.flops               # dots inside fusions count
                cb += sub.collective_bytes
                for k, v in sub.collectives.items():
                    cd[k] = cd.get(k, 0.0) + v
        elif op.opcode == "conditional":
            by += _op_bytes(op, comp.symtab)
            m = _BRANCH_RE.search(op.rest)
            if m:
                for b in _OPERAND_RE.findall(m.group(1)):
                    sub = _cost_of(b, comps, memo)
                    fl += sub.flops
                    by += sub.bytes
                    cb += sub.collective_bytes
        elif op.opcode in _COLLECTIVES:
            b = _shape_bytes(op.shape)
            cb += b
            cd[op.opcode] = cd.get(op.opcode, 0.0) + b
            by += _op_bytes(op, comp.symtab)
        else:
            by += _op_bytes(op, comp.symtab)
    out = HloCost(fl, by, cb, cd)
    memo[comp_name] = out
    return out


def module_cost(hlo: str) -> HloCost:
    comps = parse_module(hlo)
    entry = None
    for raw in hlo.splitlines():
        if raw.startswith("ENTRY"):
            m = _COMP_RE.match(raw.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:  # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else ""
    memo: dict[str, HloCost] = {}
    # fusion computations are reachable from entry; memoization keeps this
    # linear in module size.
    return _cost_of(entry, comps, memo)
