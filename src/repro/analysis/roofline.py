"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh):
    compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips * 819e9 B/s HBM)
    collective = collective_bytes / (chips * 50e9 B/s per ICI link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
are NOT in cost_analysis — we parse the optimized HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops. MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE)
per step gives the useful-compute ratio (catches remat/redundancy waste).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, one direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[4,128]' -> 1024. Tuples handled by the caller via findall."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum OUTPUT shape bytes of every collective op in optimized HLO."""
    out: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # ops look like: `%x = bf16[..]{..} all-gather(...)`, fusions don't
        # contain collectives so a substring match on the op name is safe.
        m = re.search(r"=\s+(\(?[a-z0-9_\[\],\s{}:#\"\/\.\-]*?\)?)\s+"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)", s)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        out[opname] += _shape_bytes(shape_str)
    return out


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: dict[str, int]
    model_flops: float
    bytes_per_device: float     # peak HBM from memory_analysis
    model_bytes: float = 0.0    # analytic HBM traffic floor (global)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(1.0, self.hlo_flops)

    @property
    def t_model_min(self) -> float:
        """Theoretical floor: max of the model's compute time at peak and
        its minimum HBM traffic at full bandwidth (decode shapes are
        memory-floor-bound; train shapes compute-floor-bound)."""
        return max(self.model_flops / (self.chips * PEAK_FLOPS),
                   self.model_bytes / (self.chips * HBM_BW))

    @property
    def roofline_frac(self) -> float:
        """useful work / the time the dominant term implies at peak."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.t_model_min / t

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_gflops": self.hlo_flops / 1e9,
            "hlo_gbytes": self.hlo_bytes / 1e9,
            "coll_gbytes": self.coll_bytes / 1e9,
            "t_compute_ms": self.t_compute * 1e3,
            "t_memory_ms": self.t_memory * 1e3,
            "t_collective_ms": self.t_collective * 1e3,
            "dominant": self.dominant,
            "model_gflops": self.model_flops / 1e9,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_frac": self.roofline_frac,
            "hbm_gb_per_device": self.bytes_per_device / 1e9,
            "coll_breakdown": {k: v for k, v in
                               self.coll_breakdown.items() if v},
        }


def model_flops_train(n_active_params: int, tokens: int) -> float:
    return 6.0 * n_active_params * tokens


def model_flops_decode(n_active_params: int, tokens: int,
                       kv_read_flops: float = 0.0) -> float:
    return 2.0 * n_active_params * tokens + kv_read_flops
