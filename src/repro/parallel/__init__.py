from .sharding import (param_specs, batch_specs, cache_specs,
                       make_shardings)
from .ring_matmul import ring_matmul, ring_matmul_ref
from .ring_attention import ring_attention
from .pipeline import pipeline_forward

__all__ = ["param_specs", "batch_specs", "cache_specs", "make_shardings",
           "ring_matmul", "ring_matmul_ref", "ring_attention",
           "pipeline_forward"]
