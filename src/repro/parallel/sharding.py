"""Per-architecture sharding rules (DP / TP / EP / SP on the 2-3D mesh).

Megatron-style tensor parallelism on hidden dims — robust to head counts
that do not divide the mesh (qwen2.5/qwen1.5 have 40 heads on a 16-wide
model axis; hidden dims are all multiples of 16):
  * embed / lm_head: vocab on `model`
  * attention qkv: output features on `model`; wo: input features on `model`
  * mlp: w_gate/w_up features on `model`; w_down input on `model`
  * MoE: experts on `model` when divisible (EP), else per-expert ffn on
    `model` (TP-in-expert) — granite's 40 experts use the latter
  * activations / tokens: batch on `data` (+`pod` when multi-pod); the
    long-context batch=1 shapes shard sequence on `data` instead (SP)
  * KV caches: batch on `data`, head-dim features on `model` when the kv
    head count divides, else sequence on `model`
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _model_axis(mesh: Mesh) -> str:
    return "model"


def _data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return n % mesh.shape[axis] == 0


def param_specs(arch_kind: str, params_shape: Any, mesh: Mesh) -> Any:
    """Build a PartitionSpec tree matching the param tree (by leaf path)."""
    m = _model_axis(mesh)

    def spec_for(path: tuple, leaf) -> P:
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        key = names[-1]
        shape = leaf.shape
        nd = len(shape)

        def last_on_model():
            return P(*([None] * (nd - 1) + [m]))

        def secondlast_on_model():
            return P(*([None] * (nd - 2) + [m, None]))

        if key in ("embed", "lm_head"):
            # embed (V, D): vocab on model; lm_head (D, V): vocab on model
            return P(m, None) if key == "embed" else P(None, m)
        if key in ("pos_dec",):
            return P(None, None)
        if key in ("wq", "wk", "wv", "w_x", "w_y", "in_proj",
                   "mlp_gate", "mlp_up", "mlp_w1"):
            return last_on_model()       # (..., D, F): F on model
        if key in ("bq", "bk", "bv", "mlp_b1", "b_in"):
            return last_on_model()
        if key == "w_down" and nd == 4:
            # moe (L, E, F, D): experts on model when divisible (EP),
            # else per-expert F on model (TP-in-expert)
            E = shape[1]
            if _div(E, mesh, m):
                return P(None, m, None, None)
            return P(None, None, m, None)
        if key in ("wo", "w_down", "mlp_down", "mlp_w2", "out_proj",
                   "w_out"):
            return secondlast_on_model()  # (..., F, D): F on model
        if key in ("w_gate", "w_up"):
            # dense mlp (L, D, F) -> F on model;
            # moe (L, E, D, F) -> E on model if divisible else F on model
            if nd == 4:
                E = shape[1]
                if _div(E, mesh, m):
                    return P(None, m, None, None)
                return P(None, None, None, m)
            return last_on_model()
        if key == "router":
            return P(None, None, None) if nd == 3 else P(None, None)
        if key in ("w_a", "w_i"):
            return last_on_model()       # (L, W, W) second W on model
        if key in ("conv_w", "conv_b", "A_log", "dt_bias", "D_skip", "lam",
                   "gnorm"):
            return P(*([None] * nd))
        # norms, biases, scalars: replicated
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def batch_specs(shape_kind: str, mesh: Mesh) -> dict[str, P]:
    """Input shardings for the train/serve step batches."""
    d = _data_axes(mesh)
    if shape_kind == "long":        # global_batch=1: shard sequence (SP)
        return {
            "tokens": P(None, d),
            "labels": P(None, d),
        }
    return {
        "tokens": P(d, None),
        "labels": P(d, None),
    }


def cache_specs(mesh: Mesh, *, kv_heads: int, head_dim: int,
                long_context: bool = False) -> dict[str, P]:
    """KV cache (L, B, S, Hkv, Dh) shardings."""
    m = _model_axis(mesh)
    d = _data_axes(mesh)
    if long_context:
        # batch=1: shard the cache sequence over data, features over model
        kv = P(None, None, d, None, m if head_dim % mesh.shape[m] == 0 else None)
    elif kv_heads % mesh.shape[m] == 0:
        kv = P(None, d, None, m, None)
    else:
        kv = P(None, d, None, None, m if head_dim % mesh.shape[m] == 0
               else None)
    return {"k": kv, "v": kv, "length": P(d)}


def paged_pool_specs(mesh: Mesh, *, kv_heads: int, head_dim: int) -> dict[str, P]:
    """Paged KV pool shardings (serving/kv.py block pool).

    Pool pages (L, P, page, Hkv, Dh) have no batch axis — the PAGE axis is
    the global one (any slot's table may point anywhere), so it shards over
    the data axes like the dense cache's batch does, while head structure
    follows the dense-cache rule: kv heads on `model` when divisible, else
    head_dim on `model` when divisible.  Page tables and lengths are tiny
    host-managed index state and stay replicated."""
    m = _model_axis(mesh)
    d = _data_axes(mesh)
    if kv_heads % mesh.shape[m] == 0:
        pages = P(None, d, None, m, None)
        scales = P(None, d, None, m)
    else:
        feat = m if head_dim % mesh.shape[m] == 0 else None
        pages = P(None, d, None, None, feat)
        scales = P(None, d, None, None)
    return {"k": pages, "v": pages, "k_scale": scales, "v_scale": scales,
            "page_table": P(None, None), "lengths": P(None)}


def make_shardings(tree_of_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))
