"""Ring collective matmul: the paper's FIFO data-exchange mesh at chip scale.

The TPU baseline the paper criticizes is "gather the whole operand into
every tile" — at chip scale that is all-gather(B) followed by a local GEMM,
duplicating B in every chip's HBM and paying the full all-gather before any
compute starts. The VectorMesh schedule instead keeps outputs stationary and
hands operand *tiles* to the neighbour over the mesh FIFOs while computing.

``ring_matmul`` is that schedule under shard_map: A is sharded on rows
(stationary, like PSums), B on columns; each of the `n` steps computes the
local partial GEMM against the currently-held B shard while
``jax.lax.ppermute`` moves the shard one hop around the ring (the FIFO), so
communication is fully overlapped with compute and no chip ever holds more
than TWO B shards (double buffer = the 4-deep FIFO of the paper).

HBM bytes per chip: all-gather baseline holds |B| per chip; ring holds
2|B|/n — the same "no duplication in local buffers" win as Fig. 2.

The BACKWARD is a custom VJP with the same stationarity (mirroring
``parallel.ring_attention``): reverse-differentiating the fold loop would
stack one B shard per step (the full |B| again, just deferred).  Instead a
second ring pass keeps dA output-stationary (each device folds
``g[:, cols_j] @ B_j^T`` as shard j visits) and circulates the dB
accumulators alongside the B shards, so each shard's gradient arrives home
after ``n`` hops with no psum and no saved per-step residuals.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime import compat


def _hop_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def _ring_body(a_blk: jax.Array, b_blk: jax.Array, axis: str,
               out_dtype) -> jax.Array:
    """Per-shard body. a_blk: (m_local, K); b_blk: (K, n_local)."""
    n = jax.lax.psum(1, axis)
    idx = jax.lax.axis_index(axis)
    m_local, K = a_blk.shape
    n_local = b_blk.shape[1]
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(i, carry):
        b_cur, out = carry
        # which column block of the OUTPUT this b shard belongs to
        col = (idx - i) % n
        partial = jnp.dot(a_blk, b_cur,
                          preferred_element_type=jnp.float32)
        out = jax.lax.dynamic_update_slice(
            out, partial.astype(out.dtype), (0, col * n_local))
        # hand the shard to the neighbour (FIFO hop) — overlapped by the
        # compiler with the next step's dot when async collectives are on.
        b_nxt = jax.lax.ppermute(b_cur, axis, perm)
        return (b_nxt, out)

    out0 = jnp.zeros((m_local, n_local * n), out_dtype)
    # the carry becomes device-varying after the first update/ppermute; mark
    # the initial values accordingly (jax >= 0.7 vma typing).
    out0 = compat.pcast(out0, (axis,), to="varying")
    _, out = jax.lax.fori_loop(0, n, step, (b_blk, out0))
    return out


def _ring_bwd_body(spec, a_blk: jax.Array, b_blk: jax.Array,
                   g_blk: jax.Array):
    """Backward ring pass.  a_blk: (m_local, K); b_blk: (K, n_local);
    g_blk: (m_local, N) — the local rows of the output cotangent.

    dA stays output-stationary (local accumulate); dB accumulators ride
    the ring with the B shards and are home after n hops."""
    n, axis = spec.m, spec.axis
    idx = jax.lax.axis_index(axis)
    m_local, K = a_blk.shape
    n_local = b_blk.shape[1]
    perm = _hop_perm(n)

    def step(i, carry):
        b_c, db_c, da = carry
        col = (idx - i) % n
        g_c = jax.lax.dynamic_slice(g_blk, (0, col * n_local),
                                    (m_local, n_local))
        da = da + jnp.dot(g_c, b_c.T, preferred_element_type=jnp.float32)
        db_c = db_c + jnp.dot(a_blk.T, g_c,
                              preferred_element_type=jnp.float32)
        # shard AND its gradient accumulator take the FIFO hop together
        b_c = jax.lax.ppermute(b_c, axis, perm)
        db_c = jax.lax.ppermute(db_c, axis, perm)
        return (b_c, db_c, da)

    vary = lambda x: compat.match_vma(x, g_blk)  # noqa: E731
    st0 = (b_blk,
           vary(jnp.zeros((K, n_local), jnp.float32)),
           vary(jnp.zeros((m_local, K), jnp.float32)))
    _, db, da = jax.lax.fori_loop(0, n, step, st0)
    return da.astype(a_blk.dtype), db.astype(b_blk.dtype)


@dataclasses.dataclass(frozen=True)
class _RingMmSpec:
    mesh: object
    axis: str
    m: int
    out_dtype: object


def _shard_fwd(spec: _RingMmSpec, a, b):
    fn = compat.shard_map(
        functools.partial(_ring_body, axis=spec.axis,
                          out_dtype=spec.out_dtype),
        mesh=spec.mesh,
        in_specs=(P(spec.axis, None), P(None, spec.axis)),
        out_specs=P(spec.axis, None),
    )
    return fn(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ring_mm(spec: _RingMmSpec, a, b):
    return _shard_fwd(spec, a, b)


def _ring_mm_fwd(spec: _RingMmSpec, a, b):
    return _shard_fwd(spec, a, b), (a, b)


def _ring_mm_bwd(spec: _RingMmSpec, res, g):
    a, b = res
    fn = compat.shard_map(
        functools.partial(_ring_bwd_body, spec), mesh=spec.mesh,
        in_specs=(P(spec.axis, None), P(None, spec.axis),
                  P(spec.axis, None)),
        out_specs=(P(spec.axis, None), P(None, spec.axis)),
    )
    return fn(a, b, g)


_ring_mm.defvjp(_ring_mm_fwd, _ring_mm_bwd)


def ring_matmul(a: jax.Array, b: jax.Array, mesh: Mesh, axis: str = "model",
                out_dtype=None) -> jax.Array:
    """A (M, K) row-sharded x B (K, N) col-sharded -> C (M, N) row-sharded.

    Output-stationary forward AND backward (custom VJP; see module
    docstring). The innermost jnp.dot can itself be the Pallas TEU matmul
    on real hardware.
    """
    out_dtype = out_dtype or a.dtype
    spec = _RingMmSpec(mesh=mesh, axis=axis, m=int(mesh.shape[axis]),
                       out_dtype=jnp.dtype(out_dtype))
    return _ring_mm(spec, a, b)


def ring_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def allgather_matmul(a: jax.Array, b: jax.Array, mesh: Mesh,
                     axis: str = "model", out_dtype=None) -> jax.Array:
    """The TPU-style baseline: all-gather B, then one local GEMM.

    Kept for the §Perf comparison (collective bytes and peak HBM differ)."""
    out_dtype = out_dtype or a.dtype

    def body(a_blk, b_blk):
        b_full = jax.lax.all_gather(b_blk, axis, axis=1, tiled=True)
        return jnp.dot(a_blk, b_full,
                       preferred_element_type=jnp.float32).astype(out_dtype)

    fn = compat.shard_map(body, mesh=mesh,
                          in_specs=(P(axis, None), P(None, axis)),
                          out_specs=P(axis, None))
    return fn(a, b)
