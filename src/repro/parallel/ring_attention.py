"""Ring attention over the ppermute mesh, with a memory-flat custom VJP.

This is the paper's FIFO data-exchange mesh applied to context-parallel
attention at chip scale (§Perf B6).  Queries stay home (output-stationary,
like the paper's stationary PSums), k/v sequence shards hop neighbour to
neighbour via ``jax.lax.ppermute`` (the FIFO hop), and each device folds
the visiting shard into its local rows' online softmax — no k/v all-gather
ever materializes and only one shard is in flight per step.

Forward (per ``model``-axis device, ring of ``m``):
  q_l: (B, S/m, H, Dh) local rows; k_l/v_l: this device's own sequence
  shard.  ``m`` hops of fold-then-permute.  The custom VJP saves ONLY
  ``(o, logsumexp)`` — O(S/m · H · Dh) per device, independent of ``m``.

Backward (a second ring pass with the same hop schedule):
  each hop RECOMPUTES the visiting shard's score tile from
  ``(q, k_hop, lse)``, folds ``dq`` into a local accumulator, and
  circulates ``dk``/``dv`` accumulators ALONGSIDE the k/v shards — a
  shard's gradient rides the ring with it and arrives home exactly when
  the loop ends, so there is no psum and no saved per-hop activation.
  Peak memory is a constant number of shard-sized buffers (the 4-deep
  FIFO analogue).  The naive alternative — reverse-differentiating the
  fold loop — stacks one (S/m x S/m) f32 score tile per hop per layer
  (measured: memory term 17s -> 38s on qwen2.5 train; that measurement
  is what kept the ring opt-in until this VJP).  ``impl='naive'`` keeps
  that path alive as the benchmark baseline.

Masking (causal / sliding-window) and GQA grouping are handled here so
callers (``models/layers.attention``) only pick a policy; the varying-
manual-axes typing required on jax >= 0.6 goes through ``compat.pcast`` /
``compat.match_vma`` like every other shard_map body in the repo.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime import compat

__all__ = ["ring_attention", "data_axes_spec"]


def data_axes_spec(mesh, batch: int):
    """Sharding spec for a batch dim over the data-ish mesh axes ("pod",
    "data"): the axis tuple when ``batch`` divides their product, else
    None (replicate)."""
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsz = 1
    for a in daxes:
        dsz *= mesh.shape[a]
    if not daxes or batch % dsz != 0:
        return None
    return daxes if len(daxes) > 1 else daxes[0]


@dataclasses.dataclass(frozen=True)
class _RingSpec:
    """Static description of one ring-attention call (hashable: it rides
    ``custom_vjp``'s nondiff_argnums).  ``fused`` folds each visiting
    shard with the Pallas flash kernels (block_q/block_k tile the local
    shard) instead of the XLA einsum chain."""
    mesh: object
    axis: str
    m: int
    causal: bool
    window: int | None
    dspec: tuple | str | None
    fused: bool = False
    block_q: int = 0
    block_k: int = 0
    interpret: bool = False


def _hop_perm(m: int):
    return [(i, (i + 1) % m) for i in range(m)]


def _fused_blocks(S_l: int, Dh: int) -> tuple[int, int] | None:
    """Autotuned (block_q, block_k) snapped down to divisors of the local
    shard, or None when the shard is too ragged to tile (-> einsum fold)."""
    from repro.core.pallas_bridge import attention_block_shapes
    bq, bk = attention_block_shapes(S_l, S_l, Dh)
    while bq > 1 and S_l % bq:
        bq //= 2
    while bk > 1 and S_l % bk:
        bk //= 2
    if bq < 8 or bk < 8:
        return None
    return bq, bk


def _flat_heads(x):
    """(B, S, H, Dh) -> (B*H, S, Dh) — the kernels' head-major layout."""
    B, S, H, Dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, Dh)


def _unflat_heads(x, B):
    BH, S, Dh = x.shape
    return x.reshape(B, BH // B, S, Dh).transpose(0, 2, 1, 3)


def _masked_scores(qg, kb, *, scale, q_off, k_off, causal, window):
    """(B, Hkv, G, Sq, Sk) f32 score tile of local q rows against ONE
    visiting shard, with the causal/sliding-window band mask applied in
    GLOBAL positions (q_off/k_off may be traced axis-index offsets)."""
    S_q, S_k = qg.shape[1], kb.shape[1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                   preferred_element_type=jnp.float32) * scale
    if not causal and window is None:
        return s
    qpos = q_off + jnp.arange(S_q)[:, None]
    kpos = k_off + jnp.arange(S_k)[None, :]
    mask = jnp.ones((S_q, S_k), bool)
    if causal:
        mask = mask & (qpos >= kpos)
    if window is not None:
        mask = mask & ((qpos - kpos) < window)
    return jnp.where(mask, s, -1e30)


# ---------------------------------------------------------------------------
# per-shard bodies
# ---------------------------------------------------------------------------

def _fused_fwd_body(spec: _RingSpec, q_l, k_l, v_l):
    """Fold-then-permute forward where each hop's fold IS the Pallas flash
    kernel: the hop computes the visiting shard's (o_hop, lse_hop) with
    the band mask shifted to global positions (traced axis-index offsets
    ride the kernel's scalar-prefetch operand), and the per-hop partials
    combine by logsumexp algebra — the same PSum-stationary schedule, with
    the score tile now inside the MXU kernel instead of an XLA einsum."""
    from repro.kernels.attention import flash_attention_fwd_pallas
    # see _fwd_body: partition-id only when a band mask data-depends on it
    needs_pos = spec.causal or spec.window is not None
    idx = jax.lax.axis_index(spec.axis) if needs_pos else 0
    B, S_l, H, Dh = q_l.shape
    Hkv = k_l.shape[2]
    G = H // Hkv
    qf = _flat_heads(q_l)
    q_off = idx * S_l
    perm = _hop_perm(spec.m)

    def step(t, carry):
        k_c, v_c, o_acc, lse = carry
        owner = (idx - t) % spec.m if needs_pos else 0
        o_h, lse_h = flash_attention_fwd_pallas(
            qf, _flat_heads(k_c), _flat_heads(v_c), causal=spec.causal,
            window=spec.window, block_q=spec.block_q, block_k=spec.block_k,
            q_offset=q_off, k_offset=owner * S_l,
            prune=False, interpret=spec.interpret)
        o_h = compat.match_vma(o_h.astype(jnp.float32), qf)
        lse_h = compat.match_vma(lse_h, qf)
        lse_new = jnp.logaddexp(lse, lse_h)
        o_acc = (o_acc * jnp.exp(lse - lse_new)[..., None]
                 + o_h * jnp.exp(lse_h - lse_new)[..., None])
        k_c = jax.lax.ppermute(k_c, spec.axis, perm)
        v_c = jax.lax.ppermute(v_c, spec.axis, perm)
        return (k_c, v_c, o_acc, lse_new)

    vary = lambda x: compat.match_vma(x, qf)  # noqa: E731
    st0 = (k_l, v_l,
           vary(jnp.zeros((B * H, S_l, Dh), jnp.float32)),
           vary(jnp.full((B * H, S_l), -1e30, jnp.float32)))
    _, _, o_acc, lse = jax.lax.fori_loop(0, spec.m, step, st0)
    o = _unflat_heads(o_acc, B).astype(q_l.dtype)     # (B, S_l, H, Dh)
    return o, lse.reshape(B, Hkv, G, S_l)


def _fused_bwd_body(spec: _RingSpec, q_l, k_l, v_l, o_l, lse_l, do_l):
    """Second ring pass with the Pallas backward kernels doing each hop's
    re-stream: dq folds locally, dk/dv accumulators ride the ring with
    their shards (all f32 until the final cast)."""
    from repro.kernels.attention import flash_attention_bwd_pallas
    needs_pos = spec.causal or spec.window is not None
    idx = jax.lax.axis_index(spec.axis) if needs_pos else 0
    B, S_l, H, Dh = q_l.shape
    Hkv = k_l.shape[2]
    f32 = jnp.float32
    qf = _flat_heads(q_l)
    dof = _flat_heads(do_l)
    of = _flat_heads(o_l)
    lsef = lse_l.reshape(B, H, S_l).reshape(B * H, S_l)
    delta = jnp.sum(of.astype(f32) * dof.astype(f32), axis=-1)
    q_off = idx * S_l
    perm = _hop_perm(spec.m)

    def step(t, carry):
        k_c, v_c, dk_c, dv_c, dq = carry
        owner = (idx - t) % spec.m if needs_pos else 0
        dq_h, dk_h, dv_h = flash_attention_bwd_pallas(
            qf, _flat_heads(k_c), _flat_heads(v_c), dof, lsef, delta,
            causal=spec.causal, window=spec.window, block_q=spec.block_q,
            block_k=spec.block_k, q_offset=q_off,
            k_offset=owner * S_l, prune=False,
            interpret=spec.interpret)
        dq = dq + compat.match_vma(dq_h, qf)
        dk_c = dk_c + _unflat_heads(compat.match_vma(dk_h, qf), B)
        dv_c = dv_c + _unflat_heads(compat.match_vma(dv_h, qf), B)
        k_c = jax.lax.ppermute(k_c, spec.axis, perm)
        v_c = jax.lax.ppermute(v_c, spec.axis, perm)
        dk_c = jax.lax.ppermute(dk_c, spec.axis, perm)
        dv_c = jax.lax.ppermute(dv_c, spec.axis, perm)
        return (k_c, v_c, dk_c, dv_c, dq)

    vary = lambda x: compat.match_vma(x, qf)  # noqa: E731
    st0 = (k_l, v_l,
           vary(jnp.zeros((B, S_l, Hkv, Dh), f32)),
           vary(jnp.zeros((B, S_l, Hkv, Dh), f32)),
           vary(jnp.zeros((B * H, S_l, Dh), f32)))
    _, _, dk, dv, dq = jax.lax.fori_loop(0, spec.m, step, st0)
    dq = _unflat_heads(dq, B).astype(q_l.dtype)       # (B, S_l, H, Dh)
    return dq, dk.astype(k_l.dtype), dv.astype(v_l.dtype)


def _fwd_body(spec: _RingSpec, q_l, k_l, v_l):
    """Fold-then-permute forward.  Returns (o, lse); lse is f32
    (B, Hkv, G, S/m) — the only extra residual the VJP keeps."""
    if spec.fused:
        return _fused_fwd_body(spec, q_l, k_l, v_l)
    # axis_index only when a band mask exists: with no mask nothing data-
    # depends on it, and XLA's SPMD partitioner rejects a partition-id it
    # cannot infer as manually sharded.
    needs_pos = spec.causal or spec.window is not None
    idx = jax.lax.axis_index(spec.axis) if needs_pos else 0
    B, S_l, H, Dh = q_l.shape
    Hkv = k_l.shape[2]
    G = H // Hkv
    qg = q_l.reshape(B, S_l, Hkv, G, Dh)
    scale = 1.0 / math.sqrt(Dh)
    q_off = idx * S_l
    perm = _hop_perm(spec.m)

    def step(t, carry):
        k_c, v_c, mx, l, acc = carry
        owner = (idx - t) % spec.m
        s = _masked_scores(qg, k_c, scale=scale, q_off=q_off,
                           k_off=owner * S_l, causal=spec.causal,
                           window=spec.window)
        m_new = jnp.maximum(mx, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(mx - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(v_c.dtype), v_c,
            preferred_element_type=jnp.float32)
        # hand the shard to the neighbour — the FIFO hop
        k_c = jax.lax.ppermute(k_c, spec.axis, perm)
        v_c = jax.lax.ppermute(v_c, spec.axis, perm)
        return (k_c, v_c, m_new, l, acc)

    vary = lambda x: compat.match_vma(x, qg)  # noqa: E731
    st0 = (k_l, v_l,
           vary(jnp.full((B, Hkv, G, S_l), -1e30, jnp.float32)),
           vary(jnp.zeros((B, Hkv, G, S_l), jnp.float32)),
           vary(jnp.zeros((B, Hkv, G, S_l, Dh), jnp.float32)))
    _, _, mx, l, acc = jax.lax.fori_loop(0, spec.m, step, st0)
    l_safe = jnp.where(l == 0, 1.0, l)
    o = (acc / l_safe[..., None]).transpose(0, 3, 1, 2, 4) \
        .reshape(B, S_l, H, Dh).astype(q_l.dtype)
    lse = mx + jnp.log(l_safe)
    return o, lse


def _naive_body(spec: _RingSpec, q_l, k_l, v_l):
    """The pre-VJP path: same forward, but its backward is whatever
    reverse-differentiating the fold loop produces (one stacked score
    tile per hop).  Kept as the §Perf B6 benchmark baseline."""
    o, _ = _fwd_body(spec, q_l, k_l, v_l)
    return o


def _bwd_body(spec: _RingSpec, q_l, k_l, v_l, o_l, lse_l, do_l):
    """Second ring pass: recompute each visiting shard's tile, fold dq
    locally, circulate dk/dv with the shards.  After m hops the
    accumulators are home — no psum."""
    if spec.fused:
        return _fused_bwd_body(spec, q_l, k_l, v_l, o_l, lse_l, do_l)
    needs_pos = spec.causal or spec.window is not None
    idx = jax.lax.axis_index(spec.axis) if needs_pos else 0
    B, S_l, H, Dh = q_l.shape
    Hkv = k_l.shape[2]
    G = H // Hkv
    f32 = jnp.float32
    qg = q_l.reshape(B, S_l, Hkv, G, Dh).astype(f32)
    dog = do_l.reshape(B, S_l, Hkv, G, Dh).astype(f32)
    og = o_l.reshape(B, S_l, Hkv, G, Dh).astype(f32)
    # di = rowsum(do * o), shared by the dq and dk products (flash bwd)
    delta = jnp.einsum("bqkgd,bqkgd->bkgq", dog, og)
    scale = 1.0 / math.sqrt(Dh)
    q_off = idx * S_l
    perm = _hop_perm(spec.m)

    def step(t, carry):
        k_c, v_c, dk_c, dv_c, dq = carry
        owner = (idx - t) % spec.m
        s = _masked_scores(qg, k_c, scale=scale, q_off=q_off,
                           k_off=owner * S_l, causal=spec.causal,
                           window=spec.window)
        p = jnp.exp(s - lse_l[..., None])        # masked entries -> exp(-inf)=0
        dv_c = dv_c + jnp.einsum("bkgqs,bqkgd->bskd", p, dog)
        dp = jnp.einsum("bqkgd,bskd->bkgqs", dog, v_c,
                        preferred_element_type=f32)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bkgqs,bskd->bqkgd", ds, k_c,
                             preferred_element_type=f32)
        dk_c = dk_c + jnp.einsum("bkgqs,bqkgd->bskd", ds, qg)
        # shard AND its gradient accumulator ride the ring together
        k_c = jax.lax.ppermute(k_c, spec.axis, perm)
        v_c = jax.lax.ppermute(v_c, spec.axis, perm)
        dk_c = jax.lax.ppermute(dk_c, spec.axis, perm)
        dv_c = jax.lax.ppermute(dv_c, spec.axis, perm)
        return (k_c, v_c, dk_c, dv_c, dq)

    vary = lambda x: compat.match_vma(x, qg)  # noqa: E731
    st0 = (k_l, v_l,
           vary(jnp.zeros((B, S_l, Hkv, Dh), f32)),
           vary(jnp.zeros((B, S_l, Hkv, Dh), f32)),
           vary(jnp.zeros((B, S_l, Hkv, G, Dh), f32)))
    _, _, dk, dv, dq = jax.lax.fori_loop(0, spec.m, step, st0)
    dq = dq.reshape(B, S_l, H, Dh).astype(q_l.dtype)
    return dq, dk.astype(k_l.dtype), dv.astype(v_l.dtype)


# ---------------------------------------------------------------------------
# custom VJP plumbing
# ---------------------------------------------------------------------------

def _qkv_spec(spec: _RingSpec):
    return P(spec.dspec, spec.axis, None, None)


def _shard_fwd(spec: _RingSpec, q, k, v):
    qs = _qkv_spec(spec)
    fn = compat.shard_map(
        functools.partial(_fwd_body, spec), mesh=spec.mesh,
        in_specs=(qs, qs, qs),
        out_specs=(qs, P(spec.dspec, None, None, spec.axis)))
    return fn(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ring_attn(spec: _RingSpec, q, k, v):
    o, _ = _shard_fwd(spec, q, k, v)
    return o


def _ring_attn_fwd(spec: _RingSpec, q, k, v):
    o, lse = _shard_fwd(spec, q, k, v)
    return o, (q, k, v, o, lse)


def _ring_attn_bwd(spec: _RingSpec, res, do):
    q, k, v, o, lse = res
    qs = _qkv_spec(spec)
    fn = compat.shard_map(
        functools.partial(_bwd_body, spec), mesh=spec.mesh,
        in_specs=(qs, qs, qs, qs, P(spec.dspec, None, None, spec.axis), qs),
        out_specs=(qs, qs, qs))
    return fn(q, k, v, o, lse, do)


_ring_attn.defvjp(_ring_attn_fwd, _ring_attn_bwd)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def _decide_fused(fused: bool | None, S_global: int, S_local: int, Dh: int):
    """Resolve the per-hop fold engine: explicit ``fused`` wins, else the
    flash policy (REPRO_FLASH_ATTN / backend) judged on the GLOBAL
    sequence (the ring still folds all of it, one shard per hop).
    Returns (fused, block_q, block_k, interpret); fused falls off when
    the local shard won't tile."""
    interpret = jax.default_backend() != "tpu"
    if fused is None:
        from repro.configs import base as cbase
        fused = cbase.decide_flash(cbase.flash_attn_policy(None),
                                   seq_len=S_global, kv_len=S_global,
                                   on_tpu=not interpret) == "pallas"
    if not fused:
        return False, 0, 0, interpret
    blocks = _fused_blocks(S_local, Dh)
    if blocks is None:
        return False, 0, 0, interpret
    return True, blocks[0], blocks[1], interpret


def ring_attention(q, k, v, *, causal=True, window=None, mesh=None,
                   axis: str = "model", impl: str = "vjp",
                   fused: bool | None = None):
    """Context-parallel attention on the ppermute ring.

    q: (B, S, H, Dh); k/v: (B, S, Hkv, Dh) with H % Hkv == 0 (GQA).
    Returns the (B, S, H, Dh) output, or None when the ring does not
    apply (no ambient/explicit mesh, axis absent or size 1, S does not
    divide the ring, cross-attention).  ``impl``: "vjp" (memory-flat
    custom VJP, the default) or "naive" (reverse-differentiated fold —
    benchmark baseline only).  ``fused`` selects the Pallas flash kernels
    for the per-hop score-tile fold in BOTH ring passes (None: follow the
    flash policy — on by default on TPU).
    """
    if mesh is None:
        mesh = compat.get_abstract_mesh()
    if mesh is None or getattr(mesh, "empty", False):
        return None
    if axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return None
    try:
        if mesh._are_all_axes_manual:    # already inside a shard_map
            return None
    except AttributeError:
        pass
    m = int(mesh.shape[axis])
    B, S, H, Dh = q.shape
    if S % m != 0 or k.shape[1] != S:
        return None
    use_fused, bq, bk, interp = _decide_fused(fused, S, S // m, Dh)
    spec = _RingSpec(mesh=mesh, axis=axis, m=m, causal=bool(causal),
                     window=None if window is None else int(window),
                     dspec=data_axes_spec(mesh, B), fused=use_fused,
                     block_q=bq, block_k=bk, interpret=interp)
    if impl == "naive":
        qs = _qkv_spec(spec)
        fn = compat.shard_map(
            functools.partial(_naive_body, spec), mesh=spec.mesh,
            in_specs=(qs, qs, qs), out_specs=qs)
        return fn(q, k, v)
    if impl != "vjp":
        raise ValueError(f"ring_attention impl {impl!r} not in "
                         "('vjp', 'naive')")
    return _ring_attn(spec, q, k, v)
