"""Pipeline parallelism over the `pod` axis (GPipe microbatching).

When multi-pod training is layer-bound rather than data-bound, the `pod`
axis can carry pipeline STAGES instead of outer data parallelism: the layer
stack is split into `n_pods` contiguous stages, microbatches stream through,
and activations hop stage-to-stage with ``jax.lax.ppermute`` — one more
incarnation of the paper's neighbour-FIFO exchange (stage handoff = FIFO).

This implementation runs inside shard_map over the `pod` axis. Each pod
holds only its stage's parameters (1/n_pods of the stack). The classic GPipe
schedule executes `n_micro + n_stages - 1` ticks; bubble fraction
(n_stages-1)/(n_micro + n_stages - 1).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime import compat


def pipeline_forward(stage_fn: Callable[[Any, jax.Array], jax.Array],
                     stage_params: Any, x_micro: jax.Array, mesh: Mesh,
                     axis: str = "pod") -> jax.Array:
    """Run microbatches through pipeline stages laid along `axis`.

    stage_fn(params_for_stage, x) -> x  — one stage's computation.
    stage_params: pytree whose leaves have a leading `n_stages` dim, sharded
        on `axis` (each pod holds its own stage slice).
    x_micro: (n_micro, mb, ...) microbatched input, replicated over `axis`.

    Returns (n_micro, mb, ...) outputs (valid on the LAST stage; other pods
    hold intermediate activations — callers psum/select as needed).
    """

    def body(params, xs):
        # params: this stage's slice (leading dim 1) ; xs: (n_micro, mb, ...)
        params = jax.tree.map(lambda a: a[0], params)
        n_stages = jax.lax.psum(1, axis)
        stage = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        ticks = n_micro + n_stages - 1
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(t, carry):
            inflight, outs = carry
            # which microbatch enters stage 0 this tick
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            feed = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0, keepdims=False)
            # stage 0 consumes fresh input; others consume the handoff
            x_in = jnp.where(stage == 0, feed, inflight)
            y = stage_fn(params, x_in)
            # last stage emits a finished microbatch at tick t - (S-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            valid = (t >= n_stages - 1) & (stage == n_stages - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y.astype(o.dtype), out_idx, 0),
                lambda o: o,
                outs)
            # FIFO hop to the next stage
            inflight = jax.lax.ppermute(y, axis, fwd_perm)
            return (inflight, outs)

        inflight0 = compat.pcast(jnp.zeros_like(xs[0]), (axis,),
                                 to="varying")
        outs0 = compat.pcast(jnp.zeros(xs.shape, xs.dtype), (axis,),
                             to="varying")
        _, outs = jax.lax.fori_loop(0, ticks, tick, (inflight0, outs0))
        # only the last stage ever wrote into `outs`; psum replicates it.
        return jax.lax.psum(outs, axis)

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    fn = compat.shard_map(body, mesh=mesh,
                          in_specs=(spec_p, P()),
                          out_specs=P())
    return fn(stage_params, x_micro)
