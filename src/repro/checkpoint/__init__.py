from .manager import (CheckpointCorruptError, CheckpointError,
                      CheckpointManager, TreeStructureError, latest_step,
                      restore_checkpoint, save_checkpoint, verified_steps,
                      verify_checkpoint)

__all__ = ["CheckpointCorruptError", "CheckpointError", "CheckpointManager",
           "TreeStructureError", "latest_step", "restore_checkpoint",
           "save_checkpoint", "verified_steps", "verify_checkpoint"]
