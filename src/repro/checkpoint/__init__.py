from .manager import (CheckpointCorruptError, CheckpointError,
                      CheckpointManager, TreeStructureError, latest_step,
                      restore_checkpoint, restore_checkpoint_striped,
                      save_checkpoint, verified_steps, verify_checkpoint)

__all__ = ["CheckpointCorruptError", "CheckpointError", "CheckpointManager",
           "TreeStructureError", "latest_step", "restore_checkpoint",
           "restore_checkpoint_striped", "save_checkpoint", "verified_steps",
           "verify_checkpoint"]
