"""Fault-tolerant sharded checkpointing.

Format: one directory per step, one .npz per host shard plus a JSON
manifest; writes go to a temp dir and are atomically renamed, so a crash
mid-save never corrupts the latest checkpoint. Saves run on a background
thread (async): the train loop hands over host-local numpy copies and keeps
stepping. Restore re-shards to WHATEVER mesh is现 available (elastic): the
manifest stores the logical tree structure; arrays are loaded full and
re-placed with whatever sharding the new mesh dictates (at 1000-node scale,
substitute a striped read; the interface is unchanged).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable

import jax
import numpy as np


def _flatten(tree: Any) -> tuple[list[np.ndarray], Any, list[str]]:
    leaves, treedef = jax.tree.flatten(tree)
    names = [f"leaf_{i}" for i in range(len(leaves))]
    return leaves, treedef, names


def save_checkpoint(path: str, step: int, tree: Any, *, host_id: int = 0,
                    extra: dict | None = None) -> str:
    """Synchronous sharded save with atomic rename."""
    step_dir = os.path.join(path, f"step_{step:08d}")
    tmp_dir = step_dir + f".tmp_{host_id}"
    os.makedirs(tmp_dir, exist_ok=True)
    leaves, treedef, names = _flatten(tree)
    arrays = {n: np.asarray(l) for n, l in zip(names, leaves)}
    np.savez(os.path.join(tmp_dir, f"shard_{host_id}.npz"), **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "extra": extra or {},
    }
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # single-host container: the tmp dir becomes the step dir atomically
    if os.path.isdir(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)
    return step_dir


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and not d.endswith(".tmp")
             and "tmp" not in d]
    return max(steps) if steps else None


def restore_checkpoint(path: str, step: int, like: Any, *,
                       host_id: int = 0,
                       sharding_fn: Callable[[Any], Any] | None = None) -> Any:
    """Restore into the structure of `like`; re-shard with `sharding_fn`
    (elastic: the target mesh may differ from the one that saved)."""
    step_dir = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(step_dir, f"shard_{host_id}.npz"))
    leaves, treedef = jax.tree.flatten(like)
    assert manifest["n_leaves"] == len(leaves), (
        manifest["n_leaves"], len(leaves))
    out = []
    for i, leaf in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert list(arr.shape) == list(np.shape(leaf)), (
            f"leaf {i}: ckpt {arr.shape} vs model {np.shape(leaf)}")
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    if sharding_fn is not None:
        tree = sharding_fn(tree)
    return tree


class CheckpointManager:
    """Async checkpointing with bounded retention + restart discovery."""

    def __init__(self, path: str, *, keep: int = 3, host_id: int = 0):
        self.path = path
        self.keep = keep
        self.host_id = host_id
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(path, exist_ok=True)

    def save_async(self, step: int, tree: Any, extra: dict | None = None):
        """Device->host copy happens here (blocking); the disk write is
        backgrounded. Call wait() before process exit."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            try:
                save_checkpoint(self.path, step, host_tree,
                                host_id=self.host_id, extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.path)
            if d.startswith("step_") and "tmp" not in d)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.path)

    def restore(self, like: Any, step: int | None = None,
                sharding_fn=None) -> tuple[int, Any] | None:
        step = step if step is not None else self.latest()
        if step is None:
            return None
        return step, restore_checkpoint(self.path, step, like,
                                        host_id=self.host_id,
                                        sharding_fn=sharding_fn)
