"""Fault-tolerant sharded checkpointing with verified restores (format v2).

Layout: one SHARED directory per step that every host writes into::

    step_00000040/
        shard_0.npz       one .npz per host (tmp-file + atomic rename)
        commit_0.json     per-host commit marker: CRC32 + leaf count
        ...
        manifest.json     final commit, written by host 0 (tmp + rename):
                          treedef, leaf paths/shapes/dtypes, n_hosts

A checkpoint only EXISTS once its manifest is on disk, and it is only
INTACT when every shard named by the manifest is present with a CRC32
matching its commit marker — a crash mid-save leaves an invisible partial
dir, a flipped bit leaves a detectably-corrupt one.  ``restore`` walks
steps newest-to-oldest and falls back to the newest intact checkpoint, so
a corrupted latest save costs one checkpoint interval, not the run.

(The seed format renamed a per-host tmp DIR over the step dir, so on a
multi-host fleet each host's rename deleted every other host's shard —
host shards now land in one shared dir and commit individually.  In a
real multi-host job the host-0 manifest commit happens after a barrier;
in this single-process container callers just save host 0 last.)

Saves run on a background thread (async): the train loop hands over
host-local numpy copies and keeps stepping.  Restore re-shards to
whatever mesh is available (elastic): arrays are loaded full and re-placed
by ``sharding_fn`` (at 1000-node scale, substitute a striped read; the
interface is unchanged).

Error contract: :class:`CheckpointCorruptError` means "this step is
damaged, try an older one" (the manager's fallback does exactly that);
:class:`TreeStructureError` means the CALLER's ``like`` tree disagrees
with what was saved — that is a bug, never silently absorbed, and the
error names the first diverging leaf path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from itertools import zip_longest
from typing import Any, Callable

import jax
import numpy as np

from repro.obs import REGISTRY

FORMAT_VERSION = 2


class CheckpointError(Exception):
    """Base class for checkpoint failures."""


class CheckpointCorruptError(CheckpointError):
    """Step is missing pieces or fails its checksums; fall back."""


class TreeStructureError(CheckpointError):
    """`like` and the saved tree disagree structurally; caller bug."""


def _leaf_paths(tree: Any) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _ in flat]


def _crc32_file(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(chunk, crc)
    return crc


def _write_json_atomic(path: str, obj: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


def _step_dir(path: str, step: int) -> str:
    return os.path.join(path, f"step_{step:08d}")


def save_checkpoint(path: str, step: int, tree: Any, *, host_id: int = 0,
                    n_hosts: int = 1, extra: dict | None = None) -> str:
    """Write this host's shard (and, on host 0, the committing manifest).

    Every file lands via tmp-write + ``os.replace`` so readers never see a
    half-written shard; the shared step dir is created idempotently so
    concurrent hosts cannot clobber each other's shards.
    """
    t0 = time.monotonic()
    step_dir = _step_dir(path, step)
    os.makedirs(step_dir, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    shard = os.path.join(step_dir, f"shard_{host_id}.npz")
    tmp = shard + ".tmp"
    with open(tmp, "wb") as f:      # file handle: savez must not append .npz
        np.savez(f, **arrays)
    crc = _crc32_file(tmp)
    os.replace(tmp, shard)
    _write_json_atomic(os.path.join(step_dir, f"commit_{host_id}.json"),
                       {"host_id": host_id, "crc32": crc,
                        "n_leaves": len(leaves)})
    if host_id == 0:
        manifest = {
            "format": FORMAT_VERSION,
            "step": step,
            "n_hosts": n_hosts,
            "treedef": str(treedef),
            "leaf_paths": _leaf_paths(tree),
            "n_leaves": len(leaves),
            "shapes": [list(np.shape(l)) for l in leaves],
            "dtypes": [str(np.asarray(l).dtype) for l in leaves],
            "extra": extra or {},
        }
        _write_json_atomic(os.path.join(step_dir, "manifest.json"), manifest)
    # pushed to the global registry (thread-safe: save_async calls this
    # from its background writer thread while the train loop records)
    REGISTRY.counter("checkpoint_ops", op="save")
    REGISTRY.observe("checkpoint_save_s", time.monotonic() - t0)
    return step_dir


def _read_manifest(step_dir: str) -> dict:
    mpath = os.path.join(step_dir, "manifest.json")
    if not os.path.isfile(mpath):
        raise CheckpointCorruptError(f"{step_dir}: no manifest (save never "
                                     "committed)")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(f"{step_dir}: unreadable manifest: {e}")
    if manifest.get("format") != FORMAT_VERSION:
        raise CheckpointCorruptError(
            f"{step_dir}: unsupported format {manifest.get('format')!r}")
    return manifest


def verify_checkpoint(path: str, step: int) -> tuple[bool, str]:
    """Full integrity audit of one step: manifest present, every shard the
    manifest names present, each shard's CRC32 matching its commit marker
    and its leaf count matching the manifest.  Returns (ok, reason)."""
    t0 = time.monotonic()
    step_dir = _step_dir(path, step)

    def done(ok: bool, why: str) -> tuple[bool, str]:
        REGISTRY.counter("checkpoint_ops", op="verify")
        if not ok:
            REGISTRY.counter("checkpoint_verify_failures")
        REGISTRY.observe("checkpoint_verify_s", time.monotonic() - t0)
        return ok, why

    try:
        manifest = _read_manifest(step_dir)
    except CheckpointCorruptError as e:
        return done(False, str(e))
    for h in range(manifest.get("n_hosts", 1)):
        shard = os.path.join(step_dir, f"shard_{h}.npz")
        marker = os.path.join(step_dir, f"commit_{h}.json")
        if not os.path.isfile(shard):
            return done(False, f"shard {h} missing")
        if not os.path.isfile(marker):
            return done(False, f"shard {h} never committed")
        try:
            with open(marker) as f:
                commit = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            return done(False, f"shard {h} commit marker unreadable: {e}")
        if commit.get("n_leaves") != manifest["n_leaves"]:
            return done(False,
                        (f"shard {h} has {commit.get('n_leaves')} leaves, "
                         f"manifest says {manifest['n_leaves']}"))
        crc = _crc32_file(shard)
        if crc != commit.get("crc32"):
            REGISTRY.counter("checkpoint_crc_failures")
            return done(False,
                        (f"shard {h} CRC32 {crc:#010x} != committed "
                         f"{commit.get('crc32', 0):#010x}"))
    return done(True, "ok")


def _all_steps(path: str) -> list[int]:
    if not os.path.isdir(path):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(path)
                  if d.startswith("step_") and "tmp" not in d)


def latest_step(path: str) -> int | None:
    """Newest step whose manifest committed (cheap; no CRC pass — restore
    verifies fully and falls back on damage)."""
    steps = [s for s in _all_steps(path)
             if os.path.isfile(os.path.join(_step_dir(path, s),
                                            "manifest.json"))]
    return max(steps) if steps else None


def verified_steps(path: str) -> list[int]:
    """All steps passing the full CRC audit, oldest first."""
    return [s for s in _all_steps(path) if verify_checkpoint(path, s)[0]]


def _check_structure(step: int, manifest: dict, like: Any) -> Any:
    """Raise TreeStructureError naming the first diverging leaf path when
    `like` does not match the saved tree; returns like's treedef."""
    leaves, treedef = jax.tree.flatten(like)
    if (manifest["n_leaves"] == len(leaves)
            and manifest["treedef"] == str(treedef)):
        return treedef
    saved_paths = manifest.get("leaf_paths", [])
    for i, (a, b) in enumerate(zip_longest(saved_paths, _leaf_paths(like),
                                           fillvalue="<missing>")):
        if a != b:
            raise TreeStructureError(
                f"checkpoint step {step}: saved tree and restore target "
                f"diverge at leaf {i}: checkpoint has {a!r}, `like` has "
                f"{b!r}")
    raise TreeStructureError(
        f"checkpoint step {step}: treedef mismatch with identical leaf "
        f"paths (container types differ): saved {manifest['treedef']!r} "
        f"vs {str(treedef)!r}")


def restore_checkpoint(path: str, step: int, like: Any, *,
                       host_id: int = 0,
                       sharding_fn: Callable[[Any], Any] | None = None,
                       verify: bool = True) -> Any:
    """Verified restore into the structure of `like`; re-shard with
    `sharding_fn` (elastic: the target mesh may differ from the one that
    saved).  Raises CheckpointCorruptError on damage (fallback-able) and
    TreeStructureError on a `like` mismatch (not fallback-able)."""
    t0 = time.monotonic()
    step_dir = _step_dir(path, step)
    if verify:
        ok, why = verify_checkpoint(path, step)
        if not ok:
            raise CheckpointCorruptError(f"step {step}: {why}")
    manifest = _read_manifest(step_dir)
    leaves = jax.tree.leaves(like)
    treedef = _check_structure(step, manifest, like)
    try:
        data = np.load(os.path.join(step_dir, f"shard_{host_id}.npz"))
    except Exception as e:  # zipfile/zlib raise various types on damage
        raise CheckpointCorruptError(f"step {step}: shard {host_id} "
                                     f"unreadable: {e}")
    out = []
    for i, leaf in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if list(arr.shape) != manifest["shapes"][i] or \
                str(arr.dtype) != manifest["dtypes"][i]:
            raise CheckpointCorruptError(
                f"step {step}: leaf {i} is {arr.dtype}{list(arr.shape)}, "
                f"manifest recorded {manifest['dtypes'][i]}"
                f"{manifest['shapes'][i]}")
        if list(arr.shape) != list(np.shape(leaf)):
            raise TreeStructureError(
                f"step {step}: leaf {i} "
                f"({manifest.get('leaf_paths', ['?'] * (i + 1))[i]}): "
                f"checkpoint shape {list(arr.shape)} vs restore target "
                f"{list(np.shape(leaf))}")
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    if sharding_fn is not None:
        tree = sharding_fn(tree)
    REGISTRY.counter("checkpoint_ops", op="restore")
    REGISTRY.observe("checkpoint_restore_s", time.monotonic() - t0)
    return tree


class CheckpointManager:
    """Async checkpointing with bounded retention, restart discovery and
    verified-restore fallback."""

    def __init__(self, path: str, *, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1):
        self.path = path
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(path, exist_ok=True)

    def save_async(self, step: int, tree: Any, extra: dict | None = None):
        """Device->host copy happens here (blocking); the disk write is
        backgrounded. Call wait() before process exit."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            try:
                save_checkpoint(self.path, step, host_tree,
                                host_id=self.host_id, n_hosts=self.n_hosts,
                                extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        for s in _all_steps(self.path)[:-self.keep]:
            shutil.rmtree(_step_dir(self.path, s), ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.path)

    def restore(self, like: Any, step: int | None = None,
                sharding_fn=None) -> tuple[int, Any] | None:
        """Restore `step` (default: newest), falling back through older
        checkpoints when the newer ones fail verification.  Returns
        (step, tree) or None when nothing intact exists.  A tree-structure
        mismatch raises immediately — older checkpoints would mismatch the
        same way, and silently restoring the wrong structure is the one
        failure this module exists to prevent."""
        if step is not None:
            return step, restore_checkpoint(self.path, step, like,
                                            host_id=self.host_id,
                                            sharding_fn=sharding_fn)
        for s in reversed(_all_steps(self.path)):
            try:
                tree = restore_checkpoint(self.path, s, like,
                                          host_id=self.host_id,
                                          sharding_fn=sharding_fn)
                return s, tree
            except CheckpointCorruptError as e:
                print(f"[ckpt] step {s} failed verification ({e}); "
                      f"falling back")
        return None
