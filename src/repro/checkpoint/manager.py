"""Fault-tolerant sharded checkpointing with verified restores (format v2).

Layout: one SHARED directory per step that every host writes into::

    step_00000040/
        shard_0.npz       one .npz per host (tmp-file + atomic rename)
        commit_0.json     per-host commit marker: CRC32 + leaf count
        ...
        manifest.json     final commit, written by host 0 (tmp + rename):
                          treedef, leaf paths/shapes/dtypes, n_hosts

A checkpoint only EXISTS once its manifest is on disk, and it is only
INTACT when every shard named by the manifest is present with a CRC32
matching its commit marker — a crash mid-save leaves an invisible partial
dir, a flipped bit leaves a detectably-corrupt one.  ``restore`` walks
steps newest-to-oldest and falls back to the newest intact checkpoint, so
a corrupted latest save costs one checkpoint interval, not the run.

(The seed format renamed a per-host tmp DIR over the step dir, so on a
multi-host fleet each host's rename deleted every other host's shard —
host shards now land in one shared dir and commit individually.  In a
real multi-host job the host-0 manifest commit happens after a barrier;
in this single-process container callers just save host 0 last.)

Saves run on a background thread (async): the train loop hands over
host-local numpy copies and keeps stepping.  Restore re-shards to
whatever mesh is available (elastic): arrays are loaded full and re-placed
by ``sharding_fn``.

Striped multi-host restore (:func:`restore_checkpoint_striped`): when a
whole fleet restores the same shard (gang cold-start / post-re-mesh
restart), every host reading the full file is N redundant passes over
the same bytes.  Instead host r of R reads only byte stripe
``[r*S/R, (r+1)*S/R)`` of the shard file, the fleet all-gathers the
stripes over the host mesh (``repro.runtime.fleet`` transports), each
host CRC-checks the *assembled* bytes against the commit marker and
``np.load``s from memory.  Disk bytes per host drop from S to S/R (the
``checkpoint_read_bytes{mode=...}`` counters in the obs registry price
it); integrity guarantees are unchanged because the CRC covers the
reassembled whole.  A stripe-exchange timeout raises
``TimeoutError`` (NOT ``CheckpointCorruptError``) — the bytes on disk
may be fine, so the caller must retry or fall back to full reads rather
than walk to an older step.

Error contract: :class:`CheckpointCorruptError` means "this step is
damaged, try an older one" (the manager's fallback does exactly that);
:class:`TreeStructureError` means the CALLER's ``like`` tree disagrees
with what was saved — that is a bug, never silently absorbed, and the
error names the first diverging leaf path.
"""
from __future__ import annotations

import io
import json
import os
import shutil
import threading
import time
import zlib
from itertools import zip_longest
from typing import Any, Callable

import jax
import numpy as np

from repro.obs import REGISTRY

FORMAT_VERSION = 2


def _count_read(n: int, mode: str) -> None:
    """Attribute ``n`` bytes of checkpoint-dir disk reads to ``mode``
    (``full`` = whole-file verify/load, ``striped`` = stripe reads +
    manifest/marker metadata).  The fleet drills assert striped restore
    reads strictly fewer bytes per host than a full read."""
    if n:
        REGISTRY.counter("checkpoint_read_bytes", n, mode=mode)


class CheckpointError(Exception):
    """Base class for checkpoint failures."""


class CheckpointCorruptError(CheckpointError):
    """Step is missing pieces or fails its checksums; fall back."""


class TreeStructureError(CheckpointError):
    """`like` and the saved tree disagree structurally; caller bug."""


def _leaf_paths(tree: Any) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _ in flat]


def _crc32_file(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(chunk, crc)
    return crc


def _write_json_atomic(path: str, obj: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
    os.replace(tmp, path)


def _step_dir(path: str, step: int) -> str:
    return os.path.join(path, f"step_{step:08d}")


def save_checkpoint(path: str, step: int, tree: Any, *, host_id: int = 0,
                    n_hosts: int = 1, extra: dict | None = None) -> str:
    """Write this host's shard (and, on host 0, the committing manifest).

    Every file lands via tmp-write + ``os.replace`` so readers never see a
    half-written shard; the shared step dir is created idempotently so
    concurrent hosts cannot clobber each other's shards.
    """
    t0 = time.monotonic()
    step_dir = _step_dir(path, step)
    os.makedirs(step_dir, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    shard = os.path.join(step_dir, f"shard_{host_id}.npz")
    tmp = shard + ".tmp"
    with open(tmp, "wb") as f:      # file handle: savez must not append .npz
        np.savez(f, **arrays)
    crc = _crc32_file(tmp)
    os.replace(tmp, shard)
    _write_json_atomic(os.path.join(step_dir, f"commit_{host_id}.json"),
                       {"host_id": host_id, "crc32": crc,
                        "n_leaves": len(leaves)})
    if host_id == 0:
        manifest = {
            "format": FORMAT_VERSION,
            "step": step,
            "n_hosts": n_hosts,
            "treedef": str(treedef),
            "leaf_paths": _leaf_paths(tree),
            "n_leaves": len(leaves),
            "shapes": [list(np.shape(l)) for l in leaves],
            "dtypes": [str(np.asarray(l).dtype) for l in leaves],
            "extra": extra or {},
        }
        _write_json_atomic(os.path.join(step_dir, "manifest.json"), manifest)
    # pushed to the global registry (thread-safe: save_async calls this
    # from its background writer thread while the train loop records)
    REGISTRY.counter("checkpoint_ops", op="save")
    REGISTRY.observe("checkpoint_save_s", time.monotonic() - t0)
    return step_dir


def _read_manifest(step_dir: str) -> dict:
    mpath = os.path.join(step_dir, "manifest.json")
    if not os.path.isfile(mpath):
        raise CheckpointCorruptError(f"{step_dir}: no manifest (save never "
                                     "committed)")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(f"{step_dir}: unreadable manifest: {e}")
    if manifest.get("format") != FORMAT_VERSION:
        raise CheckpointCorruptError(
            f"{step_dir}: unsupported format {manifest.get('format')!r}")
    return manifest


def verify_checkpoint(path: str, step: int) -> tuple[bool, str]:
    """Full integrity audit of one step: manifest present, every shard the
    manifest names present, each shard's CRC32 matching its commit marker
    and its leaf count matching the manifest.  Returns (ok, reason)."""
    t0 = time.monotonic()
    step_dir = _step_dir(path, step)

    def done(ok: bool, why: str) -> tuple[bool, str]:
        REGISTRY.counter("checkpoint_ops", op="verify")
        if not ok:
            REGISTRY.counter("checkpoint_verify_failures")
        REGISTRY.observe("checkpoint_verify_s", time.monotonic() - t0)
        return ok, why

    try:
        manifest = _read_manifest(step_dir)
    except CheckpointCorruptError as e:
        return done(False, str(e))
    for h in range(manifest.get("n_hosts", 1)):
        shard = os.path.join(step_dir, f"shard_{h}.npz")
        marker = os.path.join(step_dir, f"commit_{h}.json")
        if not os.path.isfile(shard):
            return done(False, f"shard {h} missing")
        if not os.path.isfile(marker):
            return done(False, f"shard {h} never committed")
        try:
            with open(marker) as f:
                commit = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            return done(False, f"shard {h} commit marker unreadable: {e}")
        if commit.get("n_leaves") != manifest["n_leaves"]:
            return done(False,
                        (f"shard {h} has {commit.get('n_leaves')} leaves, "
                         f"manifest says {manifest['n_leaves']}"))
        try:
            crc = _crc32_file(shard)
            _count_read(os.path.getsize(shard), "full")
        except OSError as e:
            # a concurrent writer's GC can reap the step mid-audit; that
            # is "fall back", not a crash
            return done(False, f"shard {h} vanished mid-audit: {e}")
        if crc != commit.get("crc32"):
            REGISTRY.counter("checkpoint_crc_failures")
            return done(False,
                        (f"shard {h} CRC32 {crc:#010x} != committed "
                         f"{commit.get('crc32', 0):#010x}"))
    return done(True, "ok")


def _all_steps(path: str) -> list[int]:
    if not os.path.isdir(path):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(path)
                  if d.startswith("step_") and "tmp" not in d)


def latest_step(path: str) -> int | None:
    """Newest step whose manifest committed (cheap; no CRC pass — restore
    verifies fully and falls back on damage)."""
    steps = [s for s in _all_steps(path)
             if os.path.isfile(os.path.join(_step_dir(path, s),
                                            "manifest.json"))]
    return max(steps) if steps else None


def verified_steps(path: str) -> list[int]:
    """All steps passing the full CRC audit, oldest first."""
    return [s for s in _all_steps(path) if verify_checkpoint(path, s)[0]]


def _check_structure(step: int, manifest: dict, like: Any) -> Any:
    """Raise TreeStructureError naming the first diverging leaf path when
    `like` does not match the saved tree; returns like's treedef."""
    leaves, treedef = jax.tree.flatten(like)
    if (manifest["n_leaves"] == len(leaves)
            and manifest["treedef"] == str(treedef)):
        return treedef
    saved_paths = manifest.get("leaf_paths", [])
    for i, (a, b) in enumerate(zip_longest(saved_paths, _leaf_paths(like),
                                           fillvalue="<missing>")):
        if a != b:
            raise TreeStructureError(
                f"checkpoint step {step}: saved tree and restore target "
                f"diverge at leaf {i}: checkpoint has {a!r}, `like` has "
                f"{b!r}")
    raise TreeStructureError(
        f"checkpoint step {step}: treedef mismatch with identical leaf "
        f"paths (container types differ): saved {manifest['treedef']!r} "
        f"vs {str(treedef)!r}")


def _audited_tree(step: int, manifest: dict, like: Any, treedef: Any,
                  data, sharding_fn: Callable[[Any], Any] | None) -> Any:
    """Shared tail of the full and striped restores: audit every loaded
    leaf against the manifest (corruption) and the `like` target (caller
    bug), unflatten, re-shard."""
    out = []
    for i, leaf in enumerate(jax.tree.leaves(like)):
        arr = data[f"leaf_{i}"]
        if list(arr.shape) != manifest["shapes"][i] or \
                str(arr.dtype) != manifest["dtypes"][i]:
            raise CheckpointCorruptError(
                f"step {step}: leaf {i} is {arr.dtype}{list(arr.shape)}, "
                f"manifest recorded {manifest['dtypes'][i]}"
                f"{manifest['shapes'][i]}")
        if list(arr.shape) != list(np.shape(leaf)):
            raise TreeStructureError(
                f"step {step}: leaf {i} "
                f"({manifest.get('leaf_paths', ['?'] * (i + 1))[i]}): "
                f"checkpoint shape {list(arr.shape)} vs restore target "
                f"{list(np.shape(leaf))}")
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    if sharding_fn is not None:
        tree = sharding_fn(tree)
    return tree


def restore_checkpoint(path: str, step: int, like: Any, *,
                       host_id: int = 0,
                       sharding_fn: Callable[[Any], Any] | None = None,
                       verify: bool = True) -> Any:
    """Verified restore into the structure of `like`; re-shard with
    `sharding_fn` (elastic: the target mesh may differ from the one that
    saved).  Raises CheckpointCorruptError on damage (fallback-able) and
    TreeStructureError on a `like` mismatch (not fallback-able)."""
    t0 = time.monotonic()
    step_dir = _step_dir(path, step)
    if verify:
        ok, why = verify_checkpoint(path, step)
        if not ok:
            raise CheckpointCorruptError(f"step {step}: {why}")
    manifest = _read_manifest(step_dir)
    treedef = _check_structure(step, manifest, like)
    shard = os.path.join(step_dir, f"shard_{host_id}.npz")
    try:
        data = np.load(shard)
        _count_read(os.path.getsize(shard), "full")
    except Exception as e:  # zipfile/zlib raise various types on damage
        raise CheckpointCorruptError(f"step {step}: shard {host_id} "
                                     f"unreadable: {e}")
    tree = _audited_tree(step, manifest, like, treedef, data, sharding_fn)
    REGISTRY.counter("checkpoint_ops", op="restore")
    REGISTRY.observe("checkpoint_restore_s", time.monotonic() - t0)
    return tree


def restore_checkpoint_striped(path: str, step: int, like: Any, *,
                               rank: int, world: int, exchange,
                               host_id: int = 0,
                               sharding_fn: Callable[[Any], Any] | None
                               = None) -> Any:
    """Collective verified restore: ``world`` hosts each read 1/world of
    the shard's bytes and all-gather the stripes over ``exchange`` (see
    module docstring).  Every participating host must call this with the
    same (step, host_id) or the all-gather times out.

    Integrity: the CRC32 of the *assembled* bytes is checked against the
    shard's commit marker on every host — equivalent to the full-read
    ``verify_checkpoint`` audit for this shard, without re-reading it.
    """
    t0 = time.monotonic()
    step_dir = _step_dir(path, step)
    manifest = _read_manifest(step_dir)
    treedef = _check_structure(step, manifest, like)
    shard = os.path.join(step_dir, f"shard_{host_id}.npz")
    marker = os.path.join(step_dir, f"commit_{host_id}.json")
    try:
        with open(marker) as f:
            commit = json.load(f)
        size = os.path.getsize(shard)
        lo = rank * size // world
        hi = (rank + 1) * size // world
        with open(shard, "rb") as f:
            f.seek(lo)
            stripe = f.read(hi - lo)
        _count_read(len(stripe) + os.path.getsize(marker), "striped")
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"step {step}: shard {host_id} unreadable for striping: {e}")
    parts = exchange.allgather(f"ckpt:{step}:{host_id}:{size}", rank,
                               world, stripe)
    peer_bytes = sum(len(p) for i, p in enumerate(parts) if i != rank)
    if peer_bytes:
        REGISTRY.counter("checkpoint_stripe_bytes", peer_bytes, dir="recv")
        REGISTRY.counter("checkpoint_stripe_bytes",
                         len(stripe) * (world - 1), dir="sent")
    blob = b"".join(parts)
    crc = zlib.crc32(blob)
    if len(blob) != size or crc != commit.get("crc32"):
        REGISTRY.counter("checkpoint_crc_failures")
        raise CheckpointCorruptError(
            f"step {step}: assembled shard {host_id} CRC32 {crc:#010x} "
            f"({len(blob)} B) != committed {commit.get('crc32', 0):#010x} "
            f"({size} B)")
    try:
        data = np.load(io.BytesIO(blob))
    except Exception as e:
        raise CheckpointCorruptError(f"step {step}: assembled shard "
                                     f"{host_id} unreadable: {e}")
    tree = _audited_tree(step, manifest, like, treedef, data, sharding_fn)
    REGISTRY.counter("checkpoint_ops", op="restore_striped")
    REGISTRY.observe("checkpoint_restore_s", time.monotonic() - t0)
    return tree


class CheckpointManager:
    """Async checkpointing with bounded retention, restart discovery and
    verified-restore fallback."""

    def __init__(self, path: str, *, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1,
                 fault_hook: Callable[[int], None] | None = None):
        self.path = path
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        # fault injection seam (chaos `diskfull@N`): called with the step
        # on the writer thread BEFORE any bytes land; an exception it
        # raises surfaces at the next wait() like a real failed write
        self.fault_hook = fault_hook
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(path, exist_ok=True)

    def save_async(self, step: int, tree: Any, extra: dict | None = None):
        """Device->host copy happens here (blocking); the disk write is
        backgrounded. Call wait() before process exit."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                save_checkpoint(self.path, step, host_tree,
                                host_id=self.host_id, n_hosts=self.n_hosts,
                                extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        for s in _all_steps(self.path)[:-self.keep]:
            shutil.rmtree(_step_dir(self.path, s), ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.path)

    def restore(self, like: Any, step: int | None = None,
                sharding_fn=None,
                stripe: tuple[int, int, Any] | None = None
                ) -> tuple[int, Any] | None:
        """Restore `step` (default: newest), falling back through older
        checkpoints when the newer ones fail verification.  Returns
        (step, tree) or None when nothing intact exists.  A tree-structure
        mismatch raises immediately — older checkpoints would mismatch the
        same way, and silently restoring the wrong structure is the one
        failure this module exists to prevent.

        ``stripe=(rank, world, exchange)`` switches to the collective
        striped restore — only valid when every fleet member calls with
        the same view of the checkpoint dir (shared filesystem), so all
        ranks walk the same step sequence in lockstep; an exchange
        timeout (a ``TimeoutError``) propagates rather than triggering
        fallback, because peers may still be alive on the newer step."""
        def load(s: int) -> Any:
            if stripe is not None:
                rank, world, exchange = stripe
                return restore_checkpoint_striped(
                    self.path, s, like, rank=rank, world=world,
                    exchange=exchange, host_id=self.host_id,
                    sharding_fn=sharding_fn)
            return restore_checkpoint(self.path, s, like,
                                      host_id=self.host_id,
                                      sharding_fn=sharding_fn)

        if step is not None:
            return step, load(step)
        for s in reversed(_all_steps(self.path)):
            try:
                return s, load(s)
            except CheckpointCorruptError as e:
                print(f"[ckpt] step {s} failed verification ({e}); "
                      f"falling back")
        return None
