"""Training step factory: loss, remat, microbatch accumulation, AdamW.

The returned ``train_step(params, opt_state, batch) -> (params, opt_state,
metrics)`` is a single jit-able function; the launcher wraps it in jax.jit
with in/out shardings from repro.parallel.sharding. Microbatching runs a
lax.scan over grad accumulation so the global batch is decoupled from
per-device activation memory; remat uses the dots-saveable policy (recompute
everything except matmul outputs — the standard memory/compute trade at
scale).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    remat: bool = False  # models remat per-layer internally
    aux_weight: float = 0.01      # MoE load-balance loss weight
    z_weight: float = 1e-4        # z-loss for logit stability


def loss_fn(forward: Callable, params: Any, batch: dict,
            aux_weight: float = 0.01, z_weight: float = 1e-4) -> tuple:
    """Next-token CE + MoE aux + z-loss. forward(params, batch)->(logits,aux).

    The label logit is extracted with a masked SUM over the vocab axis (not
    take_along_axis/gather): the mask is elementwise over the vocab-sharded
    logits, so GSPMD never all-gathers the vocab dimension — gather would
    replicate (B, S, V) f32 on every chip.
    """
    logits, aux = forward(params, batch)
    labels = batch["labels"]
    T = labels.shape[1]
    logits = logits[:, -T:].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    at_label = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    ce = (logz - at_label).mean()
    zloss = (logz ** 2).mean()
    return ce + aux_weight * aux + z_weight * zloss, (ce, aux)


def make_train_step(forward: Callable, hyper: TrainHyper) -> Callable:
    """forward(params, batch) -> (logits, aux)."""

    flc = functools.partial(loss_fn, forward, aux_weight=hyper.aux_weight,
                            z_weight=hyper.z_weight)
    if hyper.remat:
        flc = jax.checkpoint(
            flc, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    grad_fn = jax.value_and_grad(flc, has_aux=True)

    def compute_grads(params, batch):
        if hyper.microbatches == 1:
            (loss, (ce, aux)), grads = grad_fn(params, batch)
            return loss, ce, aux, grads

        mb = hyper.microbatches

        def resplit(x):
            b = x.shape[0]
            assert b % mb == 0, (b, mb)
            return x.reshape(mb, b // mb, *x.shape[1:])

        micro = jax.tree.map(resplit, batch)

        def acc_step(carry, mbatch):
            loss_a, ce_a, aux_a, g_a = carry
            (loss, (ce, aux)), g = grad_fn(params, mbatch)
            g_a = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_a, g)
            return (loss_a + loss, ce_a + ce, aux_a + aux, g_a), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, ce, aux, grads), _ = jax.lax.scan(
            acc_step, (0.0, 0.0, 0.0, g0), micro)
        inv = 1.0 / mb
        return loss * inv, ce * inv, aux * inv, jax.tree.map(
            lambda g: g * inv, grads)

    def train_step(params, opt_state, batch):
        loss, ce, aux, grads = compute_grads(params, batch)
        params, opt_state, om = adamw_update(hyper.optimizer, params, grads,
                                             opt_state)
        metrics = {"loss": loss, "ce": ce, "aux": aux, **om}
        return params, opt_state, metrics

    return train_step
