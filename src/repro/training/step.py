"""Training step factory: loss, remat, microbatch accumulation, AdamW.

The returned ``train_step(params, opt_state, batch) -> (params, opt_state,
metrics)`` is a single jit-able function; the launcher wraps it in jax.jit
with in/out shardings from repro.parallel.sharding. Microbatching runs a
lax.scan over grad accumulation so the global batch is decoupled from
per-device activation memory; remat uses the dots-saveable policy (recompute
everything except matmul outputs — the standard memory/compute trade at
scale).

Nonfinite guard: every step all-reduces a FINITE flag over the loss and
every grad leaf (under jit/GSPMD the ``jnp.all`` reductions over sharded
leaves are already global collectives, so each host sees the same verdict)
and, when any value is nonfinite, keeps params/opt-state byte-identical —
a NaN burst skips a step instead of training the model into garbage.  The
host-side :class:`GradGuard` consumes the flag plus the loss each step and
escalates: a bounded budget of consecutive skips, then rollback; a
sustained loss spike above the running EMA, then rollback.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    remat: bool = False  # models remat per-layer internally
    aux_weight: float = 0.01      # MoE load-balance loss weight
    z_weight: float = 1e-4        # z-loss for logit stability


def loss_fn(forward: Callable, params: Any, batch: dict,
            aux_weight: float = 0.01, z_weight: float = 1e-4) -> tuple:
    """Next-token CE + MoE aux + z-loss. forward(params, batch)->(logits,aux).

    The label logit is extracted with a masked SUM over the vocab axis (not
    take_along_axis/gather): the mask is elementwise over the vocab-sharded
    logits, so GSPMD never all-gathers the vocab dimension — gather would
    replicate (B, S, V) f32 on every chip.
    """
    logits, aux = forward(params, batch)
    labels = batch["labels"]
    T = labels.shape[1]
    logits = logits[:, -T:].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    at_label = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    ce = (logz - at_label).mean()
    zloss = (logz ** 2).mean()
    return ce + aux_weight * aux + z_weight * zloss, (ce, aux)


def make_train_step(forward: Callable, hyper: TrainHyper) -> Callable:
    """forward(params, batch) -> (logits, aux)."""

    flc = functools.partial(loss_fn, forward, aux_weight=hyper.aux_weight,
                            z_weight=hyper.z_weight)
    if hyper.remat:
        flc = jax.checkpoint(
            flc, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    grad_fn = jax.value_and_grad(flc, has_aux=True)

    def compute_grads(params, batch):
        if hyper.microbatches == 1:
            (loss, (ce, aux)), grads = grad_fn(params, batch)
            return loss, ce, aux, grads

        mb = hyper.microbatches

        def resplit(x):
            b = x.shape[0]
            assert b % mb == 0, (b, mb)
            return x.reshape(mb, b // mb, *x.shape[1:])

        micro = jax.tree.map(resplit, batch)

        def acc_step(carry, mbatch):
            loss_a, ce_a, aux_a, g_a = carry
            (loss, (ce, aux)), g = grad_fn(params, mbatch)
            g_a = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_a, g)
            return (loss_a + loss, ce_a + ce, aux_a + aux, g_a), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, ce, aux, grads), _ = jax.lax.scan(
            acc_step, (0.0, 0.0, 0.0, g0), micro)
        inv = 1.0 / mb
        return loss * inv, ce * inv, aux * inv, jax.tree.map(
            lambda g: g * inv, grads)

    def train_step(params, opt_state, batch, grad_scale=None):
        loss, ce, aux, grads = compute_grads(params, batch)
        if grad_scale is not None:
            # fault-injection hook: the chaos runtime feeds NaN here so the
            # guard below is exercised end-to-end (1.0 in normal operation)
            grads = jax.tree.map(lambda g: g * grad_scale, grads)
        finite = jnp.isfinite(loss)
        for g in jax.tree.leaves(grads):
            finite &= jnp.all(jnp.isfinite(g))
        new_params, new_opt, om = adamw_update(hyper.optimizer, params,
                                               grads, opt_state)
        # skip-step: a nonfinite loss/grad leaves params, moments AND the
        # schedule step untouched (jnp.where keeps dtypes leaf-by-leaf)
        keep = lambda new, old: jnp.where(finite, new, old)  # noqa: E731
        params = jax.tree.map(keep, new_params, params)
        opt_state = jax.tree.map(keep, new_opt, opt_state)
        metrics = {"loss": loss, "ce": ce, "aux": aux,
                   "finite": finite.astype(jnp.float32), **om}
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# host-side escalation: skip budget + loss-spike divergence -> rollback
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    max_consecutive_skips: int = 3   # nonfinite steps in a row before rollback
    spike_factor: float = 3.0        # loss > factor * EMA counts as a spike
    spike_patience: int = 3          # consecutive spikes before rollback
    ema_beta: float = 0.9            # loss EMA decay
    warmup_steps: int = 5            # steps before spike detection arms


class GradGuard:
    """Consumes (loss, finite) once per step; returns the loop's action:

    ``"ok"``        update applied, loss healthy
    ``"skip"``      nonfinite step — params were not updated (in-jit
                    guard); within the consecutive-skip budget
    ``"rollback"``  skip budget exhausted, or the loss has spiked above
                    ``spike_factor`` x its EMA for ``spike_patience``
                    consecutive steps — restore the last checkpoint

    Pure host-side state so policies are unit-testable without a model;
    call :meth:`reset` after acting on a rollback.
    """

    def __init__(self, policy: GuardPolicy = GuardPolicy()):
        self.policy = policy
        self.ema: float | None = None
        self.steps = 0
        self.consecutive_skips = 0
        self.consecutive_spikes = 0
        # what caused the most recent skip/rollback — the train loop logs
        # it with the step index and it labels the gradguard_events
        # counters in the metrics registry
        self.last_trigger: str | None = None

    def update(self, loss: float, finite: bool) -> str:
        from repro.obs import REGISTRY
        p = self.policy
        if not finite or not math.isfinite(loss):
            self.consecutive_skips += 1
            if self.consecutive_skips > p.max_consecutive_skips:
                self.last_trigger = "skip_budget"
                REGISTRY.counter("gradguard_events", kind="rollback",
                                 trigger="skip_budget")
                return "rollback"
            self.last_trigger = "nonfinite"
            REGISTRY.counter("gradguard_events", kind="skip",
                             trigger="nonfinite")
            return "skip"
        self.consecutive_skips = 0
        self.steps += 1
        if self.ema is None:
            self.ema = loss
            return "ok"
        if self.steps > p.warmup_steps and loss > p.spike_factor * self.ema:
            # diverging: don't fold the spike into the EMA (that would
            # normalize the divergence it is trying to detect)
            self.consecutive_spikes += 1
            if self.consecutive_spikes >= p.spike_patience:
                self.last_trigger = "loss_spike"
                REGISTRY.counter("gradguard_events", kind="rollback",
                                 trigger="loss_spike")
                return "rollback"
            return "ok"
        self.consecutive_spikes = 0
        self.ema = p.ema_beta * self.ema + (1 - p.ema_beta) * loss
        return "ok"

    def reset(self) -> None:
        """Forget history after a rollback (the restored state's loss scale
        may differ from the diverged one's)."""
        self.ema = None
        self.steps = 0
        self.consecutive_skips = 0
        self.consecutive_spikes = 0
