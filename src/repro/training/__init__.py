from .step import (GradGuard, GuardPolicy, TrainHyper, loss_fn,
                   make_train_step)

__all__ = ["GradGuard", "GuardPolicy", "TrainHyper", "loss_fn",
           "make_train_step"]
