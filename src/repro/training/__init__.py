from .step import TrainHyper, make_train_step, loss_fn

__all__ = ["TrainHyper", "make_train_step", "loss_fn"]
