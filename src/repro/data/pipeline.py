"""Data pipeline: deterministic synthetic LM stream + host-sharded loader.

Design mirrors a production multi-host input pipeline:
  * the logical dataset is an infinite, seedable, *indexable* stream, so any
    host can compute any batch — restart/elastic re-shard need no data state
    beyond the step counter (checkpoint stores only `step`);
  * each host takes a disjoint slice of the global batch
    (``ShardedLoader``) determined by (host_id, n_hosts);
  * a background ``Prefetcher`` thread keeps `depth` batches ready so input
    never serializes with the step (compute/IO overlap on the host side);
  * straggler mitigation hook: ``ShardedLoader.reshard`` reassigns slices
    when the runtime reports a slow/failed host (see repro.runtime).
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLM:
    """Deterministic synthetic token stream: batch `i` is a pure function of
    (seed, i). A light Markov structure makes the loss meaningfully
    decreasing (learnable bigram skeleton + noise) rather than pure noise."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed random bigram table: next-token bias
        self._bigram = rng.integers(0, cfg.vocab,
                                    size=(cfg.vocab,)).astype(np.int32)

    @staticmethod
    def _hash(x: np.ndarray) -> np.ndarray:
        """splitmix64 — counter-based randomness, vectorized."""
        x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
        x ^= x >> np.uint64(30)
        x = (x * np.uint64(0xBF58476D1CE4E5B9)).astype(np.uint64)
        x ^= x >> np.uint64(27)
        x = (x * np.uint64(0x94D049BB133111EB)).astype(np.uint64)
        return x ^ (x >> np.uint64(31))

    def batch(self, index: int, start: int, size: int) -> dict[str, np.ndarray]:
        """Rows [start, start+size) of global batch `index`.

        Row r of batch i is a pure function of (seed, i, r) — NOT of the
        (start, size) slicing — so any shard decomposition (and any elastic
        re-shard) sees identical data."""
        cfg = self.cfg
        rows = (np.arange(start, start + size, dtype=np.uint64)[:, None]
                + np.uint64(index) * np.uint64(1 << 20)
                + np.uint64(cfg.seed) * np.uint64(1 << 40))
        t_ix = np.arange(cfg.seq_len + 1, dtype=np.uint64)[None, :]
        h = self._hash(rows * np.uint64(0x100000001) + t_ix)
        rand = (h % np.uint64(cfg.vocab)).astype(np.int32)
        noise = (self._hash(h) >> np.uint64(40)).astype(np.float64) / (1 << 24)
        toks = np.empty((size, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rand[:, 0]
        for t in range(cfg.seq_len):
            follow = self._bigram[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.75, follow,
                                      rand[:, t + 1])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ShardedLoader:
    """Host-sharded view of the stream: host h of H owns rows
    [h*B/H, (h+1)*B/H) of every global batch."""

    def __init__(self, source: SyntheticLM, host_id: int, n_hosts: int):
        cfg = source.cfg
        assert cfg.global_batch % n_hosts == 0, (cfg.global_batch, n_hosts)
        self.source = source
        self.host_id = host_id
        self.n_hosts = n_hosts

    @property
    def per_host(self) -> int:
        return self.source.cfg.global_batch // self.n_hosts

    def batch(self, index: int) -> dict[str, np.ndarray]:
        return self.source.batch(index, self.host_id * self.per_host,
                                 self.per_host)

    def reshard(self, host_id: int, n_hosts: int) -> "ShardedLoader":
        """Elastic re-shard after a host set change (no data state lost —
        the stream is indexable)."""
        return ShardedLoader(self.source, host_id, n_hosts)


class Prefetcher:
    """Background thread that keeps `depth` batches ready."""

    def __init__(self, loader: ShardedLoader, start_step: int = 0,
                 depth: int = 2):
        self.loader = loader
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.loader.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict[str, np.ndarray]]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def make_train_iterator(cfg: DataConfig, host_id: int = 0, n_hosts: int = 1,
                        start_step: int = 0, prefetch: int = 2) -> Prefetcher:
    return Prefetcher(ShardedLoader(SyntheticLM(cfg), host_id, n_hosts),
                      start_step=start_step, depth=prefetch)
