from .pipeline import (DataConfig, SyntheticLM, ShardedLoader, Prefetcher,
                       make_train_iterator)

__all__ = ["DataConfig", "SyntheticLM", "ShardedLoader", "Prefetcher",
           "make_train_iterator"]
