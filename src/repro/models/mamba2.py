"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

The SSD chunked form IS a chain of dense GEMMs (intra-chunk quadratic block +
low-rank inter-chunk state passing), which is exactly the workload family the
paper's tiling methodology targets; the chunk size plays the TEU-tile role.
Sub-quadratic in sequence length -> this arch runs the long_500k shape.

Layers scan-stacked; decode keeps O(1) state (conv window + SSM state), so
a "500k-token KV cache" is a few MB of state.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import gather_seq, rms_norm, shard_seq

# Pooled-serving slot layout (see serving/engine.py _write_slot): batch axis
# of every cache entry.  SSM state caches are position-free, so padded
# prefill would corrupt them — no PREFILL_TRUE_LENGTHS here.
CACHE_BATCH_AXES = {"conv": 1, "ssm": 1, "length": 0}


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    chunk: int = 128
    remat: bool = True
    # sequence parallelism hurts here: d_model=1024 gives tiny per-device
    # shards and GSPMD re-gathers around the SSD chunk scans (2.5x flops,
    # 5x traffic measured) — see EXPERIMENTS.md SPerf, lesson L3.
    sp: bool = False
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def d_xbc(self) -> int:
        return self.d_inner + 2 * self.d_state

    def param_count(self) -> int:
        D, Din, N, L = self.d_model, self.d_inner, self.d_state, self.n_layers
        in_proj = D * (2 * Din + 2 * N + self.n_heads)
        conv = self.d_xbc * self.d_conv
        out = Din * D
        per_layer = in_proj + conv + out + 2 * self.n_heads + Din + 2 * D
        return L * per_layer + 2 * self.vocab * D + D


def init_params(cfg: Mamba2Config, key: jax.Array) -> dict:
    D, Din, N, H, L = (cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads,
                       cfg.n_layers)
    ks = jax.random.split(key, 8)
    dt = cfg.dtype

    def nrm(k, shape, scale=0.02):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    layers = {
        "ln": jnp.ones((L, D), dt),
        "in_proj": nrm(ks[0], (L, D, 2 * Din + 2 * N + H)),
        "conv_w": nrm(ks[1], (L, cfg.d_conv, cfg.d_xbc), 0.2),
        "conv_b": jnp.zeros((L, cfg.d_xbc), dt),
        "A_log": jnp.tile(jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
                          (L, 1)),
        "dt_bias": jnp.zeros((L, H), jnp.float32),
        "D_skip": jnp.ones((L, H), jnp.float32),
        "gnorm": jnp.ones((L, Din), dt),
        "out_proj": nrm(ks[2], (L, Din, D)),
    }
    return {
        "embed": nrm(ks[3], (cfg.vocab, D)),
        "layers": layers,
        "ln_f": jnp.ones((D,), dt),
        "lm_head": nrm(ks[4], (D, cfg.vocab)),
    }


def _ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    x: (B, L, H, P); dt: (B, L, H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B, L, N). Returns y: (B, L, H, P).
    """
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    if L % chunk:
        # pad with dt=0 steps: decay exp(0)=1 and zero state contribution,
        # so padding is exact; the padded rows are sliced off below.
        pad = chunk - L % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Lp = x.shape[1]
    T = Lp // chunk

    def resh(a, trailing):
        return a.reshape((B, T, chunk) + trailing).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(trailing))))


    xc = resh(x.astype(jnp.float32), (H, P))       # (T, B, Q, H, P)
    dtc = resh(dt, (H,))                            # (T, B, Q, H)
    Bc = resh(Bm.astype(jnp.float32), (N,))         # (T, B, Q, N)
    Cc = resh(Cm.astype(jnp.float32), (N,))         # (T, B, Q, N)

    a = dtc * A                                     # (T, B, Q, H) log-decay
    a_cum = jnp.cumsum(a, axis=2)                   # within-chunk cumsum
    a_tot = a_cum[:, :, -1]                         # (T, B, H)

    def step(S, inp):
        xq, dtq, Bq, Cq, acum, atot = inp
        # decay from step j to end of chunk / to step i
        # intra-chunk (the "diag block" GEMM of SSD):
        idx = jnp.arange(acum.shape[1])
        causal = (idx[:, None] >= idx[None, :])[None, :, :, None]
        # mask the EXPONENT, not just the product: non-causal entries have
        # positive log-decay sums that overflow exp to inf, and
        # where(causal, inf, 0) back-propagates inf * 0 = NaN into acum.
        diff = acum[:, :, None, :] - acum[:, None, :, :]            # (B,Q,Q,H)
        Lmat = jnp.where(causal, jnp.exp(jnp.where(causal, diff, 0.0)), 0.0)
        scores = jnp.einsum("bin,bjn->bij", Cq, Bq)                # (B,Q,Q)
        w = scores[..., None] * Lmat * dtq[:, None, :, :]           # (B,Q,Q,H)
        y_diag = jnp.einsum("bijh,bjhp->bihp", w, xq)
        # contribution of the carried state (the "low-rank" block):
        y_off = jnp.einsum("bin,bhpn->bihp", Cq, S) * \
            jnp.exp(acum)[..., None]
        # new chunk-final state
        decay_to_end = jnp.exp(atot[:, None, :] - acum)             # (B,Q,H)
        Sc = jnp.einsum("bjn,bjh,bjhp->bhpn", Bq, decay_to_end * dtq, xq)
        S = jnp.exp(atot)[..., None, None] * S + Sc
        return S, y_diag + y_off

    S0 = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(step, S0, (xc, dtc, Bc, Cc, a_cum, a_tot))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Lp, H, P)
    return y[:, :L]


def _split_proj(cfg: Mamba2Config, zxbcdt):
    Din, N, H = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :Din]
    xbc = zxbcdt[..., Din:Din + cfg.d_xbc]
    dt = zxbcdt[..., Din + cfg.d_xbc:]
    return z, xbc, dt


def _mix_block(cfg: Mamba2Config, lp, x, conv_state=None, ssm_state=None,
               single_step: bool = False):
    """One mamba2 mixer. x: (B, L, D) (or (B, 1, D) when single_step)."""
    B, L, D = x.shape
    Din, N, H, P = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.headdim
    zxbcdt = x @ lp["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)

    if single_step:
        # roll conv window: conv_state (B, d_conv-1, d_xbc)
        win = jnp.concatenate([conv_state, xbc.astype(jnp.float32)], axis=1)
        new_conv = win[:, 1:]
        conv_w = lp["conv_w"].astype(jnp.float32)      # (d_conv, d_xbc)
        xbc = jax.nn.silu((win * conv_w[None]).sum(1) +
                          lp["conv_b"].astype(jnp.float32))[:, None]
    else:
        pad = jnp.zeros((B, cfg.d_conv - 1, cfg.d_xbc), jnp.float32)
        seq = jnp.concatenate([pad, xbc.astype(jnp.float32)], axis=1)
        conv_w = lp["conv_w"].astype(jnp.float32)
        xbc = sum(seq[:, i:i + L] * conv_w[i][None, None]
                  for i in range(cfg.d_conv))
        xbc = jax.nn.silu(xbc + lp["conv_b"].astype(jnp.float32))
        new_conv = seq[:, L:]  # unused in train

    xs = xbc[..., :Din].reshape(B, -1, H, P)
    Bm = xbc[..., Din:Din + N]
    Cm = xbc[..., Din + N:]
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))       # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         lp["dt_bias"].astype(jnp.float32))

    if single_step:
        dA = jnp.exp(dt[:, 0] * A)                      # (B, H)
        Sc = jnp.einsum("bn,bh,bhp->bhpn", Bm[:, 0], dt[:, 0], xs[:, 0])
        ssm_state = dA[..., None, None] * ssm_state + Sc
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], ssm_state)[:, None]
    else:
        y = _ssd_chunked(xs, dt, A, Bm, Cm, min(cfg.chunk, L))
        if ssm_state is None:
            ssm_state = jnp.zeros((B, H, P, N), jnp.float32)

    y = y + lp["D_skip"].astype(jnp.float32)[None, None, :, None] * xs
    y = y.reshape(B, -1, Din)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(cfg.dtype), lp["gnorm"], cfg.norm_eps)
    return y @ lp["out_proj"], new_conv, ssm_state


def forward(cfg: Mamba2Config, params: dict, tokens: jax.Array,
            vision_embeds=None):
    x = params["embed"][tokens]

    def body(x, lp):
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        if cfg.sp:
            h = gather_seq(h)
        o, _, _ = _mix_block(cfg, lp, h)
        x = x + o
        return (shard_seq(x) if cfg.sp else x), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x @ params["lm_head"], 0.0


def init_cache(cfg: Mamba2Config, batch: int, max_len: int = 0,
               kv_dtype: Any = None) -> dict:
    L, H, P, N = cfg.n_layers, cfg.n_heads, cfg.headdim, cfg.d_state
    return {
        "conv": jnp.zeros((L, batch, cfg.d_conv - 1, cfg.d_xbc), jnp.float32),
        "ssm": jnp.zeros((L, batch, H, P, N), jnp.float32),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg: Mamba2Config, params: dict, tokens: jax.Array, cache: dict,
            vision_embeds=None):
    """Prefill = forward pass that also leaves final (conv, ssm) states."""
    x = params["embed"][tokens]
    B, L, _ = x.shape

    def body(x, lp):
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        zxbcdt = h @ lp["in_proj"]
        z, xbc, dt = _split_proj(cfg, zxbcdt)
        pad = jnp.zeros((B, cfg.d_conv - 1, cfg.d_xbc), jnp.float32)
        seq = jnp.concatenate([pad, xbc.astype(jnp.float32)], axis=1)
        conv_w = lp["conv_w"].astype(jnp.float32)
        xc = sum(seq[:, i:i + L] * conv_w[i][None, None]
                 for i in range(cfg.d_conv))
        xc = jax.nn.silu(xc + lp["conv_b"].astype(jnp.float32))
        conv_state = seq[:, L:]
        Din, N, H, P = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.headdim
        xs = xc[..., :Din].reshape(B, L, H, P)
        Bm = xc[..., Din:Din + N]
        Cm = xc[..., Din + N:]
        A = -jnp.exp(lp["A_log"].astype(jnp.float32))
        dtv = jax.nn.softplus(dt.astype(jnp.float32) +
                              lp["dt_bias"].astype(jnp.float32))
        y = _ssd_chunked(xs, dtv, A, Bm, Cm, min(cfg.chunk, L))
        # final state: replay decay over the whole sequence cheaply via the
        # same chunk recursion (recompute last chunk's S) — here we fold the
        # full sequence: S = sum_j exp(sum_{k>j} a_k) dt_j B_j x_j
        a = dtv * A
        a_rev = jnp.cumsum(a[:, ::-1], axis=1)[:, ::-1] - a
        S = jnp.einsum("bjn,bjh,bjhp->bhpn", Bm,
                       jnp.exp(a_rev) * dtv, xs)
        y = y + lp["D_skip"].astype(jnp.float32)[None, None, :, None] * xs
        y = y.reshape(B, L, Din) * jax.nn.silu(z.astype(jnp.float32))
        y = rms_norm(y.astype(cfg.dtype), lp["gnorm"], cfg.norm_eps)
        return x + y @ lp["out_proj"], (conv_state, S)

    x, (convs, ssms) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x[:, -1:] @ params["lm_head"]
    cache = {"conv": convs, "ssm": ssms,
             "length": jnp.full((B,), L, jnp.int32)}
    return logits, cache


def decode_step(cfg: Mamba2Config, params: dict, tokens: jax.Array,
                cache: dict):
    x = params["embed"][tokens]
    B = x.shape[0]

    def body(x, inp):
        lp, conv_s, ssm_s = inp
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        o, conv_s, ssm_s = _mix_block(cfg, lp, h, conv_s, ssm_s,
                                      single_step=True)
        return x + o, (conv_s, ssm_s)

    x, (convs, ssms) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["ssm"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return logits, {"conv": convs, "ssm": ssms,
                    "length": cache["length"] + 1}
