"""Decoder-only transformer family (dense GQA + MoE variants + VLM backbone).

Covers qwen3-4b (qk_norm), qwen2.5-14b / qwen1.5-32b (QKV bias), yi-9b,
internvl2-26b (vision-prefix backbone; the ViT frontend is a stub per the
assignment — ``vision_embeds`` arrive precomputed), granite-moe and olmoe
(MoE MLPs). Layers are stacked on a leading axis and traversed with
jax.lax.scan so the HLO stays compact for the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.runtime import compat
from .layers import (MoEConfig, apply_rope, attention, decode_attention,
                     gather_seq, moe_layer, paged_decode_attention,
                     quantize_kv, rms_norm, shard_seq, swiglu)

# Serving-engine capability flags (see configs/base.py and serving/engine.py):
# prefill accepts ``true_lengths`` for length-bucketed padded prompts, the
# KV cache pages cleanly (pure attention KV, per-position writes), and the
# pooled-cache slot layout is declared instead of assumed.
PREFILL_TRUE_LENGTHS = True
SUPPORTS_PAGED_KV = True
CACHE_BATCH_AXES = {"k": 1, "v": 1, "k_scale": 1, "v_scale": 1, "length": 0}


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    moe: MoEConfig | None = None
    window: int | None = None         # sliding-window attention (None = full)
    remat: bool = True                # per-layer activation checkpointing
    vision_tokens: int = 0            # VLM prefix length (stub frontend)
    dtype: Any = jnp.bfloat16
    attn_impl: str = "auto"           # auto | xla | pallas (flash policy)
    ring_attn: str | None = None      # context-parallel mode override
    #   (auto|ring|replicated|off); None defers to configs.base policy /
    #   REPRO_RING_ATTN — see RingAttnPolicy

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        D, H, Kv, Dh, F, V, L = (self.d_model, self.n_heads, self.n_kv_heads,
                                 self.dh, self.d_ff, self.vocab, self.n_layers)
        attn = D * H * Dh + 2 * D * Kv * Dh + H * Dh * D
        if self.moe:
            mlp = D * self.moe.n_experts + \
                3 * self.moe.n_experts * D * self.moe.d_ff
        else:
            mlp = 3 * D * F
        return L * (attn + mlp + 2 * D) + 2 * V * D + D

    def active_param_count(self) -> int:
        """Per-token active params (MoE uses top_k experts)."""
        if not self.moe:
            return self.param_count()
        D, H, Kv, Dh, L = (self.d_model, self.n_heads, self.n_kv_heads,
                           self.dh, self.n_layers)
        attn = D * H * Dh + 2 * D * Kv * Dh + H * Dh * D
        mlp = D * self.moe.n_experts + 3 * self.moe.top_k * D * self.moe.d_ff
        return L * (attn + mlp + 2 * D) + 2 * self.vocab * D + D


def init_params(cfg: TransformerConfig, key: jax.Array) -> dict:
    D, H, Kv, Dh, F, V, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh,
                             cfg.d_ff, cfg.vocab, cfg.n_layers)
    ks = jax.random.split(key, 16)
    dt = cfg.dtype
    s = 0.02

    def nrm(k, shape, scale=s):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    layers: dict[str, jax.Array] = {
        "ln1": jnp.ones((L, D), dt),
        "ln2": jnp.ones((L, D), dt),
        "wq": nrm(ks[0], (L, D, H * Dh)),
        "wk": nrm(ks[1], (L, D, Kv * Dh)),
        "wv": nrm(ks[2], (L, D, Kv * Dh)),
        "wo": nrm(ks[3], (L, H * Dh, D)),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((L, H * Dh), dt)
        layers["bk"] = jnp.zeros((L, Kv * Dh), dt)
        layers["bv"] = jnp.zeros((L, Kv * Dh), dt)
    if cfg.qk_norm:
        layers["q_norm"] = jnp.ones((L, Dh), dt)
        layers["k_norm"] = jnp.ones((L, Dh), dt)
    if cfg.moe:
        E, Fe = cfg.moe.n_experts, cfg.moe.d_ff
        layers["router"] = nrm(ks[4], (L, D, E))
        layers["w_gate"] = nrm(ks[5], (L, E, D, Fe))
        layers["w_up"] = nrm(ks[6], (L, E, D, Fe))
        layers["w_down"] = nrm(ks[7], (L, E, Fe, D))
    else:
        layers["w_gate"] = nrm(ks[5], (L, D, F))
        layers["w_up"] = nrm(ks[6], (L, D, F))
        layers["w_down"] = nrm(ks[7], (L, F, D))
    return {
        "embed": nrm(ks[8], (V, D)),
        "layers": layers,
        "ln_f": jnp.ones((D,), dt),
        "lm_head": nrm(ks[9], (D, V)),
    }


def _qkv(cfg: TransformerConfig, lp: dict, x: jax.Array, positions):
    B, S, D = x.shape
    H, Kv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = x @ lp["wq"]
    k = x @ lp["wk"]
    v = x @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, Kv, Dh)
    v = v.reshape(B, S, Kv, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _block_train(cfg: TransformerConfig, x, lp, positions):
    h = gather_seq(rms_norm(x, lp["ln1"], cfg.norm_eps))
    q, k, v = _qkv(cfg, lp, h, positions)
    o = attention(q, k, v, causal=True, window=cfg.window,
                  impl=cfg.attn_impl, ring=cfg.ring_attn)
    # saved by the remat policy: backward reuses the attention output
    # instead of re-streaming the whole flash pipeline (§Perf B1)
    from jax.ad_checkpoint import checkpoint_name
    o = checkpoint_name(o, "attn_out")
    # Megatron-SP residual stream: the carry x stays SEQUENCE-SHARDED and
    # only the deltas are resharded before the add — GSPMD then lowers the
    # wo / w_down partial-sum contractions as reduce-scatter instead of
    # all-reduce (16x fewer collective bytes; §Perf B2).
    x = x + shard_seq(o.reshape(*x.shape[:2], -1) @ lp["wo"])
    h = gather_seq(rms_norm(x, lp["ln2"], cfg.norm_eps))
    if cfg.moe:
        mo, aux = moe_layer(h, lp, cfg.moe)
    else:
        mo, aux = swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"]), 0.0
    return x + shard_seq(mo), aux


def forward(cfg: TransformerConfig, params: dict, tokens: jax.Array,
            vision_embeds: jax.Array | None = None):
    """tokens: (B, S_text) int32 -> logits (B, S, vocab), aux_loss.

    For VLM configs, ``vision_embeds`` (B, P, D) is prepended (stub ViT)."""
    x = params["embed"][tokens]
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].astype(jnp.int32)

    def body(carry, lp):
        x, aux = carry
        x, a = _block_train(cfg, x, lp, positions)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out"))
    (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return logits, aux


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               kv_dtype: Any = None) -> dict:
    kv_dtype = kv_dtype or cfg.dtype
    L, Kv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
    cache = {
        "k": jnp.zeros((L, batch, max_len, Kv, Dh), kv_dtype),
        "v": jnp.zeros((L, batch, max_len, Kv, Dh), kv_dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }
    if kv_dtype == jnp.int8:
        cache["k_scale"] = jnp.zeros((L, batch, max_len, Kv), jnp.float32)
        cache["v_scale"] = jnp.zeros((L, batch, max_len, Kv), jnp.float32)
    return cache


def prefill(cfg: TransformerConfig, params: dict, tokens: jax.Array,
            cache: dict, vision_embeds: jax.Array | None = None,
            true_lengths: jax.Array | None = None):
    """Run the prompt through the model, filling the cache.

    Returns (logits_last, cache).

    ``true_lengths`` (B,) supports length-BUCKETED prompts: tokens may be
    right-padded to a bucket size, and causality guarantees every position
    < true_lengths[b] is unaffected by the padding.  The cache length is
    set to the true length (decode overwrites the first junk position and
    masks the rest) and the returned logits are taken at position
    ``true_lengths - 1`` instead of the padded last row."""
    x = params["embed"][tokens]
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].astype(jnp.int32)

    def body(x, lp):
        h = gather_seq(rms_norm(x, lp["ln1"], cfg.norm_eps))
        q, k, v = _qkv(cfg, lp, h, positions)
        o = attention(q, k, v, causal=True, window=cfg.window,
                      impl=cfg.attn_impl, ring=cfg.ring_attn)
        x = x + o.reshape(B, S, -1) @ lp["wo"]
        h = gather_seq(rms_norm(x, lp["ln2"], cfg.norm_eps))
        if cfg.moe:
            mo, _ = moe_layer(h, lp, cfg.moe)
        else:
            mo = swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
        return shard_seq(x + mo), (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    kv_dt = cache["k"].dtype
    if true_lengths is None:
        new_cache = {"length": jnp.full((B,), S, jnp.int32)}
    else:
        new_cache = {"length": true_lengths.astype(jnp.int32)}
    if kv_dt == jnp.int8:
        kq, kscale = quantize_kv(ks)
        vq, vscale = quantize_kv(vs)
        new_cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], kq, (0, 0, 0, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], vq, (0, 0, 0, 0, 0))
        new_cache["k_scale"] = jax.lax.dynamic_update_slice(
            cache["k_scale"], kscale, (0, 0, 0, 0))
        new_cache["v_scale"] = jax.lax.dynamic_update_slice(
            cache["v_scale"], vscale, (0, 0, 0, 0))
    else:
        new_cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], ks.astype(kv_dt), (0, 0, 0, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], vs.astype(kv_dt), (0, 0, 0, 0, 0))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if true_lengths is None:
        logits = x[:, -1:] @ params["lm_head"]
    else:
        last = x[jnp.arange(B), true_lengths - 1][:, None]
        logits = last @ params["lm_head"]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Paged KV serving (block-pool cache; see repro.serving.kv)
# ---------------------------------------------------------------------------

def init_paged_pool(cfg: TransformerConfig, num_pages: int, page_size: int,
                    kv_dtype: Any = None) -> dict:
    """Global page-pool arrays for the paged serving path.  Page 0 is the
    TRASH page (pad-token writes land there; never mapped to a slot)."""
    kv_dtype = kv_dtype or cfg.dtype
    L, Kv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
    pool = {
        "k": jnp.zeros((L, num_pages, page_size, Kv, Dh), kv_dtype),
        "v": jnp.zeros((L, num_pages, page_size, Kv, Dh), kv_dtype),
    }
    if kv_dtype == jnp.int8:
        pool["k_scale"] = jnp.zeros((L, num_pages, page_size, Kv),
                                    jnp.float32)
        pool["v_scale"] = jnp.zeros((L, num_pages, page_size, Kv),
                                    jnp.float32)
    return pool


def paged_step(cfg: TransformerConfig, params: dict, tokens: jax.Array,
               pool: dict, page_table: jax.Array, lengths: jax.Array,
               counts: jax.Array):
    """One paged serving step: scatter T new tokens' K/V into the pool and
    attend against each slot's paged history.

    tokens: (B, T); counts: (B,) valid tokens per row (<= T; rows with
    count 0 are idle slots riding the SPMD step).  Rows are INDEPENDENT,
    so one call may mix prefill chunks (counts[b] > 1) and decode rows
    (counts[b] == 1) — the engine's continuous-batching tick is exactly
    such a merged call.  page_table: (B, max_pages_view) physical page
    ids — the engine passes a power-of-two SLICE of the full table
    covering the longest active slot, so gather/attention cost scales
    with actual lengths, not max_len.  lengths: (B,) tokens cached before
    this call; because positions derive from it, a row whose leading
    pages were mapped read-only from the prefix cache simply starts with
    lengths[b] == matched tokens and writes land mid-sequence (mid-page
    included) in its first PRIVATE page — shared pages are never written.
    Pad/idle writes are routed to trash page 0.

    Returns (logits (B, T, vocab), pool', lengths + counts)."""
    x = params["embed"][tokens]
    B, T, _ = x.shape
    page = pool["k"].shape[2]
    MP = page_table.shape[1]
    positions = lengths[:, None] + jnp.arange(T)[None, :]      # (B, T)
    valid = jnp.arange(T)[None, :] < counts[:, None]
    lp_idx = jnp.clip(positions // page, 0, MP - 1)
    phys = jnp.where(valid,
                     jnp.take_along_axis(page_table, lp_idx, axis=1), 0)
    off = positions % page
    quantized = "k_scale" in pool
    # the Pallas kernel path is decode-only; chunked prefill stays on the
    # gather path (its q block is the whole chunk, a different schedule)
    impl = cfg.attn_impl if T == 1 else "xla"

    def replicate(x):
        # Pin per-token tensors REPLICATED whenever a mesh is ambient.
        # With a head-dim-sharded pool, letting GSPMD propagate the
        # scatter operand's sharding back INTO the rope/qk-norm subgraph
        # miscompiles on the 0.4.37 CPU partitioner (measured: q off by
        # >2x, written pages doubled — rope's split/concat on the sharded
        # Dh axis feeding a scatter is the trigger).  Serving tokens are
        # a few KB, so replicating them is free; the POOL stays sharded
        # and the gather/attention path handles it exactly.  No-op
        # outside a mesh context.
        mesh = compat.get_abstract_mesh()
        if mesh is None or getattr(mesh, "empty", False):
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*([None] * x.ndim)))

    def write(pages, new):
        # (P, page, ...) scattered at per-token (phys, off) pairs; rows of
        # one slot never collide (consecutive positions), distinct slots
        # own distinct pages, and all invalid tokens land on trash page 0.
        return pages.at[phys, off].set(new.astype(pages.dtype))

    def body(x, inp):
        if quantized:
            lp, kc, vc, ksc, vsc = inp
        else:
            lp, kc, vc = inp
            ksc = vsc = None
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, lp, h, positions)
        q, k, v = replicate(q), replicate(k), replicate(v)
        if quantized:
            kq, ks_ = quantize_kv(k)
            vq, vs_ = quantize_kv(v)
            kc, vc = write(kc, kq), write(vc, vq)
            ksc, vsc = write(ksc, ks_), write(vsc, vs_)
            o = paged_decode_attention(q, kc, vc, page_table, lengths,
                                       ksc, vsc, impl=impl)
            out_pool = (kc, vc, ksc, vsc)
        else:
            kc, vc = write(kc, k), write(vc, v)
            o = paged_decode_attention(q, kc, vc, page_table, lengths,
                                       impl=impl)
            out_pool = (kc, vc)
        x = x + replicate(o).reshape(B, T, -1) @ lp["wo"]
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe:
            mo, _ = moe_layer(h, lp, cfg.moe)
        else:
            mo = swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
        # the residual carry stays replicated too: serving activations are
        # small, and this keeps GSPMD from threading pool-derived layouts
        # through the layer scan
        return replicate(x + mo), out_pool

    if quantized:
        xs = (params["layers"], pool["k"], pool["v"], pool["k_scale"],
              pool["v_scale"])
        x, (ks, vs, kss, vss) = jax.lax.scan(body, x, xs)
        new_pool = {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss}
    else:
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], pool["k"],
                                             pool["v"]))
        new_pool = {"k": ks, "v": vs}
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return logits, new_pool, lengths + counts


def decode_step(cfg: TransformerConfig, params: dict, tokens: jax.Array,
                cache: dict):
    """tokens: (B, 1) -> (logits (B, 1, V), cache). One serving step."""
    x = params["embed"][tokens]
    B = x.shape[0]
    positions = cache["length"][:, None].astype(jnp.int32)

    quantized = "k_scale" in cache

    def upd_cache(c, new):
        # per-slot write position (continuous batching: lengths differ)
        return jax.vmap(
            lambda cb, nb, p: jax.lax.dynamic_update_slice(
                cb, nb.astype(cb.dtype), (p,) + (0,) * (cb.ndim - 1))
        )(c, new, cache["length"])

    def body(x, inp):
        if quantized:
            lp, kc, vc, ksc, vsc = inp
        else:
            lp, kc, vc = inp
            ksc = vsc = None
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, lp, h, positions)
        if quantized:
            kq, ks_ = quantize_kv(k)
            vq, vs_ = quantize_kv(v)
            kc, vc = upd_cache(kc, kq), upd_cache(vc, vq)
            ksc, vsc = upd_cache(ksc, ks_), upd_cache(vsc, vs_)
            o = decode_attention(q, kc, vc, cache["length"] + 1, ksc, vsc)
            out_caches = (kc, vc, ksc, vsc)
        else:
            kc, vc = upd_cache(kc, k), upd_cache(vc, v)
            o = decode_attention(q, kc, vc, cache["length"] + 1)
            out_caches = (kc, vc)
        x = x + o.reshape(B, 1, -1) @ lp["wo"]
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe:
            mo, _ = moe_layer(h, lp, cfg.moe)
        else:
            mo = swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
        return x + mo, out_caches

    if quantized:
        x, (ks, vs, kss, vss) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        new_cache = {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss,
                     "length": cache["length"] + 1}
    else:
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"]))
        new_cache = {"k": ks, "v": vs, "length": cache["length"] + 1}
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return logits, new_cache
