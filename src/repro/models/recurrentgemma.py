"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU + local attention, 1:2.

Block pattern repeats (recurrent, recurrent, local-attention); 38 layers =
12 full groups + 2 trailing recurrent blocks. The RG-LRU is a gated linear
recurrence evaluated with an associative scan (train/prefill) or a single
state update (decode) — sub-quadratic, so this arch runs long_500k. Local
attention is MQA (kv=1) with a 2048 sliding window, so its decode cache is
window-bounded, not seq_len-bounded.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import (apply_rope, attention, decode_attention, gather_seq,
                     geglu, rms_norm, shard_seq)

RG_LRU_C = 8.0

# Pooled-serving slot layout (see serving/engine.py _write_slot).  NOTE the
# grouped recurrent states carry batch at axis 2 — (G, 2, batch, ...) — which
# the seed engine's fixed axis-1 assumption silently corrupted; declaring the
# axes here is what makes pooled slot writes correct for this family.
CACHE_BATCH_AXES = {"conv_g": 2, "lru_g": 2, "k": 1, "v": 1,
                    "conv_t": 1, "lru_t": 1, "length": 0}


@dataclasses.dataclass(frozen=True)
class RGConfig:
    name: str
    n_layers: int                  # total blocks (38)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    window: int = 2048
    conv_width: int = 4
    remat: bool = True
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    attn_impl: str = "auto"           # auto | xla | pallas (flash policy)

    @property
    def dh(self) -> int:
        return self.d_model // self.n_heads

    @property
    def lru_width(self) -> int:
        return self.d_model

    @property
    def n_groups(self) -> int:
        return self.n_layers // 3

    @property
    def n_tail_rec(self) -> int:
        return self.n_layers - 3 * self.n_groups

    def param_count(self) -> int:
        D, W, F = self.d_model, self.lru_width, self.d_ff
        H, Kv, Dh = self.n_heads, self.n_kv_heads, self.dh
        rec = 2 * D * W + self.conv_width * W + 2 * W * W + W + W * D + 2 * D
        attn = D * H * Dh + 2 * D * Kv * Dh + H * Dh * D + 2 * D
        mlp = 3 * D * F
        n_rec = 2 * self.n_groups + self.n_tail_rec
        n_attn = self.n_groups
        return (n_rec * (rec + mlp) + n_attn * (attn + mlp)
                + 2 * self.vocab * D + D)


def _init_rec(cfg: RGConfig, key, n: int, dt):
    D, W = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 8)

    def nrm(k, shape, scale=0.02):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    return {
        "ln1": jnp.ones((n, D), dt),
        "ln2": jnp.ones((n, D), dt),
        "w_x": nrm(ks[0], (n, D, W)),          # branch into conv + LRU
        "w_y": nrm(ks[1], (n, D, W)),          # gate branch (GeLU)
        "conv_w": nrm(ks[2], (n, cfg.conv_width, W), 0.2),
        "w_a": nrm(ks[3], (n, W, W)),          # recurrence gate
        "w_i": nrm(ks[4], (n, W, W)),          # input gate
        "lam": jnp.full((n, W), 2.0, jnp.float32),   # Lambda (pre-softplus)
        "w_out": nrm(ks[5], (n, W, D)),
        "mlp_gate": nrm(ks[6], (n, D, cfg.d_ff)),
        "mlp_up": nrm(ks[7], (n, D, cfg.d_ff)),
        "mlp_down": nrm(jax.random.fold_in(key, 99), (n, cfg.d_ff, D)),
    }


def _init_attn(cfg: RGConfig, key, n: int, dt):
    D, H, Kv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    ks = jax.random.split(key, 8)

    def nrm(k, shape, scale=0.02):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    return {
        "ln1": jnp.ones((n, D), dt),
        "ln2": jnp.ones((n, D), dt),
        "wq": nrm(ks[0], (n, D, H * Dh)),
        "wk": nrm(ks[1], (n, D, Kv * Dh)),
        "wv": nrm(ks[2], (n, D, Kv * Dh)),
        "wo": nrm(ks[3], (n, H * Dh, D)),
        "mlp_gate": nrm(ks[4], (n, D, cfg.d_ff)),
        "mlp_up": nrm(ks[5], (n, D, cfg.d_ff)),
        "mlp_down": nrm(ks[6], (n, cfg.d_ff, D)),
    }


def init_params(cfg: RGConfig, key: jax.Array) -> dict:
    dt = cfg.dtype
    ks = jax.random.split(key, 6)
    G, Tr = cfg.n_groups, cfg.n_tail_rec
    rec = _init_rec(cfg, ks[0], 2 * G, dt)
    rec_groups = jax.tree.map(
        lambda a: a.reshape((G, 2) + a.shape[1:]), rec)
    params = {
        "embed": (jax.random.normal(ks[1], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "rec_groups": rec_groups,
        "attn_groups": _init_attn(cfg, ks[2], G, dt),
        "rec_tail": _init_rec(cfg, ks[3], Tr, dt) if Tr else None,
        "ln_f": jnp.ones((cfg.d_model,), dt),
        "lm_head": (jax.random.normal(ks[4], (cfg.d_model, cfg.vocab),
                                      jnp.float32) * 0.02).astype(dt),
    }
    return params


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _rg_lru_scan(x, r, i, lam):
    """x, r, i: (B, L, W); lam: (W,). h_t = a_t h_{t-1} + sqrt(1-a_t^2) i x."""
    log_a = -RG_LRU_C * jax.nn.softplus(lam) * r          # (B, L, W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 0.0)) * (i * x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h


def _rec_mixer(cfg: RGConfig, lp, x, conv_state=None, lru_state=None,
               single_step=False):
    """Griffin recurrent block mixer. x: (B, L, D)."""
    B, L, D = x.shape
    W = cfg.lru_width
    u = x @ lp["w_x"]                                  # (B, L, W)
    gate = jax.nn.gelu((x @ lp["w_y"]).astype(jnp.float32))
    conv_w = lp["conv_w"].astype(jnp.float32)          # (cw, W)

    if single_step:
        win = jnp.concatenate([conv_state, u.astype(jnp.float32)], axis=1)
        new_conv = win[:, 1:]
        u = (win * conv_w[None]).sum(1)[:, None]       # (B, 1, W)
    else:
        pad = jnp.zeros((B, cfg.conv_width - 1, W), jnp.float32)
        seq = jnp.concatenate([pad, u.astype(jnp.float32)], axis=1)
        u = sum(seq[:, j:j + L] * conv_w[j][None, None]
                for j in range(cfg.conv_width))
        new_conv = seq[:, L:]

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", uf,
                                  lp["w_a"].astype(jnp.float32)))
    ig = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", uf,
                                   lp["w_i"].astype(jnp.float32)))
    lam = lp["lam"].astype(jnp.float32)

    if single_step:
        log_a = -RG_LRU_C * jax.nn.softplus(lam) * r[:, 0]
        a = jnp.exp(log_a)
        h = a * lru_state + jnp.sqrt(jnp.clip(1 - a * a, 0.0)) * \
            (ig[:, 0] * uf[:, 0])
        new_lru = h
        h = h[:, None]
    else:
        h = _rg_lru_scan(uf, r, ig, lam)
        new_lru = h[:, -1]

    out = (h * gate).astype(cfg.dtype) @ lp["w_out"]
    return out, new_conv, new_lru


def _attn_mixer(cfg: RGConfig, lp, x, positions, kc=None, vc=None,
                lengths=None, single_step=False):
    B, L, D = x.shape
    H, Kv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = (x @ lp["wq"]).reshape(B, L, H, Dh)
    k = (x @ lp["wk"]).reshape(B, L, Kv, Dh)
    v = (x @ lp["wv"]).reshape(B, L, Kv, Dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if single_step:
        slots = lengths % kc.shape[1]                  # per-slot ring write
        upd = jax.vmap(lambda cb, nb, p: jax.lax.dynamic_update_slice(
            cb, nb.astype(cb.dtype), (p, 0, 0)))
        kc = upd(kc, k, slots)
        vc = upd(vc, v, slots)
        o = decode_attention(q, kc, vc,
                             jnp.minimum(lengths + 1, kc.shape[1]))
    else:
        o = attention(q, k, v, causal=True, window=cfg.window,
                      impl=cfg.attn_impl)
    out = o.reshape(B, L, H * Dh) @ lp["wo"]
    return out, kc, vc


def _rec_block(cfg, lp, x, *args, **kw):
    h = gather_seq(rms_norm(x, lp["ln1"], cfg.norm_eps))
    o, conv_s, lru_s = _rec_mixer(cfg, lp, h, *args, **kw)
    x = x + o
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + geglu(h, lp["mlp_gate"], lp["mlp_up"], lp["mlp_down"])
    return x, conv_s, lru_s


def _attn_block(cfg, lp, x, positions, **kw):
    h = gather_seq(rms_norm(x, lp["ln1"], cfg.norm_eps))
    o, kc, vc = _attn_mixer(cfg, lp, h, positions, **kw)
    x = x + o
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + geglu(h, lp["mlp_gate"], lp["mlp_up"], lp["mlp_down"])
    return x, kc, vc


def forward(cfg: RGConfig, params: dict, tokens: jax.Array,
            vision_embeds=None):
    x = params["embed"][tokens]
    B, L = tokens.shape
    positions = jnp.arange(L)[None, :].astype(jnp.int32)

    def group(x, gp):
        rec2, attnp = gp
        for j in range(2):
            lp = jax.tree.map(lambda a: a[j], rec2)
            x, _, _ = _rec_block(cfg, lp, x)
        x, _, _ = _attn_block(cfg, attnp, x, positions)
        return shard_seq(x), None

    def tail(x, lp):
        x, _, _ = _rec_block(cfg, lp, x)
        return shard_seq(x), None

    if cfg.remat:
        group = jax.checkpoint(
            group, policy=jax.checkpoint_policies.nothing_saveable)
        tail = jax.checkpoint(
            tail, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(group, x,
                        (params["rec_groups"], params["attn_groups"]))
    if params["rec_tail"] is not None:
        x, _ = jax.lax.scan(tail, x, params["rec_tail"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x @ params["lm_head"], 0.0


# ---------------------------------------------------------------------------
# Serving: window-bounded attention caches + O(1) recurrent state.
# ---------------------------------------------------------------------------

def init_cache(cfg: RGConfig, batch: int, max_len: int,
               kv_dtype: Any = None) -> dict:
    kv_dtype = kv_dtype or cfg.dtype
    G, Tr, W = cfg.n_groups, cfg.n_tail_rec, cfg.lru_width
    wlen = min(cfg.window, max_len)
    return {
        "conv_g": jnp.zeros((G, 2, batch, cfg.conv_width - 1, W), jnp.float32),
        "lru_g": jnp.zeros((G, 2, batch, W), jnp.float32),
        "k": jnp.zeros((G, batch, wlen, cfg.n_kv_heads, cfg.dh), kv_dtype),
        "v": jnp.zeros((G, batch, wlen, cfg.n_kv_heads, cfg.dh), kv_dtype),
        "conv_t": jnp.zeros((Tr, batch, cfg.conv_width - 1, W), jnp.float32),
        "lru_t": jnp.zeros((Tr, batch, W), jnp.float32),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(cfg: RGConfig, params: dict, tokens: jax.Array, cache: dict):
    x = params["embed"][tokens]
    B = x.shape[0]
    positions = cache["length"][:, None].astype(jnp.int32)

    def group(x, inp):
        gp, conv2, lru2, kc, vc = inp
        rec2, attnp = gp
        new_conv, new_lru = [], []
        for j in range(2):
            lp = jax.tree.map(lambda a: a[j], rec2)
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            o, cs, ls = _rec_mixer(cfg, lp, h, conv2[j], lru2[j],
                                   single_step=True)
            x = x + o
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + geglu(h, lp["mlp_gate"], lp["mlp_up"], lp["mlp_down"])
            new_conv.append(cs)
            new_lru.append(ls)
        x, kc, vc = _attn_block(cfg, attnp, x, positions, kc=kc, vc=vc,
                                lengths=cache["length"], single_step=True)
        return x, (jnp.stack(new_conv), jnp.stack(new_lru), kc, vc)

    x, (convs, lrus, ks, vs) = jax.lax.scan(
        group, x,
        ((params["rec_groups"], params["attn_groups"]),
         cache["conv_g"], cache["lru_g"], cache["k"], cache["v"]))

    conv_t, lru_t = cache["conv_t"], cache["lru_t"]
    if params["rec_tail"] is not None:
        def tail(x, inp):
            lp, cs0, ls0 = inp
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            o, cs, ls = _rec_mixer(cfg, lp, h, cs0, ls0, single_step=True)
            x = x + o
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + geglu(h, lp["mlp_gate"], lp["mlp_up"], lp["mlp_down"])
            return x, (cs, ls)
        x, (conv_t, lru_t) = jax.lax.scan(
            tail, x, (params["rec_tail"], cache["conv_t"], cache["lru_t"]))

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    new_cache = {"conv_g": convs, "lru_g": lrus, "k": ks, "v": vs,
                 "conv_t": conv_t, "lru_t": lru_t,
                 "length": cache["length"] + 1}
    return logits, new_cache


def prefill(cfg: RGConfig, params: dict, tokens: jax.Array, cache: dict,
            vision_embeds=None):
    """Prefill via forward + state extraction (simplified: recompute final
    states; window cache filled with the last `window` keys)."""
    x = params["embed"][tokens]
    B, L = tokens.shape
    positions = jnp.arange(L)[None, :].astype(jnp.int32)
    wlen = cache["k"].shape[2]

    def group(x, gp):
        rec2, attnp = gp
        convs, lrus = [], []
        for j in range(2):
            lp = jax.tree.map(lambda a: a[j], rec2)
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            o, cs, ls = _rec_mixer(cfg, lp, h)
            x = x + o
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + geglu(h, lp["mlp_gate"], lp["mlp_up"], lp["mlp_down"])
            convs.append(cs)
            lrus.append(ls)
        h = rms_norm(x, attnp["ln1"], cfg.norm_eps)
        H, Kv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
        q = (h @ attnp["wq"]).reshape(B, L, H, Dh)
        k = (h @ attnp["wk"]).reshape(B, L, Kv, Dh)
        v = (h @ attnp["wv"]).reshape(B, L, Kv, Dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = attention(q, k, v, causal=True, window=cfg.window,
                      impl=cfg.attn_impl)
        x = x + o.reshape(B, L, H * Dh) @ attnp["wo"]
        h = rms_norm(x, attnp["ln2"], cfg.norm_eps)
        x = x + geglu(h, attnp["mlp_gate"], attnp["mlp_up"],
                      attnp["mlp_down"])
        # scatter the last `wlen` keys into their ring slots (pos % wlen) so
        # decode_step's ring writes/masks stay consistent.
        take = min(L, wlen)
        slots = (jnp.arange(take) + max(0, L - take)) % wlen
        kw = jnp.zeros((B, wlen) + k.shape[2:], cache["k"].dtype)
        vw = jnp.zeros((B, wlen) + v.shape[2:], cache["v"].dtype)
        kw = kw.at[:, slots].set(k[:, -take:].astype(cache["k"].dtype))
        vw = vw.at[:, slots].set(v[:, -take:].astype(cache["v"].dtype))
        return x, (jnp.stack(convs), jnp.stack(lrus), kw, vw)

    x, (convs, lrus, ks, vs) = jax.lax.scan(
        group, x, (params["rec_groups"], params["attn_groups"]))

    conv_t, lru_t = cache["conv_t"], cache["lru_t"]
    if params["rec_tail"] is not None:
        def tail(x, lp):
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            o, cs, ls = _rec_mixer(cfg, lp, h)
            x = x + o
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + geglu(h, lp["mlp_gate"], lp["mlp_up"], lp["mlp_down"])
            return x, (cs, ls)
        x, (conv_t, lru_t) = jax.lax.scan(tail, x, params["rec_tail"])

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x[:, -1:] @ params["lm_head"]
    new_cache = {"conv_g": convs, "lru_g": lrus, "k": ks, "v": vs,
                 "conv_t": conv_t, "lru_t": lru_t,
                 "length": jnp.full((B,), L, jnp.int32)}
    return logits, new_cache
