"""Model zoo: decoder-only transformers (dense/MoE/VLM backbone), Mamba-2
SSD, RecurrentGemma hybrid, Whisper enc-dec. Pure functions over stacked-
layer param dicts (jax.lax.scan) for compact HLO at dry-run scale."""
from . import layers, mamba2, recurrentgemma, transformer, whisper
from .layers import MoEConfig
from .mamba2 import Mamba2Config
from .recurrentgemma import RGConfig
from .transformer import TransformerConfig
from .whisper import WhisperConfig

__all__ = [
    "layers", "mamba2", "recurrentgemma", "transformer", "whisper",
    "MoEConfig", "Mamba2Config", "RGConfig", "TransformerConfig",
    "WhisperConfig",
]
