"""Shared neural layers (pure functions over param dicts).

Conventions:
  * params are plain dicts of jnp arrays; layer stacks carry a leading layer
    axis and are traversed with jax.lax.scan (compact HLO for the dry-run).
  * activations default to bf16; params bf16; accumulations f32.
  * attention is grouped (GQA) and supports qk-norm, qkv-bias, causal and
    sliding-window masks. The XLA path is used for lowering/dry-run (CPU
    container); the Pallas flash kernels (repro.kernels) are the TPU path,
    selected via ``impl='pallas'``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.runtime import compat


def shard_seq(x: jax.Array) -> jax.Array:
    """Megatron-style sequence parallelism at layer boundaries.

    Shards the sequence dim of (B, S, D) activations over the `model` axis
    so the per-layer residuals saved for backward shrink by the TP degree
    (the TP all-gather that follows is traffic the block pays anyway).
    No-op outside a mesh context or when S does not divide."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or getattr(mesh, "empty", False):
        return x
    if "model" not in mesh.axis_names:
        return x
    m = mesh.shape["model"]
    if m == 1 or x.ndim != 3 or x.shape[1] % m != 0:
        return x
    UC = jax.sharding.PartitionSpec.UNCONSTRAINED
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(UC, "model", UC))


def gather_seq(x: jax.Array) -> jax.Array:
    """Inverse of shard_seq: all-gather the sequence dim at block entry so
    the mixer (attention/SSD/LRU) computes on the full sequence — GSPMD
    emits exactly one all-gather here and one reduce-scatter at the residual
    add (the Megatron-SP schedule), instead of resharding inside the
    attention scans."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or getattr(mesh, "empty", False):
        return x
    if "model" not in mesh.axis_names or mesh.shape["model"] == 1:
        return x
    if x.ndim != 3:
        return x
    UC = jax.sharding.PartitionSpec.UNCONSTRAINED
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(UC, None, UC))


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) +
            bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 1e6) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, dh/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA) — XLA paths for lowering; Pallas kernels are the TPU path.
# ---------------------------------------------------------------------------

def _flash_mode(S: int, Sk: int, override: str | None = None) -> str:
    """Resolve the attention engine ('pallas' trainable kernel | 'xla')
    for one call.  The policy lives in ``configs.base`` (explicit
    override > REPRO_FLASH_ATTN env > default); imported lazily to keep
    the configs<->models import order acyclic."""
    from repro.configs import base as cbase
    pol = cbase.flash_attn_policy(override)
    return cbase.decide_flash(pol, seq_len=S, kv_len=Sk,
                              on_tpu=jax.default_backend() == "tpu")


def _flash_pallas(q, k, v, *, causal, window):
    """Dispatch to the trainable fused Pallas kernel (custom-VJP fwd+bwd,
    pruned grid).  Under a data-parallel mesh the call shard_maps over the
    batch axes (attention has no cross-batch terms, so batch sharding is
    exact); a mesh with a live ``model`` axis returns None — the
    ring/replicated context-parallel paths own those shapes."""
    from repro.kernels import ops as kops

    def call(q, k, v):
        o = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window)
        return o.transpose(0, 2, 1, 3)

    mesh = compat.get_abstract_mesh()
    if mesh is None or getattr(mesh, "empty", False):
        return call(q, k, v)
    try:
        if mesh._are_all_axes_manual:    # already inside a shard_map
            return call(q, k, v)
    except AttributeError:
        pass
    if "model" in mesh.axis_names and mesh.shape["model"] > 1:
        return None
    from jax.sharding import PartitionSpec as P

    from repro.parallel.ring_attention import data_axes_spec
    dspec = data_axes_spec(mesh, q.shape[0])
    if dspec is None:
        return None
    sp = P(dspec, None, None, None)
    fn = compat.shard_map(call, mesh=mesh, in_specs=(sp, sp, sp),
                          out_specs=sp)
    return fn(q, k, v)


def _ring_mode(S: int, m: int, override: str | None = None) -> str:
    """Resolve the context-parallel mode ('ring' | 'replicated' | 'off')
    for a global sequence of S on an m-wide model axis.  The policy lives
    in ``configs.base`` (explicit override > REPRO_RING_ATTN env >
    default); imported lazily to keep the configs<->models import order
    acyclic."""
    from repro.configs import base as cbase
    return cbase.decide_ring(cbase.ring_attn_policy(override),
                             seq_len=S, ring_size=m)


def _grouped_scores_full(q, k, v, *, causal, window, q_offset=0):
    """Full-mask attention. q: (B, S, H, Dh); k/v: (B, Sk, Hkv, Dh)."""
    B, S, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(Dh)
    qpos = q_offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((S, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, Dh).astype(q.dtype)


def _grouped_scores_chunked(q, k, v, *, causal, window, chunk: int = 1024,
                            q_offset=0):
    """Online-softmax scan over kv chunks for ONE q block (flash inner loop).

    q: (B, Sq, H, Dh) with global position offset q_offset (may be traced).
    The (Sq, Sk) score matrix is never materialized.
    """
    B, S, H, Dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    assert Sk % chunk == 0, (Sk, chunk)
    # keep q/k/v in model dtype (bf16): the MXU dots accumulate in f32 via
    # preferred_element_type, and the p tensor is stored bf16 like the flash
    # kernel — this halves the attention HBM traffic vs f32 intermediates
    # (§Perf iteration C2/A1).
    qg = q.reshape(B, S, Hkv, G, Dh)
    scale = 1.0 / math.sqrt(Dh)
    n_chunks = Sk // chunk
    kc = k.reshape(B, n_chunks, chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    qpos = q_offset + jnp.arange(S)[:, None]

    def step(carry, inp):
        m, l, acc = carry
        ci, kb, vb = inp
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        mask = jnp.ones((S, chunk), bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(v.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    # inside shard_map the carries must carry the same varying-manual
    # axes as the data they will be combined with
    m0 = compat.match_vma(jnp.full((B, Hkv, G, S), -1e30, jnp.float32), qg)
    l0 = compat.match_vma(jnp.zeros((B, Hkv, G, S), jnp.float32), qg)
    a0 = compat.match_vma(jnp.zeros((B, Hkv, G, S, Dh), jnp.float32), qg)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    o = acc / jnp.where(l == 0, 1.0, l)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh).astype(q.dtype)


def _attention_blocked(q, k, v, *, causal, window, q_chunk=2048,
                       k_chunk=4096, base_offset=0, use_constraints=True):
    """Flash-style double blocking in pure XLA: outer scan over q blocks,
    inner online-softmax scan over kv blocks. Peak temp is one
    (q_chunk x k_chunk) tile per (batch, head) instead of the full S x Sk
    score matrix — this is what makes 32k-seq cells lowerable (and is the
    same schedule as kernels/attention.py, whose Pallas version is the
    real-TPU path).

    base_offset: global position of q row 0 (traced OK) — used by the
    ring/shard_map path where each device holds a sequence slice."""
    B, S, H, Dh = q.shape
    q_chunk = min(q_chunk, S)
    while S % q_chunk:          # largest block size that divides S
        q_chunk -= 1
    Sk = k.shape[1]
    k_chunk = min(k_chunk, Sk)
    while Sk % k_chunk:
        k_chunk -= 1
    nq = S // q_chunk
    qb = q.reshape(B, nq, q_chunk, H, Dh).transpose(1, 0, 2, 3, 4)
    if use_constraints:
        qb = _shard_qblocks(qb)

    def qstep(_, inp):
        qi, qblk = inp
        o = _grouped_scores_chunked(
            qblk, k, v, causal=causal, window=window,
            chunk=k_chunk, q_offset=base_offset + qi * q_chunk)
        return None, o

    # without this, scan-of-scans backward saves every inner-chunk residual
    # — i.e. the full S x Sk score matrix in f32, just distributed. With it,
    # backward recomputes one q-block at a time (flash-style).
    qstep = jax.checkpoint(
        qstep, policy=jax.checkpoint_policies.nothing_saveable)
    _, os = jax.lax.scan(qstep, None, (jnp.arange(nq), qb))
    return os.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dh)


def _attention_ring(q, k, v, *, causal, window, ring: str | None = None):
    """Context-parallel attention over the `model` mesh axis.

    Two schedules behind one policy (``configs.base.ring_attn_policy``;
    ``ring`` overrides the mode for this call):

    * ``ring`` — the ppermute ring (§Perf B6, the paper's FIFO mesh):
      k/v stay SEQUENCE-SHARDED and hop neighbour-to-neighbour while each
      device folds the visiting shard into its rows' online softmax.  The
      memory-flat custom VJP in ``parallel.ring_attention`` (backward
      recomputes each hop's score tile; dk/dv accumulators circulate with
      the shards) is what lets this be the DEFAULT long-sequence path.
    * ``replicated`` — the §Perf B5 shard_map: q sequence-sharded against
      replicated k/v; shard_map AD transposes the replicated k/v into ONE
      psum of dk/dv per layer.  The XLA fallback below the ring's
      sequence threshold.

    Returns None when inapplicable (no mesh / indivisible shapes / mode
    'off') so the caller can fall back to the constraint-based layout."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or getattr(mesh, "empty", False):
        return None
    if "model" not in mesh.axis_names or mesh.shape["model"] == 1:
        return None
    try:
        if mesh._are_all_axes_manual:    # already inside a shard_map
            return None
    except AttributeError:
        pass
    m = mesh.shape["model"]
    B, S, H, Dh = q.shape
    if S % m != 0 or k.shape[1] != S:
        return None
    from repro.parallel.ring_attention import data_axes_spec, ring_attention

    mode = _ring_mode(S, m, ring)
    if mode == "off":
        return None
    if mode == "ring":
        out = ring_attention(q, k, v, causal=causal, window=window,
                             mesh=mesh)
        if out is not None:
            return out

    dspec = data_axes_spec(mesh, B)
    from jax.sharding import PartitionSpec as P

    def body(q_l, k_l, v_l):
        off = jax.lax.axis_index("model") * q_l.shape[1]
        return _attention_blocked(q_l, k_l, v_l, causal=causal,
                                  window=window, base_offset=off,
                                  use_constraints=False)

    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(dspec, "model", None, None),
                  P(dspec, None, None, None),
                  P(dspec, None, None, None)),
        out_specs=P(dspec, "model", None, None),
    )
    return fn(q, k, v)


def _shard_attn_inputs(q, k, v):
    """Context-parallel attention layout (§Perf iteration C3).

    Without a constraint, GSPMD shards the CONTRACTING Dh dim of the score
    einsums whenever the head count doesn't divide the model axis (24 or 40
    heads on a 16-wide mesh) and emits a partial-sum all-reduce of the score
    tensor per kv-chunk step — hundreds of GB/device. Instead: shard q's
    SEQUENCE over `model` and replicate k/v (k/v are kv-heads-only, a few
    hundred MB) — every device computes its own q rows, no sharded
    contractions, attention traffic drops by the TP degree."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or getattr(mesh, "empty", False):
        return q, k, v
    if "model" not in mesh.axis_names or mesh.shape["model"] == 1:
        return q, k, v
    UC = jax.sharding.PartitionSpec.UNCONSTRAINED
    P = jax.sharding.PartitionSpec
    k = jax.lax.with_sharding_constraint(k, P(UC, None, None, None))
    v = jax.lax.with_sharding_constraint(v, P(UC, None, None, None))
    return q, k, v


def _shard_qblocks(qb):
    """Shard the q-chunk rows of the blocked layout (nq, B, qc, H, Dh) over
    `model` — the constraint must live on the POST-reshape tensor or GSPMD
    re-replicates every scan step (§Perf iteration C3')."""
    mesh = compat.get_abstract_mesh()
    if mesh is None or getattr(mesh, "empty", False):
        return qb
    if "model" not in mesh.axis_names or mesh.shape["model"] == 1:
        return qb
    if qb.shape[2] % mesh.shape["model"] != 0:
        return qb
    UC = jax.sharding.PartitionSpec.UNCONSTRAINED
    P = jax.sharding.PartitionSpec
    return jax.lax.with_sharding_constraint(
        qb, P(None, UC, "model", None, None))


def attention(q, k, v, *, causal=True, window=None, impl=None,
              full_threshold: int = 2048, q_offset: int = 0,
              ring: str | None = None):
    """Dispatch: the trainable fused Pallas kernel when the flash policy
    picks it (TPU auto / forced — the DEFAULT training path on real
    hardware), else full-mask XLA for short seqs and context-parallel
    shard_map (ppermute ring / replicated k/v, per the ring policy) or
    double-blocked flash-style scan for long ones.  ``impl`` overrides
    the flash policy ('pallas' | 'xla'; None/'auto' resolves via
    REPRO_FLASH_ATTN); ``ring`` overrides the ring-policy mode."""
    if impl in (None, "auto", "pallas", "xla"):
        mode = _flash_mode(q.shape[1], k.shape[1],
                           None if impl in (None, "auto") else impl)
    else:
        raise ValueError(f"attention impl {impl!r} not in "
                         "(None, 'auto', 'pallas', 'xla')")
    # the kernel wrapper masks in local positions; offset callers (chunked
    # q against a longer kv) stay on the XLA paths, which honor q_offset
    offset_free = isinstance(q_offset, int) and q_offset == 0
    if mode == "pallas" and offset_free:
        out = _flash_pallas(q, k, v, causal=causal, window=window)
        if out is not None:
            return out
    if max(q.shape[1], k.shape[1]) > full_threshold:
        out = _attention_ring(q, k, v, causal=causal, window=window,
                              ring=ring)
        if out is not None:
            return out
        q, k, v = _shard_attn_inputs(q, k, v)
        return _attention_blocked(q, k, v, causal=causal, window=window)
    q, k, v = _shard_attn_inputs(q, k, v)
    return _grouped_scores_full(q, k, v, causal=causal, window=window,
                                q_offset=q_offset)


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric int8 KV quantization.

    x: (..., Dh) -> (int8 same shape, f32 scale (...,)). Halves decode-cache
    HBM vs bf16 — what lets the 32B-param decode_32k cell fit a v5e pod."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def decode_attention(q, k_cache, v_cache, lengths, k_scale=None,
                     v_scale=None, chunk: int = 4096):
    """One-token attention against a cache. q: (B, 1, H, Dh);
    caches: (B, S, Hkv, Dh) (bf16, or int8 + (B, S, Hkv) scales);
    lengths: (B,).

    Long caches process in chunks with an online softmax so quantized
    caches dequantize ONE chunk at a time — the full-cache f32 dequant temp
    was the qwen1.5-32b decode_32k capacity blocker (§Perf next-steps)."""
    B, _, H, Dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    scale = 1.0 / math.sqrt(Dh)

    def dense(kc, vc, pos0):
        kcf = dequantize_kv(kc, k_scale) if k_scale is not None and \
            kc.dtype == jnp.int8 else kc
        vcf = dequantize_kv(vc, v_scale) if v_scale is not None and \
            vc.dtype == jnp.int8 else vc
        s = jnp.einsum("bkgd,bskd->bkgs", qg, kcf.astype(jnp.float32)) * scale
        mask = (pos0 + jnp.arange(kc.shape[1]))[None, :] < lengths[:, None]
        s = jnp.where(mask[:, None, None, :], s, -1e30)
        return s, vcf

    if S <= chunk or S % chunk != 0:
        s, vcf = dense(k_cache, v_cache, 0)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bskd->bkgd", p, vcf.astype(jnp.float32))
        return o.reshape(B, 1, H, Dh).astype(q.dtype)

    n = S // chunk

    def resh(a, trail):
        return a.reshape((B, n, chunk) + trail).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(trail))))

    kc = resh(k_cache, (Hkv, Dh))
    vc = resh(v_cache, (Hkv, Dh))
    ks = resh(k_scale, (Hkv,)) if k_scale is not None else None
    vs = resh(v_scale, (Hkv,)) if v_scale is not None else None

    def step(carry, inp):
        m, l, acc = carry
        if ks is not None:
            ci, kb, vb, ksb, vsb = inp
            kb = dequantize_kv(kb, ksb)
            vb = dequantize_kv(vb, vsb)
        else:
            ci, kb, vb = inp
        s = jnp.einsum("bkgd,bskd->bkgs", qg,
                       kb.astype(jnp.float32)) * scale
        mask = (ci * chunk + jnp.arange(chunk))[None, :] < lengths[:, None]
        s = jnp.where(mask[:, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgs,bskd->bkgd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    init = (jnp.full((B, Hkv, G), -1e30, jnp.float32),
            jnp.zeros((B, Hkv, G), jnp.float32),
            jnp.zeros((B, Hkv, G, Dh), jnp.float32))
    xs = (jnp.arange(n), kc, vc) if ks is None else \
        (jnp.arange(n), kc, vc, ks, vs)
    (m, l, acc), _ = jax.lax.scan(step, init, xs)
    o = acc / jnp.where(l == 0, 1.0, l)[..., None]
    return o.reshape(B, 1, H, Dh).astype(q.dtype)


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths,
                           k_scale=None, v_scale=None, *, impl="xla"):
    """Attention of freshly written tokens against a paged KV pool.

    q: (B, T, H, Dh) — token t of row b sits at position ``lengths[b] + t``
    and its K/V have already been scattered into the pool, so it attends
    every position <= its own.  k_pages/v_pages: (P, page, Hkv, Dh) ONE
    layer's global pool; page_table: (B, max_pages) physical ids (0 =
    trash, always masked by the position bound); lengths: (B,) tokens
    cached BEFORE this step's writes.  Scales (int8 pools): (P, page,
    Hkv) f32.

    ``impl='pallas'`` (T == 1 only) dispatches to the paged flash-decode
    kernel, which chases the page table inside the grid — no gathered
    contiguous cache ever materializes.  The XLA path gathers the mapped
    pages (bounded by the page-table slice the engine passes, NOT by
    max_len) and runs a masked softmax; it is the CPU/equivalence path."""
    B, T, H, Dh = q.shape
    P, page, Hkv, _ = k_pages.shape
    if impl in (None, "auto"):
        # decode q is one token; the flash policy's min-seq threshold is a
        # prefill knob, so auto here is purely a backend question
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    from repro.kernels.ops import _record_dispatch
    _record_dispatch("paged_decode_attention",
                     impl=impl if (impl == "pallas" and T == 1) else "xla",
                     t=T, page_size=page, pages=P)
    if impl == "pallas" and T == 1:
        from repro.kernels import ops as kops
        o = kops.paged_flash_decode(q[:, 0], k_pages, v_pages, page_table,
                                    lengths + 1, k_scale, v_scale)
        return o[:, None].astype(q.dtype)
    G = H // Hkv
    S = page_table.shape[1] * page
    scale = 1.0 / math.sqrt(Dh)

    def gather(pages, scales):
        x = pages[page_table].astype(jnp.float32)  # (B, MP, page, Hkv, D)
        if scales is not None:
            x = x * scales[page_table][..., None]
        return x.reshape(B, S, Hkv, Dh)

    k = gather(k_pages, k_scale)
    v = gather(v_pages, v_scale)
    qg = q.reshape(B, T, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k) * scale
    limit = lengths[:, None] + jnp.arange(T)[None, :]          # (B, T)
    mask = jnp.arange(S)[None, None, :] <= limit[:, :, None]   # (B, T, S)
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskd->btkgd", p, v)
    return o.reshape(B, T, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def geglu(x, w_gate, w_up, w_down):
    h = jax.nn.gelu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    return jax.nn.gelu(x @ w_in + b_in) @ w_out + b_out


# ---------------------------------------------------------------------------
# Mixture of Experts (token-choice top-k, capacity-bounded dispatch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden
    capacity_factor: float = 1.25


def _moe_route(xt, router, K):
    """Shared routing math. xt: (T, D) -> gate_vals/gate_idx (T, K), probs."""
    logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    return gate_vals, gate_idx, probs


def _moe_aux(probs, gate_idx, E, T, K):
    me = probs.mean(0)
    ce = jnp.bincount(gate_idx.reshape(-1), length=E).astype(jnp.float32) / \
        (T * K)
    return E * jnp.sum(me * ce)


def _moe_local(x, params, cfg: MoEConfig):
    """Single-device / data-local MoE: capacity-bounded scatter dispatch.

    Used directly on small meshes and as the per-shard body of the TP mode;
    capacities here are LOCAL token counts, so buffers stay per-device-sized.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)
    gate_vals, gate_idx, probs = _moe_route(xt, params["router"], K)

    C = max(1, int(cfg.capacity_factor * T * K / E))
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)        # (T, K, E)
    flat = onehot.reshape(T * K, E)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(T, K, E)
    pos = (pos_in_expert * onehot).sum(-1)                       # (T, K)
    keep = pos < C
    gate_vals = gate_vals * keep

    disp = jnp.zeros((E, C, D), x.dtype)
    e_idx = gate_idx.reshape(-1)
    c_idx = jnp.where(keep.reshape(-1), pos.reshape(-1), 0)
    t_idx = jnp.repeat(jnp.arange(T), K)
    contrib = jnp.where(keep.reshape(-1)[:, None], xt[t_idx], 0)
    disp = disp.at[e_idx, c_idx].add(contrib)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", disp, params["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", disp, params["w_up"])
    eo = jnp.einsum("ecf,efd->ecd", h, params["w_down"])         # (E, C, D)

    gathered = eo[e_idx, c_idx].astype(jnp.float32) * \
        gate_vals.reshape(-1)[:, None]
    out = jnp.zeros((T, D), jnp.float32).at[t_idx].add(gathered)
    aux = _moe_aux(probs, gate_idx, E, T, K)
    return out.reshape(B, S, D).astype(x.dtype), aux


def _moe_ep_body(x, router, wg, wu, wd, *, cfg: MoEConfig, n_groups: int,
                 model_axis: str):
    """Expert-parallel shard body: experts live on `model`-axis devices;
    tokens travel to their experts over all-to-all (and back).

    Deterministic slot layout: the send buffer to destination group g is
    e_per blocks of c slots (one block per expert owned by g), so after the
    all-to-all a reshape+transpose lines tokens up per local expert — no
    second dispatch pass.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    e_per = E // n_groups
    T = B * S
    xt = x.reshape(T, D)
    gate_vals, gate_idx, probs = _moe_route(xt, router, K)

    c = max(1, int(cfg.capacity_factor * T * K / E))   # per-expert capacity
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)        # (T, K, E)
    flat = onehot.reshape(T * K, E)
    pos = ((jnp.cumsum(flat, axis=0) - flat).reshape(T, K, E)
           * onehot).sum(-1)                                     # (T, K)
    keep = pos < c
    gate_vals = gate_vals * keep

    grp = gate_idx // e_per                                      # (T, K)
    eloc = gate_idx % e_per
    slot = eloc * c + jnp.where(keep, pos, 0)                    # within group
    send = jnp.zeros((n_groups, e_per * c, D), x.dtype)
    t_idx = jnp.repeat(jnp.arange(T), K)
    contrib = jnp.where(keep.reshape(-1)[:, None], xt[t_idx], 0)
    send = send.at[grp.reshape(-1), slot.reshape(-1)].add(contrib)

    # FIFO-mesh moment: tokens hop to their expert's device and back.
    recv = jax.lax.all_to_all(send, model_axis, split_axis=0,
                              concat_axis=0, tiled=True)
    # (n_groups(src), e_per * c, D) -> (e_per, n_groups * c, D)
    recv = recv.reshape(n_groups, e_per, c, D).transpose(1, 0, 2, 3) \
        .reshape(e_per, n_groups * c, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, wg)) * \
        jnp.einsum("ecd,edf->ecf", recv, wu)
    eo = jnp.einsum("ecf,efd->ecd", h, wd)         # (e_per, n_groups*c, D)

    back = eo.reshape(e_per, n_groups, c, D).transpose(1, 0, 2, 3) \
        .reshape(n_groups, e_per * c, D)
    back = jax.lax.all_to_all(back, model_axis, split_axis=0,
                              concat_axis=0, tiled=True)

    gathered = back[grp.reshape(-1), slot.reshape(-1)].astype(jnp.float32) * \
        gate_vals.reshape(-1)[:, None]
    out = jnp.zeros((T, D), jnp.float32).at[t_idx].add(gathered)
    aux = _moe_aux(probs, gate_idx, E, T, K)
    return out.reshape(B, S, D).astype(x.dtype), aux


def moe_layer(x: jax.Array, params: dict[str, jax.Array],
              cfg: MoEConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D); params: router (D, E), w_gate/w_up (E, D, F),
    w_down (E, F, D). Returns (out, aux_loss).

    Distribution dispatch:
      * no mesh (tests)                    -> local capacity dispatch
      * E divisible by the model axis     -> expert parallelism (shard_map +
        all-to-all; capacities are per-device, so buffers never scale with
        the global batch)
      * otherwise (e.g. granite's 40e/16) -> TP-in-expert: every device
        keeps all experts with 1/16 of each FFN, tokens stay put, psum after
        w_down.
    """
    mesh = compat.get_abstract_mesh()
    if (mesh is None or getattr(mesh, "empty", False)
            or "model" not in getattr(mesh, "axis_names", ())
            or mesh.shape["model"] == 1):
        return _moe_local(x, params, cfg)

    from jax.sharding import PartitionSpec as P
    msize = mesh.shape["model"]
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dspec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    E = cfg.n_experts
    lp = {k: params[k] for k in ("router", "w_gate", "w_up", "w_down")}

    if E % msize == 0:
        import functools
        body = functools.partial(_moe_ep_body, cfg=cfg, n_groups=msize,
                                 model_axis="model")
        # Tokens enter SEQUENCE-sharded over `model` (the SP boundary
        # layout): each device routes its own S/msize slice — exact FLOPs.
        # Decode (S=1) can't split the sequence; tokens are then replicated
        # over `model` and the duplicate compute de-duplicated by psum/m
        # (MoE decode FLOPs are negligible).
        seq_split = x.shape[1] % msize == 0
        x_spec = P(dspec, "model", None) if seq_split else P(dspec, None,
                                                             None)

        def wrapped(x, router, wg, wu, wd):
            out, aux = body(x, router, wg, wu, wd)
            axes = daxes + ("model",)
            if not seq_split:
                out = jax.lax.psum(out, "model") / msize
                aux = compat.pcast(aux, ("model",), to="varying")
            n = 1
            for a in axes:
                n *= jax.lax.psum(1, a)
            return out, jax.lax.psum(aux, axes) / n

        fn = compat.shard_map(
            wrapped, mesh=mesh,
            in_specs=(x_spec, P(None, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=(x_spec, P()),
        )
        return fn(x, lp["router"], lp["w_gate"], lp["w_up"], lp["w_down"])

    # Non-divisible expert count (granite's 40e on a 16-wide axis).
    seq_split = x.shape[1] % msize == 0
    axes_all = daxes + ("model",)

    if seq_split:
        # Token-split over `model`: each device routes/computes its own
        # S/msize tokens against (temporarily gathered) full expert weights
        # — dispatch buffers shrink by msize and router compute de-dupes.
        def split_body(x, router, wg, wu, wd):
            out, aux = _moe_local(x, {"router": router, "w_gate": wg,
                                      "w_up": wu, "w_down": wd}, cfg)
            n = 1
            for a in axes_all:
                n *= jax.lax.psum(1, a)
            return out, jax.lax.psum(aux, axes_all) / n

        fn = compat.shard_map(
            split_body, mesh=mesh,
            in_specs=(P(dspec, "model", None), P(None, None),
                      P(None, None, None), P(None, None, None),
                      P(None, None, None)),
            out_specs=(P(dspec, "model", None), P()),
        )
        return fn(x, lp["router"], lp["w_gate"], lp["w_up"], lp["w_down"])

    # Decode fallback: tokens replicated over `model`, per-expert FFN dim
    # sharded (TP-in-expert), psum after the down-projection.
    def tp_body(x, router, wg, wu, wd):
        out, aux = _moe_local(x, {"router": router, "w_gate": wg,
                                  "w_up": wu, "w_down": wd}, cfg)
        out = jax.lax.psum(out, "model")
        aux = compat.pcast(aux, ("model",), to="varying")
        n = 1
        for a in axes_all:
            n *= jax.lax.psum(1, a)
        return out, jax.lax.psum(aux, axes_all) / n

    fn = compat.shard_map(
        tp_body, mesh=mesh,
        in_specs=(P(dspec, None, None), P(None, None),
                  P(None, None, "model"), P(None, None, "model"),
                  P(None, "model", None)),
        out_specs=(P(dspec, None, None), P()),
    )
    return fn(x, lp["router"], lp["w_gate"], lp["w_up"], lp["w_down"])
