"""Whisper-medium style encoder-decoder (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, S_enc, D) directly to the encoder.
Encoder: bidirectional MHA + GELU MLP, sinusoidal positions. Decoder: causal
self-attention + cross-attention over encoder output, learned positions,
tied output embedding. LayerNorm (with bias) throughout, pre-norm.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import (attention, decode_attention, gather_seq, gelu_mlp,
                     layer_norm, shard_seq)

# Pooled-serving slot layout (see serving/engine.py _write_slot): batch axis
# of every cache entry, including the encoder cross-attention K/V.
CACHE_BATCH_AXES = {"k": 1, "v": 1, "xk": 1, "xv": 1, "length": 0}


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str
    n_layers: int            # per stack (24 enc + 24 dec)
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_audio_ctx: int = 1500
    max_text_ctx: int = 448
    remat: bool = True
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    attn_impl: str = "auto"           # auto | xla | pallas (flash policy)

    @property
    def dh(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        D, F, L = self.d_model, self.d_ff, self.n_layers
        attn = 4 * D * D
        mlp = 2 * D * F + D + F
        enc = L * (attn + mlp + 4 * D)
        dec = L * (2 * attn + mlp + 6 * D)
        return enc + dec + self.vocab * D + self.max_text_ctx * D + 4 * D


def _sinusoidal(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / (d // 2 - 1)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _attn_params(key, n, D, dt):
    ks = jax.random.split(key, 4)

    def nrm(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * 0.02).astype(dt)

    return {
        "wq": nrm(ks[0], (n, D, D)), "bq": jnp.zeros((n, D), dt),
        "wk": nrm(ks[1], (n, D, D)),
        "wv": nrm(ks[2], (n, D, D)), "bv": jnp.zeros((n, D), dt),
        "wo": nrm(ks[3], (n, D, D)), "bo": jnp.zeros((n, D), dt),
    }


def init_params(cfg: WhisperConfig, key: jax.Array) -> dict:
    D, F, L, dt = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.dtype
    ks = jax.random.split(key, 12)

    def nrm(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * 0.02).astype(dt)

    def ln(n):
        return jnp.ones((n, D), dt), jnp.zeros((n, D), dt)

    enc = {"attn": _attn_params(ks[0], L, D, dt)}
    enc["ln1_w"], enc["ln1_b"] = ln(L)
    enc["ln2_w"], enc["ln2_b"] = ln(L)
    enc["mlp_w1"] = nrm(ks[1], (L, D, F))
    enc["mlp_b1"] = jnp.zeros((L, F), dt)
    enc["mlp_w2"] = nrm(ks[2], (L, F, D))
    enc["mlp_b2"] = jnp.zeros((L, D), dt)

    dec = {"self": _attn_params(ks[3], L, D, dt),
           "cross": _attn_params(ks[4], L, D, dt)}
    dec["ln1_w"], dec["ln1_b"] = ln(L)
    dec["ln2_w"], dec["ln2_b"] = ln(L)
    dec["ln3_w"], dec["ln3_b"] = ln(L)
    dec["mlp_w1"] = nrm(ks[5], (L, D, F))
    dec["mlp_b1"] = jnp.zeros((L, F), dt)
    dec["mlp_w2"] = nrm(ks[6], (L, F, D))
    dec["mlp_b2"] = jnp.zeros((L, D), dt)

    return {
        "embed": nrm(ks[7], (cfg.vocab, D)),
        "pos_dec": nrm(ks[8], (cfg.max_text_ctx, D)),
        "enc": enc,
        "dec": dec,
        "ln_enc_w": jnp.ones((D,), dt), "ln_enc_b": jnp.zeros((D,), dt),
        "ln_dec_w": jnp.ones((D,), dt), "ln_dec_b": jnp.zeros((D,), dt),
    }


def _mha(cfg, lp, xq, xkv, *, causal, impl, prefix=""):
    B, S, D = xq.shape
    H, Dh = cfg.n_heads, cfg.dh
    q = (xq @ lp["wq"] + lp["bq"]).reshape(B, S, H, Dh)
    k = (xkv @ lp["wk"]).reshape(B, xkv.shape[1], H, Dh)
    v = (xkv @ lp["wv"] + lp["bv"]).reshape(B, xkv.shape[1], H, Dh)
    o = attention(q, k, v, causal=causal, window=None, impl=impl)
    return o.reshape(B, S, D) @ lp["wo"] + lp["bo"], k, v


def encode(cfg: WhisperConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, D) precomputed embeddings (stub frontend)."""
    x = frames.astype(cfg.dtype) + _sinusoidal(
        frames.shape[1], cfg.d_model).astype(cfg.dtype)[None]
    enc = params["enc"]

    def body(x, lp):
        h = gather_seq(layer_norm(x, lp["ln1_w"], lp["ln1_b"], cfg.norm_eps))
        o, _, _ = _mha(cfg, lp["attn"], h, h, causal=False,
                       impl=cfg.attn_impl)
        x = x + o
        h = layer_norm(x, lp["ln2_w"], lp["ln2_b"], cfg.norm_eps)
        x = x + gelu_mlp(h, lp["mlp_w1"], lp["mlp_b1"], lp["mlp_w2"],
                         lp["mlp_b2"])
        return shard_seq(x), None

    stacked = {"attn": enc["attn"],
               **{k: v for k, v in enc.items() if k != "attn"}}
    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, stacked)
    return layer_norm(x, params["ln_enc_w"], params["ln_enc_b"], cfg.norm_eps)


def forward(cfg: WhisperConfig, params: dict, tokens: jax.Array,
            frames: jax.Array):
    """Teacher-forced training step: (tokens (B, S_dec), frames (B, S_enc, D))
    -> logits (B, S_dec, vocab)."""
    enc_out = encode(cfg, params, frames)
    B, S = tokens.shape
    pos = params["pos_dec"]
    pe = pos[jnp.arange(S) % pos.shape[0]]
    x = params["embed"][tokens] + pe[None]
    dec = params["dec"]

    def body(x, lp):
        h = gather_seq(layer_norm(x, lp["ln1_w"], lp["ln1_b"], cfg.norm_eps))
        o, _, _ = _mha(cfg, lp["self"], h, h, causal=True,
                       impl=cfg.attn_impl)
        x = x + o
        h = layer_norm(x, lp["ln2_w"], lp["ln2_b"], cfg.norm_eps)
        o, _, _ = _mha(cfg, lp["cross"], h, enc_out, causal=False,
                       impl=cfg.attn_impl)
        x = x + o
        h = layer_norm(x, lp["ln3_w"], lp["ln3_b"], cfg.norm_eps)
        x = x + gelu_mlp(h, lp["mlp_w1"], lp["mlp_b1"], lp["mlp_w2"],
                         lp["mlp_b2"])
        return shard_seq(x), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, dec)
    x = layer_norm(x, params["ln_dec_w"], params["ln_dec_b"], cfg.norm_eps)
    logits = x @ params["embed"].T          # tied output embedding
    return logits, 0.0


def init_cache(cfg: WhisperConfig, batch: int, max_len: int,
               kv_dtype: Any = None) -> dict:
    kv_dtype = kv_dtype or cfg.dtype
    L, H, Dh = cfg.n_layers, cfg.n_heads, cfg.dh
    return {
        "k": jnp.zeros((L, batch, max_len, H, Dh), kv_dtype),
        "v": jnp.zeros((L, batch, max_len, H, Dh), kv_dtype),
        "xk": jnp.zeros((L, batch, cfg.n_audio_ctx, H, Dh), kv_dtype),
        "xv": jnp.zeros((L, batch, cfg.n_audio_ctx, H, Dh), kv_dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg: WhisperConfig, params: dict, tokens: jax.Array, cache: dict,
            frames: jax.Array):
    """Encode audio, precompute cross K/V, run the prompt through the
    decoder. Returns (last-token logits, cache)."""
    enc_out = encode(cfg, params, frames)
    B, S = tokens.shape
    pe = params["pos_dec"][jnp.arange(S) % params["pos_dec"].shape[0]]
    x = params["embed"][tokens] + pe[None]
    H, Dh = cfg.n_heads, cfg.dh

    def body(x, lp):
        h = layer_norm(x, lp["ln1_w"], lp["ln1_b"], cfg.norm_eps)
        o, k, v = _mha(cfg, lp["self"], h, h, causal=True,
                       impl=cfg.attn_impl)
        x = x + o
        h = layer_norm(x, lp["ln2_w"], lp["ln2_b"], cfg.norm_eps)
        o, xk, xv = _mha(cfg, lp["cross"], h, enc_out, causal=False,
                         impl=cfg.attn_impl)
        x = x + o
        h = layer_norm(x, lp["ln3_w"], lp["ln3_b"], cfg.norm_eps)
        x = x + gelu_mlp(h, lp["mlp_w1"], lp["mlp_b1"], lp["mlp_w2"],
                         lp["mlp_b2"])
        return x, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec"])
    kv_dt = cache["k"].dtype
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], ks.astype(kv_dt), (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], vs.astype(kv_dt), (0, 0, 0, 0, 0)),
        "xk": xks.astype(kv_dt),
        "xv": xvs.astype(kv_dt),
        "length": jnp.full((B,), S, jnp.int32),
    }
    x = layer_norm(x, params["ln_dec_w"], params["ln_dec_b"], cfg.norm_eps)
    return x[:, -1:] @ params["embed"].T, cache


def decode_step(cfg: WhisperConfig, params: dict, tokens: jax.Array,
                cache: dict):
    B = tokens.shape[0]
    pe = params["pos_dec"][cache["length"] % params["pos_dec"].shape[0]]
    x = params["embed"][tokens] + pe[:, None]

    def upd_cache(c, new):
        return jax.vmap(
            lambda cb, nb, p: jax.lax.dynamic_update_slice(
                cb, nb.astype(cb.dtype), (p, 0, 0))
        )(c, new, cache["length"])

    def body(x, inp):
        lp, kc, vc, xk, xv = inp
        h = layer_norm(x, lp["ln1_w"], lp["ln1_b"], cfg.norm_eps)
        H, Dh = cfg.n_heads, cfg.dh
        q = (h @ lp["self"]["wq"] + lp["self"]["bq"]).reshape(B, 1, H, Dh)
        k = (h @ lp["self"]["wk"]).reshape(B, 1, H, Dh)
        v = (h @ lp["self"]["wv"] + lp["self"]["bv"]).reshape(B, 1, H, Dh)
        kc = upd_cache(kc, k)
        vc = upd_cache(vc, v)
        o = decode_attention(q, kc, vc, cache["length"] + 1)
        x = x + o.reshape(B, 1, -1) @ lp["self"]["wo"] + lp["self"]["bo"]
        h = layer_norm(x, lp["ln2_w"], lp["ln2_b"], cfg.norm_eps)
        q = (h @ lp["cross"]["wq"] + lp["cross"]["bq"]).reshape(B, 1, H, Dh)
        lens = jnp.full((B,), xk.shape[1], jnp.int32)
        o = decode_attention(q, xk, xv, lens)
        x = x + o.reshape(B, 1, -1) @ lp["cross"]["wo"] + lp["cross"]["bo"]
        h = layer_norm(x, lp["ln3_w"], lp["ln3_b"], cfg.norm_eps)
        x = x + gelu_mlp(h, lp["mlp_w1"], lp["mlp_b1"], lp["mlp_w2"],
                         lp["mlp_b2"])
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec"], cache["k"], cache["v"], cache["xk"],
                  cache["xv"]))
    cache = dict(cache, k=ks, v=vs, length=cache["length"] + 1)
    x = layer_norm(x, params["ln_dec_w"], params["ln_dec_b"], cfg.norm_eps)
    return x @ params["embed"].T, cache
