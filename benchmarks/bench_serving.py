"""Serving-engine benchmark: dense vs paged vs paged-int8 KV.

For one smoke arch and one mixed-length request trace, serves the SAME
trace through each KV mode and reports per-engine throughput and memory:

  * ``us_per_call``   — microseconds per generated token (decode + its
    share of prefill);
  * ``tok_s``         — end-to-end generated tokens/sec;
  * ``kv_peak_mb``    — peak resident KV bytes.  Dense reserves
    ``batch x max_len`` up front; the paged pool's page accounting tracks
    the tokens actually cached, so this column is where the block pool
    earns its keep (and the int8 pool halves it again).

The acceptance row pair: ``serve_paged`` must be >= ``serve_dense`` in
tokens/sec at equal slot count, with kv_peak_mb scaling with the actual
sequence lengths.

Each engine first serves the ENTIRE trace once unmeasured: decoding is
greedy and deterministic, so the warm pass visits exactly the jit shapes
(prompt buckets AND power-of-two page-table views) the timed pass will —
the timed run measures steady serving, not tracing.

Run directly: ``PYTHONPATH=src python benchmarks/bench_serving.py``
(``--smoke`` shrinks the trace for CI).
"""
import argparse
import time

import numpy as np

ARCH = "qwen3-4b"
SLOTS = 4
MAX_LEN = 256
PAGE = 16


LENGTHS = (8, 12, 24, 48)


def _trace(vocab: int, n_requests: int, seed: int = 0):
    """Mixed-length prompt trace (short chats + a few long contexts)."""
    rng = np.random.default_rng(seed)
    lens = rng.choice(LENGTHS, size=n_requests, p=[0.4, 0.3, 0.2, 0.1])
    return [rng.integers(0, vocab, int(n)).astype(np.int32) for n in lens]


def _serve(kv_mode: str, n_requests: int, max_new: int):
    from repro.launch.serve import build_engine
    num_pages = None
    if kv_mode != "dense":
        # pool sized to the trace's real need (plus slack), NOT to
        # batch x max_len — the whole point of paging
        per_req = -(-(48 + max_new) // PAGE) + 1
        num_pages = SLOTS * per_req + 4
    engine, vocab = build_engine(
        ARCH, slots=SLOTS, max_len=MAX_LEN, max_new=max_new,
        kv_mode=kv_mode, page_size=PAGE, num_pages=num_pages)
    # warm pass: serve the exact timed trace twice — greedy decoding is
    # deterministic, so this compiles every prompt bucket and pow2
    # page-table view the timed pass will touch.  Two iterations because
    # the paged engines' radix prefix cache changes the admission path
    # once the trie is warm (suffix-only prefill + COW page copies): the
    # first pass compiles the cold shapes and populates the trie, the
    # second compiles the cache-hit shapes the timed pass will replay.
    prompts = _trace(vocab, n_requests)
    for _ in range(2):
        for p in prompts:
            engine.submit(p)
        engine.run()
    warm_tokens = sum(len(v) for v in engine.results.values())
    for p in prompts:
        engine.submit(p)
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(v) for v in engine.results.values()) - warm_tokens
    stats = engine.kv_stats()
    return {
        "tokens": tokens,
        "tok_s": tokens / dt,
        "us_per_tok": dt / tokens * 1e6,
        "kv_peak_mb": stats["peak_bytes"] / 1e6,
        "evictions": stats.get("evictions", 0),
    }


def main(csv=True, n_requests: int = 12, max_new: int = 16,
         smoke: bool = False):
    if smoke:
        n_requests, max_new = 4, 6
    rows = []
    dense = _serve("dense", n_requests, max_new)
    for mode in ("dense", "paged", "paged_int8"):
        r = dense if mode == "dense" else _serve(mode, n_requests, max_new)
        speed = r["tok_s"] / dense["tok_s"]
        rows.append((f"serve_{mode}", r["us_per_tok"],
                     f"tok_s={r['tok_s']:.1f};kv_peak_mb="
                     f"{r['kv_peak_mb']:.3f};x_dense={speed:.2f}"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
    else:
        for name, us, derived in rows:
            print(f"{name:24s} {us:10.0f} us/tok   {derived}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (fewer requests, shorter decode)")
    a = ap.parse_args()
    main(csv=True, smoke=a.smoke)
