# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows for: Table III (traffic + perf), Fig. 3 (classic rooflines),
# Fig. 4 (exclusive workloads), the Pallas kernel micro-bench, the
# attention engine comparison (xla vs blocked vs trainable Pallas, fwd and
# fwd+bwd, plus the causal grid-pruning win), the scheduler-engine
# micro-bench, the serving-engine KV-mode comparison, the ring-attention
# fwd/bwd table (§Perf B6) and the model-zoo dry-run + end-to-end tables.
#
# ``--smoke`` runs the CI-sized variant of every bench that has one (and
# skips the slow kernel sweep); ``--json-out PATH`` additionally writes the
# collected rows as JSON — CI uploads that file (BENCH_smoke.json) as a
# workflow artifact so the perf trajectory is tracked per PR.
import argparse
import inspect
import io
import json
import os
import sys
from contextlib import redirect_stdout

# make `from benchmarks import ...` work when invoked as a script path
# (python benchmarks/run.py) and not only as `python -m benchmarks.run`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def _collect(mod, **kwargs) -> list[str]:
    """Run one bench module's main(csv=True, ...) and return its CSV rows,
    passing only the kwargs its signature accepts (not every bench has a
    smoke mode)."""
    params = inspect.signature(mod.main).parameters
    kwargs = {k: v for k, v in kwargs.items() if k in params}
    buf = io.StringIO()
    with redirect_stdout(buf):
        mod.main(csv=True, **kwargs)
    return [line for line in buf.getvalue().splitlines()
            if line and not line.startswith("name,")]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: smoke variants, skip the kernel "
                         "sweep")
    ap.add_argument("--json-out", default=None,
                    help="also write the rows as JSON (perf-trajectory "
                         "artifact)")
    args = ap.parse_args(argv)

    # Persist scheduler searches under .cache/ so repeated benchmark runs
    # start warm (see repro/core/autotune.py; delete .cache/ to reset).
    os.environ.setdefault("REPRO_SCHED_DISK_CACHE", "1")
    from benchmarks import (bench_attention, bench_dryrun, bench_fault,
                            bench_fleet_serving, bench_kernels, bench_ring,
                            bench_roofline_fig3, bench_roofline_fig4,
                            bench_scheduler, bench_serving, bench_table3,
                            bench_traffic)
    mods = [bench_scheduler, bench_table3, bench_roofline_fig3,
            bench_roofline_fig4, bench_kernels, bench_attention,
            bench_serving, bench_fleet_serving, bench_traffic, bench_fault,
            bench_ring, bench_dryrun]
    if args.smoke:
        mods.remove(bench_kernels)   # Pallas interpret sweep: minutes on CPU

    # Per-bench metrics: the global registry (repro.obs) accumulates
    # counters (kernel dispatches, cache tiers, serve outcomes, guard/
    # checkpoint events) as a side effect of running each bench; the delta
    # between snapshots attributes them to the module that caused them.
    from repro.obs import REGISTRY

    def _counters() -> dict:
        return dict(REGISTRY.snapshot().get("counters", {}))

    print("name,us_per_call,derived")
    rows: list[str] = []
    metrics: dict[str, dict] = {}
    for mod in mods:
        kw = {"smoke": args.smoke}
        if args.smoke and mod is bench_scheduler:
            kw["reps"] = 3
        before = _counters()
        for line in _collect(mod, **kw):
            rows.append(line)
            print(line)
        after = _counters()
        delta = {k: v - before.get(k, 0) for k, v in after.items()
                 if v != before.get(k, 0)}
        if delta:
            metrics[mod.__name__.rsplit(".", 1)[-1]] = delta
        sys.stdout.flush()

    if args.json_out:
        parsed = []
        for line in rows:
            name, us, derived = (line.split(",", 2) + ["", ""])[:3]
            try:
                us_f = float(us)
            except ValueError:
                us_f = 0.0
            parsed.append({"name": name, "us_per_call": us_f,
                           "derived": derived})
        with open(args.json_out, "w") as f:
            json.dump({"smoke": args.smoke, "rows": parsed,
                       "metrics": metrics}, f, indent=1)
        print(f"[run] wrote {len(parsed)} rows to {args.json_out}",
              file=sys.stderr)


if __name__ == '__main__':
    main()
