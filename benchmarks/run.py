# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows for: Table III (traffic + perf), Fig. 3 (classic rooflines),
# Fig. 4 (exclusive workloads), the Pallas kernel micro-bench, the
# 40-cell dry-run roofline table, and the scheduler-engine micro-bench.
import io
import os
import sys
from contextlib import redirect_stdout


def main() -> None:
    # Persist scheduler searches under .cache/ so repeated benchmark runs
    # start warm (see repro/core/autotune.py; delete .cache/ to reset).
    os.environ.setdefault("REPRO_SCHED_DISK_CACHE", "1")
    from benchmarks import (bench_dryrun, bench_kernels, bench_roofline_fig3,
                            bench_roofline_fig4, bench_scheduler,
                            bench_serving, bench_table3)
    print("name,us_per_call,derived")
    for mod in (bench_scheduler, bench_table3, bench_roofline_fig3,
                bench_roofline_fig4, bench_kernels, bench_serving,
                bench_dryrun):
        buf = io.StringIO()
        with redirect_stdout(buf):
            mod.main(csv=True)
        for line in buf.getvalue().splitlines():
            if line and not line.startswith("name,"):
                print(line)
        sys.stdout.flush()


if __name__ == '__main__':
    main()
