# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows for: Table III (traffic + perf), Fig. 3 (classic rooflines),
# Fig. 4 (exclusive workloads), the Pallas kernel micro-bench, and the
# 40-cell dry-run roofline table.
import io
import sys
from contextlib import redirect_stdout


def main() -> None:
    from benchmarks import (bench_dryrun, bench_kernels, bench_roofline_fig3,
                            bench_roofline_fig4, bench_table3)
    print("name,us_per_call,derived")
    for mod in (bench_table3, bench_roofline_fig3, bench_roofline_fig4,
                bench_kernels, bench_dryrun):
        buf = io.StringIO()
        with redirect_stdout(buf):
            mod.main(csv=True)
        for line in buf.getvalue().splitlines():
            if line and not line.startswith("name,"):
                print(line)
        sys.stdout.flush()


if __name__ == '__main__':
    main()
