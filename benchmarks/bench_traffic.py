"""Serving traffic benchmark: bursty arrivals against the continuous-
batching engine, with and without the radix prefix cache.

Unlike bench_serving (throughput of a pre-loaded batch), this replays a
synthetic TRAFFIC TRACE through the engine's event-loop API — requests
arrive over time in Poisson bursts (short gaps inside a burst, long lulls
between bursts), prompt lengths are mixed, and a configurable fraction of
requests share a long common prompt prefix (the system-prompt / few-shot
pattern that prefix caching exists for).  Per request it records

  * TTFT — submit to first generated token (the prefix cache's target:
    a cache-hit request prefills only its suffix);
  * TPOT — mean per-token latency after the first token;

and reports p50/p99 of each, plus the cache's effect on the page pool:

  * ``dedup``        — logical pages mapped / physical pages allocated
    ((allocs + shared mappings) / allocs): 1.0 means every mapping paid
    for a private page, 2.0 means half the working set was served from
    shared pages.  At 50% prefix share this is expected >= 2x.
  * ``hit_rate``     — prefix-cache lookups that matched;
  * ``shared_peak``  — most physical pages simultaneously mapped > once.

Rows: ``traffic_<mode>`` (cache on) and ``traffic_nocache`` with
identical traces, us_per_call = TTFT p50.  The derived column carries
``ttft_p99_ms``/``tpot_p50_us``/``tpot_p99_us``/``tok_s`` so the JSON
artifact (run.py --json-out) tracks the latency distribution over time.

Run directly: ``PYTHONPATH=src python benchmarks/bench_traffic.py``
(``--smoke`` shrinks the trace for CI; ``--share 0.3`` varies the
prefix-share ratio).
"""
import argparse
import time
from collections import deque

import numpy as np

ARCH = "qwen3-4b"
SLOTS = 4
MAX_LEN = 128
PAGE = 8
PREFIX_LEN = 96                 # shared preamble: 12 pages at PAGE=8
NUM_PAGES = 97                  # 96 usable: ~1.5x the peak working set


def _trace(vocab: int, n_requests: int, share: float, seed: int = 0):
    """[(arrival_tick, prompt)] — bursts of back-to-back arrivals
    separated by Poisson lulls; ``share`` of the requests (exactly, not in
    expectation) start with the common PREFIX_LEN-token preamble + a short
    unique suffix, the rest are cold prompts with mixed lengths.  The
    FIRST arrival is a prefix-share request followed by a lull — the
    steady-state pattern prefix caching targets (a long-lived system
    prompt warmed by the first request of the day), compressed into a
    short trace."""
    rng = np.random.default_rng(seed)
    common = rng.integers(0, vocab, PREFIX_LEN)
    n_share = round(share * n_requests)
    # sharer slots: the leader + every ceil(n/n_share)-th request after it
    sharers = set(np.linspace(0, n_requests - 1, max(1, n_share),
                              dtype=int).tolist()) if n_share else set()
    out, tick = [], 0
    for i in range(n_requests):
        if i == 1:
            tick += 25                              # leader finishes
        elif i % 4 == 0 and i > 0:                  # burst boundary
            tick += 3 + int(rng.poisson(4.0))       # lull
        else:
            tick += int(rng.poisson(0.4))           # inside a burst
        if i in sharers:
            prompt = np.concatenate(
                [common, rng.integers(0, vocab, int(rng.integers(3, 7)))])
        else:
            prompt = rng.integers(0, vocab, int(rng.integers(8, 25)))
        out.append((tick, prompt.astype(np.int32)))
    return out


def _drive(engine, trace):
    """Replay the trace through submit()/step(), recording per-request
    wall-clock TTFT and completion times."""
    pending = deque(trace)
    meta = {}
    tick = 0
    shared_peak = 0
    while pending or engine.pending():
        while pending and pending[0][0] <= tick:
            _, prompt = pending.popleft()
            rid = engine.submit(prompt)
            meta[rid] = {"t0": time.perf_counter(), "first": None,
                         "done": None, "n": 0}
        engine.step()
        now = time.perf_counter()
        shared_peak = max(shared_peak,
                          engine.kv.stats().get("pages_shared", 0))
        for rid, m in meta.items():
            if m["done"] is not None:
                continue
            done = rid in engine.results
            n = len(engine.results[rid]) if done \
                else len(engine._partial_output(rid))
            if n > 0 and m["first"] is None:
                m["first"] = now
            m["n"] = n
            if done:
                m["done"] = now
        tick += 1
    return meta, shared_peak


def _serve(n_requests: int, max_new: int, share: float, prefix_cache: bool):
    from repro.launch.serve import build_engine
    engine, vocab = build_engine(
        ARCH, slots=SLOTS, max_len=MAX_LEN, max_new=max_new,
        kv_mode="paged", page_size=PAGE, num_pages=NUM_PAGES,
        prefix_cache=prefix_cache)
    trace = _trace(vocab, n_requests, share)
    # warm pass: greedy decode is deterministic, so replaying the same
    # trace visits every jit shape the measured pass needs.  The reset
    # then drops pool/trie/scheduler state but keeps the compiled traces
    # (they key on the bundle) — the timed pass measures a COLD-cache
    # serve (the trie warms in-run, as in production) with zero
    # compilation noise.
    _drive(engine, trace)
    engine.reset_serving_state()
    t0 = time.perf_counter()
    meta, shared_peak = _drive(engine, trace)
    dt = time.perf_counter() - t0
    ttft = np.asarray([m["first"] - m["t0"] for m in meta.values()])
    tpot = np.asarray([(m["done"] - m["first"]) / max(1, m["n"] - 1)
                       for m in meta.values()])
    kst = engine.kv.stats()
    pst = engine.prefix_stats()
    tokens = sum(len(v) for v in engine.results.values())
    return {
        "ttft_p50_ms": float(np.percentile(ttft, 50) * 1e3),
        "ttft_p99_ms": float(np.percentile(ttft, 99) * 1e3),
        "tpot_p50_us": float(np.percentile(tpot, 50) * 1e6),
        "tpot_p99_us": float(np.percentile(tpot, 99) * 1e6),
        "tok_s": tokens / dt,
        # logical page mappings per physical page allocated
        "dedup": (kst["allocs"] + kst["shares"]) / max(1, kst["allocs"]),
        "hit_rate": pst.get("hit_rate", 0.0),
        "matched_tokens": pst.get("matched_tokens", 0),
        "cow": pst.get("cow_copies", 0),
        "shared_peak": shared_peak,
    }


def main(csv=True, n_requests: int = 24, max_new: int = 8,
         share: float = 0.5, smoke: bool = False):
    if smoke:
        n_requests, max_new = 12, 4
    rows = []
    for name, r in (("traffic_prefix", _serve(n_requests, max_new, share,
                                              prefix_cache=True)),
                    ("traffic_nocache", _serve(n_requests, max_new, share,
                                               prefix_cache=False))):
        rows.append((name, r["ttft_p50_ms"] * 1e3,
                     f"ttft_p99_ms={r['ttft_p99_ms']:.1f};"
                     f"tpot_p50_us={r['tpot_p50_us']:.0f};"
                     f"tpot_p99_us={r['tpot_p99_us']:.0f};"
                     f"tok_s={r['tok_s']:.1f};"
                     f"share={share:.2f};"
                     f"dedup={r['dedup']:.2f};"
                     f"hit_rate={r['hit_rate']:.2f};"
                     f"matched_tokens={r['matched_tokens']};"
                     f"cow={r['cow']};"
                     f"shared_peak={r['shared_peak']}"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
    else:
        for name, us, derived in rows:
            print(f"{name:18s} ttft_p50={us/1e3:8.1f} ms   {derived}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (fewer requests, shorter decode)")
    ap.add_argument("--share", type=float, default=0.5,
                    help="fraction of requests sharing the common prefix")
    ap.add_argument("--requests", type=int, default=24)
    a = ap.parse_args()
    main(csv=False, smoke=a.smoke, share=a.share, n_requests=a.requests)
