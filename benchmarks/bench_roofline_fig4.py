"""Paper Fig. 4: VectorMesh-exclusive workloads (modern CNN + spatial
matching) against the roofline."""
from repro.sim import GEMM, MODERN, SPATIAL, simulate, vectormesh


def rows(n_pe=512):
    out = []
    for w in MODERN + SPATIAL + GEMM:
        r = simulate(vectormesh(n_pe), w)
        out.append({"workload": w.name, "family": w.family,
                    "gmacs": round(r.gmacs, 2),
                    "roofline": round(r.roofline_gmacs, 2),
                    "frac": round(r.roofline_frac, 2)})
    return out


def main(csv=True):
    rs = rows()
    if csv:
        print("name,us_per_call,derived")
        for r in rs:
            print(f"fig4_{r['workload']},0,{r['gmacs']}/{r['roofline']} "
                  f"GMAC/s ({r['frac']})")
    # memory-bound layers reach their (low) roofline; compute-bound layers
    # reach a high fraction of peak
    dw = next(r for r in rs if r["workload"] == "MBN_DW_S1")
    assert dw["frac"] > 0.4
    return rs


if __name__ == "__main__":
    main()
