"""Paper Table III: normalized GLB/DRAM access + performance, 128/512 PEs."""
from repro.sim import CLASSIC, eyeriss, simulate, summarize, tpu, vectormesh

PAPER = {  # (norm GLB, norm DRAM, perf GOPS)
    (128, "tpu"): (935, 239, 10), (128, "eyeriss"): (160, 85, 12),
    (128, "vectormesh"): (42, 45, 20),
    (512, "tpu"): (534, 71, 27), (512, "eyeriss"): (55, 28, 41),
    (512, "vectormesh"): (29, 32, 68),
}


def rows():
    out = []
    for n_pe in (128, 512):
        for name, mk in (("tpu", tpu), ("eyeriss", eyeriss),
                         ("vectormesh", vectormesh)):
            s = summarize([simulate(mk(n_pe), w) for w in CLASSIC])
            pg, pd, pp = PAPER[(n_pe, name)]
            out.append({
                "arch": name, "n_pe": n_pe,
                "glb": round(s["norm_glb"], 1), "glb_paper": pg,
                "dram": round(s["norm_dram"], 1), "dram_paper": pd,
                "gmacs": round(s["gmacs"], 1), "gmacs_paper": pp,
                "roofline_frac": round(s["roofline_frac"], 2),
            })
    return out


def main(csv=True):
    rs = rows()
    if csv:
        print("name,us_per_call,derived")
        for r in rs:
            tag = f"table3_{r['arch']}_{r['n_pe']}pe"
            print(f"{tag}_glb,0,{r['glb']} (paper {r['glb_paper']})")
            print(f"{tag}_dram,0,{r['dram']} (paper {r['dram_paper']})")
            print(f"{tag}_gmacs,0,{r['gmacs']} (paper {r['gmacs_paper']})")
    return rs


if __name__ == "__main__":
    main()
