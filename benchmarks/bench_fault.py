"""Fault-tolerance benchmark: what a failure actually costs.

Measures the recovery machinery end to end on a smoke model, reporting
one CSV row per scenario:

  * ``recover_kill``     — wall time from process "death" (chaos kill) to
    the first completed post-restore train step in a fresh process:
    restore + re-shard + data reopen + one step.  ``lost_steps`` is the
    work discarded back to the last checkpoint (the recovery-point
    objective of the checkpoint cadence).
  * ``recover_corrupt``  — same, but the newest checkpoint is corrupted
    on disk, so the restore pays the CRC audit and falls back one
    interval; ``fallback_steps`` is the extra work discarded.
  * ``ckpt_verify``      — the steady-state cost of the CRC audit per
    checkpoint (the tax every restart pays per step dir it inspects).
  * ``recover_kill_proc`` — REAL processes: a 2-worker supervised fleet
    loses one rank to a chaos kill (exit 43); the latency reported is
    failure detection -> backoff -> relaunch, derived from the
    supervisor's own event timestamps.
  * ``restore_striped``  — a 2-worker gang restores the same checkpoint
    with byte-striped reads (each host reads half the shard, the fleet
    exchanges stripes); reports bytes read per host vs the full-read
    baseline, from each worker's metrics counters.

Baseline column ``us_per_call`` is microseconds per recovery (or per
verify).  Run directly:
``PYTHONPATH=src python benchmarks/bench_fault.py --smoke``.
"""
import argparse
import contextlib
import io
import json
import os
import shutil
import tempfile
import time

ARCH = "qwen3-4b"


def _train_kw(steps, **kw):
    base = dict(smoke=True, steps=steps, seq_len=32, global_batch=4,
                log_every=10 ** 6)
    base.update(kw)
    return base


def _quiet(fn, *args, **kw):
    """The train loop narrates restores/faults on stdout; this bench's
    stdout is the CSV channel, so the narration goes to a scratch buffer."""
    with contextlib.redirect_stdout(io.StringIO()):
        return fn(*args, **kw)


def _time_recovery(ckpt_dir, resume_steps):
    """Fresh-process analogue: a new run() against an existing ckpt dir —
    restore, re-shard, reopen data, run ``resume_steps`` steps.  Returns
    (seconds to first completed step, restored step)."""
    from repro.launch.train import run
    t0 = time.perf_counter()
    out = _quiet(run, ARCH, **_train_kw(resume_steps, ckpt_dir=ckpt_dir,
                                        ckpt_every=10 ** 6))
    dt = time.perf_counter() - t0
    return dt, out["steps"][0]


def _bench_kill(steps, ckpt_every):
    from repro.launch.train import run
    from repro.runtime.chaos import ChaosKilled
    work = tempfile.mkdtemp(prefix="bench_fault_kill_")
    try:
        kill_at = steps - 1
        try:
            _quiet(run, ARCH, **_train_kw(steps, ckpt_dir=work,
                                          ckpt_every=ckpt_every,
                                          chaos=[f"kill@{kill_at}"]))
        except ChaosKilled:
            pass
        dt, restored = _time_recovery(work, 1)
        return dt, kill_at - restored
    finally:
        shutil.rmtree(work, ignore_errors=True)


def _bench_corrupt(steps, ckpt_every):
    from repro.launch.train import run
    work = tempfile.mkdtemp(prefix="bench_fault_corrupt_")
    try:
        _quiet(run, ARCH, **_train_kw(steps, ckpt_dir=work,
                                      ckpt_every=ckpt_every,
                                      chaos=[f"corrupt@{steps}"]))
        dt, restored = _time_recovery(work, 1)
        return dt, steps - restored
    finally:
        shutil.rmtree(work, ignore_errors=True)


def _bench_verify(steps, ckpt_every, reps=20):
    from repro.checkpoint import verified_steps
    from repro.launch.train import run
    work = tempfile.mkdtemp(prefix="bench_fault_verify_")
    try:
        _quiet(run, ARCH, **_train_kw(steps, ckpt_dir=work,
                                      ckpt_every=ckpt_every))
        n = len(verified_steps(work))            # warm the page cache
        t0 = time.perf_counter()
        for _ in range(reps):
            verified_steps(work)
        per_audit = (time.perf_counter() - t0) / (reps * max(1, n))
        return per_audit, n
    finally:
        shutil.rmtree(work, ignore_errors=True)


def _run_fleet(fleet_dir, ckpt_dir, *, steps, ckpt_every, nprocs=2,
               chaos=(), striped="never"):
    """Drive a real supervised fleet (subprocess workers) and return the
    supervisor's report."""
    from repro.launch.supervisor import make_cmd_builder
    from repro.runtime.supervisor import RestartPolicy, Supervisor
    ns = argparse.Namespace(arch=ARCH, steps=steps, seq_len=32,
                            global_batch=4, ckpt_every=ckpt_every,
                            ckpt_dir=ckpt_dir, smoke=True, chaos_seed=0,
                            distributed="none")
    policy = RestartPolicy(backoff_base_s=0.05, backoff_max_s=0.2)
    sup = Supervisor(nprocs,
                     make_cmd_builder(ns, fleet_dir, list(chaos), None),
                     fleet_dir=fleet_dir, policy=policy,
                     chaos_specs=list(chaos), ckpt_dir=ckpt_dir,
                     striped_restore=striped)
    return _quiet(sup.run)


def _bench_kill_proc(steps, ckpt_every):
    """Detection->relaunch latency of a real chaos-killed worker, from the
    supervisor's event log (worker_failed rc=43 -> its attempt-2 launch)."""
    work = tempfile.mkdtemp(prefix="bench_fault_killproc_")
    try:
        ckpt = os.path.join(work, "ckpt")
        report = _run_fleet(os.path.join(work, "fleet"), ckpt,
                            steps=steps, ckpt_every=ckpt_every,
                            chaos=[f"kill@{steps - 3}"])
        failed = next(e for e in report["events"]
                      if e["kind"] == "worker_failed" and e["rc"] == 43)
        relaunch = next(e for e in report["events"]
                        if e["kind"] == "launch" and e["attempt"] == 2
                        and e["tag"] == failed["tag"])
        return (relaunch["t"] - failed["t"], report["outcome"],
                report["wall_s"])
    finally:
        shutil.rmtree(work, ignore_errors=True)


def _bench_restore_striped(steps, ckpt_every):
    """Bytes/host of a striped gang restore vs the full shard, from the
    workers' own metrics counters.  Returns (restore_s, striped_bytes,
    full_bytes)."""
    work = tempfile.mkdtemp(prefix="bench_fault_striped_")
    try:
        ckpt = os.path.join(work, "ckpt")
        _run_fleet(os.path.join(work, "seed"), ckpt,
                   steps=steps, ckpt_every=ckpt_every)    # commit a ckpt
        fleet = os.path.join(work, "fleet")
        _run_fleet(fleet, ckpt, steps=steps + ckpt_every,
                   ckpt_every=ckpt_every, striped="always")
        with open(os.path.join(fleet, "metrics_rank0.json")) as f:
            m = json.load(f)
        striped = m["counters"]["checkpoint_read_bytes{mode=striped}"]
        restore_s = m["histograms"]["checkpoint_restore_s"]["mean"]
        from repro.checkpoint import verified_steps
        step = verified_steps(ckpt)[0]
        shard = os.path.join(ckpt, f"step_{step:08d}", "shard_0.npz")
        return restore_s, int(striped), os.path.getsize(shard)
    finally:
        shutil.rmtree(work, ignore_errors=True)


def main(csv=True, smoke: bool = False):
    steps, ckpt_every = (8, 4) if smoke else (20, 5)
    rows = []
    dt, lost = _bench_kill(steps, ckpt_every)
    rows.append(("recover_kill", dt * 1e6,
                 f"recover_s={dt:.2f};lost_steps={lost}"))
    dt, lost = _bench_corrupt(steps, ckpt_every)
    rows.append(("recover_corrupt", dt * 1e6,
                 f"recover_s={dt:.2f};fallback_steps={lost}"))
    per_audit, n = _bench_verify(steps, ckpt_every)
    rows.append(("ckpt_verify", per_audit * 1e6,
                 f"audit_ms={per_audit * 1e3:.2f};n_ckpts={n}"))
    dt, outcome, wall = _bench_kill_proc(steps, ckpt_every)
    rows.append(("recover_kill_proc", dt * 1e6,
                 f"restart_s={dt:.2f};outcome={outcome};"
                 f"fleet_wall_s={wall:.1f}"))
    dt, striped_b, full_b = _bench_restore_striped(steps, ckpt_every)
    rows.append(("restore_striped", dt * 1e6,
                 f"restore_s={dt:.2f};bytes_per_host={striped_b};"
                 f"full_bytes={full_b};"
                 f"saved_pct={100 * (1 - striped_b / full_b):.0f}"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.0f},{derived}")
    else:
        for name, us, derived in rows:
            print(f"{name:18s} {us:12.0f} us   {derived}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer steps, tighter cadence)")
    a = ap.parse_args()
    main(csv=True, smoke=a.smoke)
