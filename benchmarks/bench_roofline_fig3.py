"""Paper Fig. 3: per-workload roofline comparison on classic CNN layers."""
from repro.sim import CLASSIC, eyeriss, simulate, tpu, vectormesh


def rows(n_pe=512):
    out = []
    for w in CLASSIC:
        row = {"workload": w.name}
        for name, mk in (("tpu", tpu), ("eyeriss", eyeriss),
                         ("vectormesh", vectormesh)):
            r = simulate(mk(n_pe), w)
            row[f"{name}_gmacs"] = round(r.gmacs, 2)
            row[f"{name}_frac"] = round(r.roofline_frac, 2)
            row["roofline"] = round(r.roofline_gmacs, 2)
        out.append(row)
    return out


def main(csv=True):
    rs = rows()
    if csv:
        print("name,us_per_call,derived")
        for r in rs:
            print(f"fig3_{r['workload']},0,"
                  f"vm={r['vectormesh_gmacs']}/{r['roofline']} "
                  f"ey={r['eyeriss_gmacs']} tpu={r['tpu_gmacs']}")
    # Fig 3 claim: VectorMesh closest to the roofline on average
    vm = sum(r["vectormesh_frac"] for r in rs) / len(rs)
    ey = sum(r["eyeriss_frac"] for r in rs) / len(rs)
    tp = sum(r["tpu_frac"] for r in rs) / len(rs)
    assert vm >= ey and vm >= tp, (vm, ey, tp)
    return rs


if __name__ == "__main__":
    main()
