"""Scheduler-engine micro-benchmark: µs per tile search / exchange plan.

Times the vectorized + pruned + memoized engine (``repro.core.autotune``)
against the brute-force reference on the four op families, in three modes:

  * ``engine_cold`` — in-process LRU cleared before every call (pure
    vectorize+prune cost, what a first-ever query pays);
  * ``engine_warm`` — repeated query, LRU hit (what the simulator pays for
    every (arch, workload) revisit);
  * ``reference``   — the pre-engine pure-Python lattice scan.

Output rows follow the repo convention ``name,us_per_call,derived``; the
``derived`` column carries the cold/warm speedup over the reference, e.g.
``sched_conv2d_hot_engine_cold,7421,speedup=38.4x`` means one cold engine
search of the ResNet conv layer took 7.4 ms and was 38.4x faster than the
brute force.  The headline acceptance row is ``sched_conv2d_hot_*``:
``conv2d_op(128, 128, 56, 56, 3, 3)`` — the §II-B hot case.

Run directly (``PYTHONPATH=src python benchmarks/bench_scheduler.py``);
pass ``--no-reference`` to skip the slow brute-force timings (the speedup
column then reads ``speedup=n/a``).  ``--reps N`` controls the median-of-N
timing (default 5, reference capped at 3).
"""
import argparse
import statistics
import time

from repro.core import (TEU_BUFFER, attention_scores_op, clear_cache,
                        conv2d_op, correlation_op, matmul_op,
                        order_grid_for_sharing,
                        order_grid_for_sharing_reference, search_tiles,
                        search_tiles_reference)

CASES = [
    ("matmul_1k", lambda: matmul_op(1024, 1024, 1024)),
    ("conv2d_hot", lambda: conv2d_op(128, 128, 56, 56, 3, 3)),
    ("correlation", lambda: correlation_op(9, 9, 32, 32, 64)),
    ("attention", lambda: attention_scores_op(16, 512, 512, 64)),
]


def _median_us(fn, reps: int) -> float:
    ts = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


def rows(reps: int = 5, reference: bool = True):
    # Time the in-memory engine only: the on-disk tier (REPRO_SCHED_DISK_CACHE,
    # enabled by benchmarks/run.py) would turn "cold" into a disk hit.
    import os
    prev = os.environ.get("REPRO_SCHED_DISK_CACHE")
    os.environ["REPRO_SCHED_DISK_CACHE"] = "0"
    try:
        return _rows(reps, reference)
    finally:
        if prev is None:
            del os.environ["REPRO_SCHED_DISK_CACHE"]
        else:
            os.environ["REPRO_SCHED_DISK_CACHE"] = prev


def _rows(reps: int, reference: bool):
    out = []
    for name, mk in CASES:
        op = mk()

        def cold():
            clear_cache()
            search_tiles(op, TEU_BUFFER)

        cold_us = _median_us(cold, reps)
        search_tiles(op, TEU_BUFFER)  # prime
        warm_us = _median_us(lambda: search_tiles(op, TEU_BUFFER), reps)
        ref_us = (_median_us(lambda: search_tiles_reference(op, TEU_BUFFER),
                             min(reps, 3)) if reference else None)
        out.append({"case": name, "engine_cold_us": cold_us,
                    "engine_warm_us": warm_us, "reference_us": ref_us})

        tile = search_tiles(op, TEU_BUFFER).tile
        clear_cache()
        o_cold = _median_us(
            lambda: (clear_cache(), order_grid_for_sharing(op, tile)), reps)
        o_ref = (_median_us(
            lambda: order_grid_for_sharing_reference(op, tile),
            min(reps, 3)) if reference else None)
        out.append({"case": f"{name}_gridorder", "engine_cold_us": o_cold,
                    "engine_warm_us": _median_us(
                        lambda: order_grid_for_sharing(op, tile), reps),
                    "reference_us": o_ref})
    return out


def main(csv=True, reps: int = 5, reference: bool = True):
    # Cache-tier counters accumulated over this run (hits = warm LRU,
    # disk_hits = on-disk tier, misses = full searches, evictions = LRU
    # overflow).  Mirrored into the metrics registry as autotune_cache.*
    # gauges so --json-out snapshots carry them too.
    from repro.core.autotune import (_mirror_stats, cache_stats,
                                     reset_cache_stats)
    reset_cache_stats()
    rs = rows(reps=reps, reference=reference)
    _mirror_stats()
    stats = dict(cache_stats)
    if csv:
        print("name,us_per_call,derived")
        for r in rs:
            ref = r["reference_us"]
            for mode in ("engine_cold", "engine_warm"):
                us = r[f"{mode}_us"]
                sp = f"{ref / us:.1f}x" if ref else "n/a"
                print(f"sched_{r['case']}_{mode},{us:.0f},speedup={sp}")
            if ref:
                print(f"sched_{r['case']}_reference,{ref:.0f},speedup=1.0x")
        derived = ";".join(f"{k}={stats[k]}" for k in sorted(stats))
        print(f"sched_cache_stats,0,{derived}")
    rs.append({"case": "cache_stats", **stats})
    return rs


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--no-reference", action="store_true",
                    help="skip brute-force reference timings")
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args()
    main(reps=args.reps, reference=not args.no_reference)
