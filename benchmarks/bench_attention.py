"""Attention microbench: xla vs blocked vs Pallas trainable, fwd and
fwd+bwd, over a causal / sliding-window / GQA shape sweep — plus the
causal grid-pruning win (scheduled k-blocks and wall time, pruned vs
dense schedule).

On the CPU container the Pallas rows run in INTERPRET mode (an emulator:
per-grid-step jnp dispatch), so their absolute wall time is not the TPU
story — the compiled-Mosaic comparison is a ROADMAP open item.  What IS
backend-independent here: the scheduled-block counts (the pair-table
pruning), the pruned-vs-dense ratio of the SAME kernel, and the
xla-vs-blocked XLA rows.
"""
import time

import numpy as np

import jax
import jax.numpy as jnp


def _time(fn, *args, reps=3):
    out = fn(*args)                       # compile/warm
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _mk(rng, B, S, H, Hkv, Dh, dtype=jnp.float32):
    def arr(s):
        return jnp.asarray(rng.normal(size=s), dtype)
    return arr((B, S, H, Dh)), arr((B, S, Hkv, Dh)), arr((B, S, Hkv, Dh))


def _impl_fns(causal, window):
    """name -> fwd fn over the (B, S, H, Dh) layout."""
    from repro.kernels import ops
    from repro.models import layers

    def xla(q, k, v):
        return layers.attention(q, k, v, causal=causal, window=window,
                                impl="xla")

    def blocked(q, k, v):
        return layers._attention_blocked(q, k, v, causal=causal,
                                         window=window, q_chunk=512,
                                         k_chunk=512)

    def pallas(q, k, v):
        o = ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window)
        return o.transpose(0, 2, 1, 3)

    return {"xla": xla, "blocked": blocked, "pallas": pallas}


def _sweep_rows(rng, cases, reps):
    rows = []
    for tag, (B, S, H, Hkv, Dh, causal, window) in cases.items():
        q, k, v = _mk(rng, B, S, H, Hkv, Dh)
        impls = _impl_fns(causal, window)
        base_fwd = base_bwd = None
        for name, fn in impls.items():
            fwd = jax.jit(fn)
            us_f = _time(fwd, q, k, v, reps=reps)

            bwd = jax.jit(jax.grad(lambda q, k, v, f=fn:
                                   (f(q, k, v).astype(jnp.float32) ** 2)
                                   .sum(), argnums=(0, 1, 2)))
            us_b = _time(bwd, q, k, v, reps=reps)
            if name == "xla":
                base_fwd, base_bwd = us_f, us_b
            rows.append((f"attn_fwd_{name}_{tag}", us_f,
                         f"x_xla {base_fwd / us_f:.2f}"))
            rows.append((f"attn_fwdbwd_{name}_{tag}", us_b,
                         f"x_xla {base_bwd / us_b:.2f}"))
    return rows


def _pruning_rows(rng, S, block, reps):
    """Same Pallas kernel, pruned vs dense pair-table schedule — the
    Eyeriss-v2-style win, measurable even in interpret mode — plus the
    static scheduled-block counts at long S."""
    from repro.kernels import ops
    from repro.kernels.attention import scheduled_block_counts
    rows = []
    B, H, Hkv, Dh = 1, 4, 4, 64
    q, k, v = _mk(rng, B, S, H, Hkv, Dh)

    def run(prune):
        fn = jax.jit(lambda q, k, v: ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, block_q=block,
            block_k=block, prune=prune))
        return _time(fn, q, k, v, reps=reps)

    us_dense = run(False)
    us_pruned = run(True)
    real, dense = scheduled_block_counts(S, S, block_q=block, block_k=block,
                                         causal=True, window=None)
    rows.append((f"attn_prune_causal_S{S}", us_pruned,
                 f"{real}/{dense} blocks sched {dense / real:.2f}x cut "
                 f"wall {us_dense / us_pruned:.2f}x"))
    for Sl, w in ((32768, None), (32768, 4096)):
        r, d = scheduled_block_counts(Sl, Sl, block_q=128, block_k=128,
                                      causal=True, window=w)
        tag = f"S{Sl}" + (f"_w{w}" if w else "")
        rows.append((f"attn_sched_blocks_{tag}", 0.0,
                     f"{r}/{d} blocks {d / r:.2f}x cut"))
    return rows


def main(csv: bool = True, smoke: bool = False, reps: int = 3):
    rng = np.random.default_rng(0)
    if smoke:
        reps = 1
        cases = {
            "S256_causal": (1, 256, 4, 4, 64, True, None),
            "S256_gqa_w64": (1, 256, 8, 2, 64, True, 64),
        }
        prune_S, prune_block = 512, 64
    else:
        cases = {
            "S512_causal": (1, 512, 4, 4, 64, True, None),
            "S2048_causal": (1, 2048, 4, 4, 64, True, None),
            "S2048_gqa": (1, 2048, 8, 2, 64, True, None),
            "S2048_w512": (1, 2048, 4, 4, 64, True, 512),
            "S2048_full": (1, 2048, 4, 4, 64, False, None),
            "S4096_causal": (1, 4096, 4, 4, 64, True, None),
        }
        prune_S, prune_block = 2048, 128
    rows = _sweep_rows(rng, cases, reps)
    rows += _pruning_rows(rng, prune_S, prune_block, reps)
    if csv:
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(smoke=args.smoke)
