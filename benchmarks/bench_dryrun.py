"""Dry-run roofline table: three terms per (arch x shape), single-pod mesh.

Reads results/dryrun/*.json produced by repro.launch.dryrun (re-run any
missing cells with `python -m repro.launch.dryrun`).
"""
import glob
import json
import os

from repro.launch.dryrun import RESULTS_DIR, roofline_from_cell


def rows(mesh="single"):
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            cell = json.load(f)
        if cell.get("status") == "skipped":
            out.append({"arch": cell["arch"], "shape": cell["shape"],
                        "status": "skipped", "reason": cell["reason"]})
            continue
        rep = roofline_from_cell(cell)
        if rep is None:
            out.append({"arch": cell["arch"], "shape": cell["shape"],
                        "status": cell.get("status", "?")})
            continue
        out.append({"status": "ok", **rep.row()})
    return out


def main(csv=True):
    rs = rows()
    if csv:
        print("name,us_per_call,derived")
        for r in rs:
            tag = f"dryrun_{r['arch']}_{r['shape']}"
            if r["status"] != "ok":
                print(f"{tag},0,{r['status']}")
                continue
            dom = r["dominant"]
            t = max(r["t_compute_ms"], r["t_memory_ms"],
                    r["t_collective_ms"])
            print(f"{tag},{t*1e3:.0f},"
                  f"dom={dom} rf={r['roofline_frac']:.2f} "
                  f"useful={r['useful_ratio']:.2f} "
                  f"hbm={r['hbm_gb_per_device']:.1f}GB")
    return rs


if __name__ == "__main__":
    main()
