"""Model-zoo benchmark: dry-run roofline cells + a live end-to-end table.

Two sections, both CSV (``name,us_per_call,derived``):

``dryrun_<arch>_<shape>``
    The three-term roofline rows derived from the 512-virtual-device
    dry-run cells under ``results/dryrun`` (produced by
    ``python -m repro.launch.dryrun``; rows appear only for cells that
    exist — the sweep is too heavy to run inside the benchmark).

``e2e_<arch>``
    Live end-to-end train-step timing for the model zoo: every arch's
    smoke bundle runs REAL steps on an (2 data x 4 model) 8-virtual-
    device host mesh — params sharded by ``parallel.sharding.param_specs``
    exactly like the launcher — and reports wall time per step, tokens/s,
    and the per-device compiled-memory peak (``compat.memory_stats``).
    This is the ROADMAP "benchmark the model zoo end-to-end" table; the
    device count must be fixed before jax initializes, so the rows come
    from a worker subprocess.  ``--smoke`` shrinks to three
    representative archs (dense / MoE / SSM) and a shorter sequence for
    CI.

Run directly: ``PYTHONPATH=src python benchmarks/bench_dryrun.py``
(``--smoke`` for the CI-sized table, ``--no-e2e`` for cells only).
"""
import argparse
import glob
import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

E2E_SMOKE_ARCHS = ("qwen3-4b", "olmoe-1b-7b", "mamba2-370m")
E2E_MESH = (2, 4)                     # (data, model) on 8 host devices


# ---------------------------------------------------------------------------
# section 1: cached dry-run cells -> roofline rows
# ---------------------------------------------------------------------------

def rows(mesh="single"):
    # lazy import: repro.launch.dryrun pins XLA_FLAGS for the 512-device
    # sweep at import time; only the cached-cell section needs it.
    from repro.launch.dryrun import RESULTS_DIR, roofline_from_cell
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            cell = json.load(f)
        if cell.get("status") == "skipped":
            out.append({"arch": cell["arch"], "shape": cell["shape"],
                        "status": "skipped", "reason": cell["reason"]})
            continue
        rep = roofline_from_cell(cell)
        if rep is None:
            out.append({"arch": cell["arch"], "shape": cell["shape"],
                        "status": cell.get("status", "?")})
            continue
        out.append({"status": "ok", **rep.row()})
    return out


# ---------------------------------------------------------------------------
# section 2: live end-to-end steps (worker subprocess, 8 host devices)
# ---------------------------------------------------------------------------

def _e2e_worker(smoke: bool) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import ARCH_IDS, get_bundle
    from repro.optim import adamw_init
    from repro.parallel.sharding import param_specs
    from repro.runtime import compat
    from repro.training import TrainHyper, make_train_step

    archs = E2E_SMOKE_ARCHS if smoke else ARCH_IDS
    B, S = (4, 64) if smoke else (4, 256)
    steps = 2 if smoke else 3
    mesh = compat.make_mesh(E2E_MESH, ("data", "model"))
    key = jax.random.PRNGKey(0)

    for arch in archs:
        bundle = get_bundle(arch, smoke=True)
        cfg = bundle.cfg
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}
        if bundle.kind == "vlm":
            Pv = cfg.vision_tokens
            batch["tokens"] = batch["tokens"][:, :S - Pv]
            batch["labels"] = batch["labels"][:, :S - Pv]
            batch["vision"] = jnp.zeros((B, Pv, cfg.d_model), cfg.dtype)
        if bundle.kind == "audio":
            batch["frames"] = jnp.zeros((B, cfg.n_audio_ctx, cfg.d_model),
                                        cfg.dtype)
        params = bundle.init_params(jax.random.fold_in(key, 1))
        pspecs = param_specs(bundle.kind, params, mesh)
        psh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: isinstance(x, P))
        step = make_train_step(bundle.forward, TrainHyper())
        rep = NamedSharding(mesh, P())
        opt_sh = {"mu": psh, "nu": psh, "step": rep}
        with compat.set_mesh(mesh):
            params = jax.device_put(params, psh)
            opt = jax.device_put(adamw_init(params), opt_sh)
            # pin out_shardings to the input layouts so the compiled step
            # is a fixed point: (params, opt) feed straight back into the
            # AOT executable (jit dispatch would compile a second time)
            jitted = jax.jit(step, out_shardings=(psh, opt_sh, rep))
            t0 = time.perf_counter()
            compiled = jitted.lower(params, opt, batch).compile()
            compile_s = time.perf_counter() - t0
            mem = compat.memory_stats(compiled)
            # every step runs the AOT executable (jit dispatch would
            # re-trace and compile a second time); warm once for buffer
            # setup, then time real steps
            params, opt, m = compiled(params, opt, batch)
            jax.block_until_ready(m["loss"])
            best = float("inf")
            for _ in range(steps):
                t0 = time.perf_counter()
                params, opt, m = compiled(params, opt, batch)
                jax.block_until_ready(m["loss"])
                best = min(best, time.perf_counter() - t0)
        toks = batch["tokens"].shape[0] * batch["tokens"].shape[1]
        print(f"e2e_{arch},{best * 1e6:.0f},"
              f"step_ms={best * 1e3:.1f};tok_s={toks / best:.0f};"
              f"peak_mb_dev={mem['peak_bytes'] / 1e6:.1f};"
              f"compile_s={compile_s:.1f};loss={float(m['loss']):.3f}")


def e2e_rows(smoke: bool = False) -> list:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__), "--e2e-worker"]
    if smoke:
        cmd.append("--smoke")
    p = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=3600)
    if p.returncode != 0:
        raise RuntimeError(f"e2e worker failed:\n{p.stdout}\n{p.stderr}")
    return [ln for ln in p.stdout.splitlines() if ln.startswith("e2e_")]


def main(csv=True, smoke: bool = False, e2e: bool = True):
    rs = rows()
    if csv:
        for r in rs:
            tag = f"dryrun_{r['arch']}_{r['shape']}"
            if r["status"] != "ok":
                print(f"{tag},0,{r['status']}")
                continue
            dom = r["dominant"]
            t = max(r["t_compute_ms"], r["t_memory_ms"],
                    r["t_collective_ms"])
            print(f"{tag},{t*1e3:.0f},"
                  f"dom={dom} rf={r['roofline_frac']:.2f} "
                  f"useful={r['useful_ratio']:.2f} "
                  f"hbm={r['hbm_gb_per_device']:.1f}GB")
    if e2e:
        for line in e2e_rows(smoke=smoke):
            print(line)
    return rs


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized e2e table (3 archs, short sequence)")
    ap.add_argument("--no-e2e", action="store_true",
                    help="cached dry-run cells only")
    ap.add_argument("--e2e-worker", action="store_true",
                    help="internal: run the e2e measurements in THIS "
                         "process (expects 8-device XLA_FLAGS set)")
    a = ap.parse_args()
    if a.e2e_worker:
        _e2e_worker(a.smoke)
    else:
        main(csv=True, smoke=a.smoke, e2e=not a.no_e2e)
