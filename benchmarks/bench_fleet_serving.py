"""Serving-fleet benchmark: what moving a KV page (instead of
re-prefilling it) actually costs, and what a host loss costs the
requests that survive it.

One CSV row per drill on the in-process :class:`~repro.serving.LocalFleet`
(engines share one bundle + params, so every completed request is
token-identical to the single-engine baseline):

  * ``fleet_migrate``   — seeded migration drill: two hosts, round-robin
    placement, arrivals in waves so the second wave's shared prefix is
    OWNED by the other host and must migrate.  ``us_per_call`` is the
    mean wall time of one page migration (export -> CRC frame -> wire ->
    import); derived columns report bytes per migrated page, pages
    moved, and the directory hit rate.
  * ``fleet_host_loss`` — ``die`` chaos mid-serve: ``us_per_call`` is
    wall seconds per completed request; the derived columns report the
    router's recovery latency in fleet ticks (death -> re-admitted
    completion), retries, and tombstoned directory pages.
  * ``fleet_hedge``     — an aggressive hedge deadline twins every slow
    dispatch; derived reports the hedge rate (hedges / requests) and
    that first-writer-wins kept every outcome ``ok``.

Run directly: ``PYTHONPATH=src python benchmarks/bench_fleet_serving.py
--smoke``; ``benchmarks/run.py`` collects the rows into
``BENCH_smoke.json``.
"""
import argparse
import time

import numpy as np

ARCH = "qwen3-4b"
PAGE = 8


def _prompts(vocab, n, *, shared_pages=3, suffix=6, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, vocab, shared_pages * PAGE)
    return [np.concatenate([shared, rng.integers(1, vocab, suffix)])
            .astype(np.int32) for _ in range(n)]


def _mk_fleet(n_hosts, *, chaos=None, **cfg_kw):
    from repro.launch.serve import build_fleet
    from repro.obs import Telemetry
    from repro.obs.metrics import MetricsRegistry
    from repro.serving import FleetConfig
    cfg_kw.setdefault("placement", "round_robin")
    tel = Telemetry(enabled=True, registry=MetricsRegistry())
    fleet, vocab = build_fleet(ARCH, n_hosts, smoke=True, slots=2,
                               max_len=64, max_new=4, kv_mode="paged",
                               page_size=PAGE, chaos=chaos, telemetry=tel,
                               fleet_cfg=FleetConfig(**cfg_kw))
    return fleet, vocab, tel.metrics


def _waves(fleet, prompts, wave=2, settle_ticks=None):
    rids = []
    for i in range(0, len(prompts), wave):
        if rids:
            if settle_ticks is None:
                fleet.run()
            else:
                for _ in range(settle_ticks):
                    fleet.step()
        rids += [fleet.submit(p) for p in prompts[i:i + wave]]
    fleet.run()
    return rids


def _bench_migrate(n_requests):
    fleet, vocab, reg = _mk_fleet(2)
    rids = _waves(fleet, _prompts(vocab, n_requests))
    assert all(fleet.outcomes[r] == "ok" for r in rids)
    st = fleet.stats()
    assert st["migrations"]["ok"] >= 1 and st["page_exchange_bytes"] > 0, \
        "migration drill moved no pages — pages were re-prefilled"
    mig_s = reg.snapshot()["histograms"]["fleet_migration_s"]["mean"]
    return (mig_s,
            st["page_exchange_bytes"] / max(1, st["migrated_pages"]),
            st["migrated_pages"], st["directory"]["hit_rate"])


def _bench_host_loss(n_requests):
    from repro.runtime.chaos import ChaosInjector
    chaos = ChaosInjector([f"die@3:host=0"], seed=0)
    fleet, vocab, reg = _mk_fleet(2, chaos=chaos)
    t0 = time.perf_counter()
    rids = _waves(fleet, _prompts(vocab, n_requests), settle_ticks=2)
    wall = time.perf_counter() - t0
    assert fleet.stats()["deaths"] == 1
    done = sum(fleet.outcomes[r] == "ok" for r in rids)
    hist = reg.snapshot()["histograms"].get("fleet_recovery_ticks", {})
    st = fleet.stats()
    return (wall / max(1, done), hist.get("mean", 0.0), st["retries"],
            st["directory"]["tombstoned_pages"], done, len(rids))


def _bench_hedge(n_requests):
    fleet, vocab, _ = _mk_fleet(2, hedge_after=2, migrate=False)
    t0 = time.perf_counter()
    rids = [fleet.submit(p) for p in _prompts(vocab, n_requests)]
    fleet.run()
    wall = time.perf_counter() - t0
    assert all(fleet.outcomes[r] == "ok" for r in rids)
    return wall / len(rids), fleet.stats()["hedges"] / len(rids)


def main(csv=True, smoke: bool = False):
    n = 6 if smoke else 12
    rows = []
    mig_s, bpp, pages, hit = _bench_migrate(n)
    rows.append(("fleet_migrate", mig_s * 1e6,
                 f"bytes_per_page={bpp:.0f};pages={pages};"
                 f"dir_hit_rate={hit:.2f}"))
    per_req, rec_ticks, retries, tombs, done, total = _bench_host_loss(n)
    rows.append(("fleet_host_loss", per_req * 1e6,
                 f"recovery_ticks={rec_ticks:.1f};retries={retries};"
                 f"tombstoned={tombs};completed={done}/{total}"))
    per_req, rate = _bench_hedge(n)
    rows.append(("fleet_hedge", per_req * 1e6,
                 f"hedge_rate={rate:.2f}"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    a = ap.parse_args()
    print("name,us_per_call,derived")
    main(smoke=a.smoke)
