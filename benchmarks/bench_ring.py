"""Ring-attention fwd/bwd benchmark: the §Perf B6 acceptance table.

Three context-parallel schedules over the same (q, k, v):

  * ``allgather``  — the replicated-k/v shard_map (§Perf B5): every
    device holds the full k/v, the chip-scale "gather the operand into
    every tile" baseline the paper criticizes;
  * ``ring_naive`` — the ppermute ring with its fold loop reverse-
    differentiated by JAX: the backward that stacked one (S/m x S/m) f32
    score tile per hop and kept the ring opt-in (ROADMAP §Perf B6,
    "refuted as measured");
  * ``ring_vjp``   — the memory-flat custom VJP
    (``parallel.ring_attention``): backward recomputes each hop's tile
    and circulates dk/dv accumulators with the shards.

Per schedule: fwd and bwd (value_and_grad) wall time, the per-device HBM
traffic of the bwd program (``analysis.hlo_cost.module_cost`` — the
roofline "memory term", also printed as milliseconds at HBM_BW), and the
XLA temp arena (``compat.memory_stats``), where the naive path's stacked
residuals live.

Acceptance: ``ring_vjp`` bwd must beat ``ring_naive`` bwd on BOTH time
and memory term, and sit within noise of ``allgather`` bwd time at lower
per-device traffic bytes.  The ``ring_bwd_vjp_vs_naive`` summary row
carries the ratios.

The ring needs a mesh, so the table is produced by an 8-virtual-device
subprocess (same pattern as tests/test_distributed.py); run directly:
``PYTHONPATH=src python benchmarks/bench_ring.py`` (``--smoke`` for CI).
"""
import argparse
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (B, S, H, Hkv, Dh); mesh is (2 data, 4 model) -> S/m = S/4 per device
FULL = (2, 2048, 8, 4, 64)
SMOKE = (2, 512, 8, 4, 64)


def _worker(smoke: bool) -> None:
    import jax
    import jax.numpy as jnp

    from repro.analysis.hlo_cost import module_cost
    from repro.analysis.roofline import HBM_BW
    from repro.models import layers
    from repro.parallel.ring_attention import ring_attention
    from repro.runtime import compat

    B, S, H, Hkv, Dh = SMOKE if smoke else FULL
    reps = 1 if smoke else 2
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, Dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, Dh),
                          jnp.float32)

    paths = {
        "allgather": lambda q, k, v: layers._attention_ring(
            q, k, v, causal=True, window=None, ring="replicated"),
        "ring_naive": lambda q, k, v: ring_attention(
            q, k, v, causal=True, window=None, impl="naive"),
        "ring_vjp": lambda q, k, v: ring_attention(
            q, k, v, causal=True, window=None, impl="vjp"),
    }

    def timed(fn, *args):
        out = fn(*args)           # compile + warm
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    stats = {}
    for name, f in paths.items():
        def loss(q, k, v, f=f):
            return (f(q, k, v).astype(jnp.float32) ** 2).sum()

        with compat.set_mesh(mesh):
            fwd = jax.jit(f)
            bwd = jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))
            t_fwd = timed(fwd, q, k, v)
            t_bwd = timed(bwd, q, k, v)
            compiled = bwd.lower(q, k, v).compile()
        cost = module_cost(compiled.as_text())   # per-device (SPMD shapes)
        mem = compat.memory_stats(compiled)
        stats[name] = dict(t_fwd=t_fwd, t_bwd=t_bwd, hbm=cost.bytes,
                           temp=mem["temp_bytes"])
        print(f"ring_fwd_{name},{t_fwd * 1e6:.0f},S={S};mesh=2x4")
        print(f"ring_bwd_{name},{t_bwd * 1e6:.0f},"
              f"hbm_mb_dev={cost.bytes / 1e6:.1f};"
              f"mem_term_ms={cost.bytes / HBM_BW * 1e3:.2f};"
              f"temp_mb={mem['temp_bytes'] / 1e6:.1f}")

    nv, vj, ag = stats["ring_naive"], stats["ring_vjp"], stats["allgather"]
    print(f"ring_bwd_vjp_vs_naive,0,"
          f"speedup={nv['t_bwd'] / vj['t_bwd']:.2f}x;"
          f"hbm_ratio={vj['hbm'] / nv['hbm']:.2f};"
          f"temp_ratio={vj['temp'] / max(1, nv['temp']):.2f}")
    print(f"ring_bwd_vjp_vs_allgather,0,"
          f"time_ratio={vj['t_bwd'] / ag['t_bwd']:.2f};"
          f"hbm_ratio={vj['hbm'] / ag['hbm']:.2f};"
          f"temp_ratio={vj['temp'] / max(1, ag['temp']):.2f}")


def main(csv=True, smoke: bool = False):
    """Spawn the 8-device worker and relay its CSV rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__), "--worker"]
    if smoke:
        cmd.append("--smoke")
    p = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=1800)
    if p.returncode != 0:
        raise RuntimeError(f"bench_ring worker failed:\n{p.stdout}\n"
                           f"{p.stderr}")
    rows = []
    for line in p.stdout.splitlines():
        if line.startswith("ring_"):
            rows.append(line)
            print(line)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized shapes (short sequence, single rep)")
    ap.add_argument("--worker", action="store_true",
                    help="internal: run measurements in THIS process "
                         "(expects the 8-device XLA_FLAGS already set)")
    a = ap.parse_args()
    if a.worker:
        _worker(a.smoke)
    else:
        main(csv=True, smoke=a.smoke)
