"""Kernel micro-bench: wall time in interpret mode (CPU container; on TPU
the same entry points compile via Mosaic) + the analytic traffic the
VectorMesh schedule predicts for each kernel's tiling."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TEU_BUFFER, matmul_op, search_tiles, traffic
from repro.kernels import ops


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def main(csv=True):
    rng = np.random.default_rng(0)
    rows = []

    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    us = _time(lambda a, b: ops.matmul(a, b, block_m=64, block_n=64,
                                       block_k=64), a, b)
    op = matmul_op(256, 256, 256)
    s = search_tiles(op, TEU_BUFFER)
    t = traffic(op, s.tile, shared_axes=("i", "j"))
    rows.append(("kernel_matmul_256", us,
                 f"sched {t.normalized_access():.1f}B/kMAC"))

    x = jnp.asarray(rng.normal(size=(1, 32, 32, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 16, 32)), jnp.float32)
    us = _time(lambda x, w: ops.conv2d(x, w, block_oh=8, block_co=16), x, w)
    rows.append(("kernel_conv2d_3x3", us, ""))

    i1 = jnp.asarray(rng.normal(size=(16, 16, 16)), jnp.float32)
    i2 = jnp.asarray(rng.normal(size=(16, 16, 16)), jnp.float32)
    us = _time(lambda a, b: ops.correlation(a, b, radius=2, block_y=8),
               i1, i2)
    rows.append(("kernel_correlation_r2", us, ""))

    q = jnp.asarray(rng.normal(size=(1, 8, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), jnp.float32)
    us = _time(lambda q, k: ops.flash_attention(q, k, k, block_q=32,
                                                block_k=32), q, k)
    rows.append(("kernel_flash_attention", us, "GQA 8/2"))

    qd = jnp.asarray(rng.normal(size=(4, 8, 32)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(4, 2, 128, 32)), jnp.float32)
    lens = jnp.full((4,), 100, jnp.int32)
    us = _time(lambda q, kc, l: ops.flash_decode(q, kc, kc, l, block_k=64),
               qd, kc, lens)
    rows.append(("kernel_flash_decode", us, ""))

    if csv:
        print("name,us_per_call,derived")
        for n, us, d in rows:
            print(f"{n},{us:.0f},{d}")
    return rows


if __name__ == "__main__":
    main()
