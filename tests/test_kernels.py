"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32):
    a = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(a, dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(16, 16, 16), (70, 50, 130), (128, 64, 32),
                                   (1, 256, 96)])
def test_matmul_sweep(shape, dtype):
    M, N, K = shape
    a, b = _arr((M, K), dtype), _arr((K, N), dtype)
    out = ops.matmul(a, b, block_m=32, block_n=32, block_k=64)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.matmul_ref(a, b), np.float32), **TOL[dtype])


@pytest.mark.parametrize("stride,dilation", [(1, 1), (2, 1), (1, 2), (2, 2)])
@pytest.mark.parametrize("kh,kw", [(3, 3), (1, 7), (5, 5), (1, 1)])
def test_conv2d_sweep(stride, dilation, kh, kw):
    x = _arr((2, 18, 17, 6))
    w = _arr((kh, kw, 6, 10))
    if (18 - (kh - 1) * dilation - 1) < 0:
        pytest.skip("kernel larger than input")
    out = ops.conv2d(x, w, stride=stride, dilation=dilation,
                     block_oh=4, block_co=8)
    r = ref.conv2d_ref(x, w, stride=stride, dilation=dilation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("radius", [1, 2, 4])
@pytest.mark.parametrize("H,W,C", [(12, 10, 8), (8, 8, 16), (16, 6, 4)])
def test_correlation_sweep(radius, H, W, C):
    i1, i2 = _arr((H, W, C)), _arr((H, W, C))
    out = ops.correlation(i1, i2, radius=radius, block_y=4)
    r = ref.correlation_ref(i1, i2, radius=radius)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("H,Hkv", [(8, 8), (8, 2), (4, 1)])
def test_flash_attention_sweep(causal, H, Hkv):
    B, S, Dh = 2, 24, 16
    q = _arr((B, H, S, Dh))
    k = _arr((B, Hkv, S, Dh))
    v = _arr((B, Hkv, S, Dh))
    out = ops.flash_attention(q, k, v, causal=causal, block_q=8, block_k=8)
    r = ref.attention_ref(q.reshape(B * H, S, Dh),
                          k.reshape(B * Hkv, S, Dh),
                          v.reshape(B * Hkv, S, Dh),
                          causal=causal).reshape(B, H, S, Dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               rtol=1e-3, atol=1e-3)


def test_flash_attention_window():
    B, H, Hkv, S, Dh = 1, 4, 2, 32, 8
    q, k, v = _arr((B, H, S, Dh)), _arr((B, Hkv, S, Dh)), _arr((B, Hkv, S, Dh))
    out = ops.flash_attention(q, k, v, causal=True, window=8,
                              block_q=8, block_k=8)
    r = ref.attention_ref(q.reshape(B * H, S, Dh), k.reshape(B * Hkv, S, Dh),
                          v.reshape(B * Hkv, S, Dh), causal=True,
                          window=8).reshape(B, H, S, Dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("lens", [[32, 10, 1], [5, 5, 5]])
def test_flash_decode(lens):
    B, H, Hkv, S, Dh = 3, 8, 2, 32, 16
    q = _arr((B, H, Dh))
    kc, vc = _arr((B, Hkv, S, Dh)), _arr((B, Hkv, S, Dh))
    lengths = jnp.asarray(lens, jnp.int32)
    out = ops.flash_decode(q, kc, vc, lengths, block_k=8)
    G = H // Hkv
    r = ref.decode_ref(q.reshape(B, Hkv, G, Dh).reshape(B * Hkv, G, Dh),
                       kc.reshape(B * Hkv, S, Dh), vc.reshape(B * Hkv, S, Dh),
                       jnp.repeat(lengths, Hkv)).reshape(B, Hkv, G, Dh)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(r.reshape(B, H, Dh)),
                               rtol=1e-3, atol=1e-3)


def test_matmul_blocks_follow_tile_search():
    """ops.matmul default blocks come from the paper's tile search."""
    from repro.core.pallas_bridge import matmul_block_shapes
    bm, bn, bk = matmul_block_shapes(4096, 4096, 4096)
    assert bm % 128 == 0 and bn % 128 == 0
    assert bm * bk * 2 + bk * bn * 2 <= 8 * 1024 * 1024
