"""Unit tests: NDRange tensor-op formulation (paper Eq. 1-3)."""
import pytest

from repro.core import (conv2d_op, correlation_op, depthwise_conv2d_op,
                        matmul_op, attention_scores_op)
from repro.core.ndrange import AffineExpr, Dim, TEMPORAL


def test_matmul_counts():
    op = matmul_op(64, 32, 16)
    assert op.total_macs() == 64 * 32 * 16
    full = op.full_tile()
    A, B = op.inputs
    assert A.footprint_elems(full) == 64 * 16
    assert B.footprint_elems(full) == 16 * 32
    assert op.output.footprint_elems(full) == 64 * 32


def test_matmul_tile_footprints_match_eq4():
    """(t_i + t_j) * t_k input words per t_i*t_j*t_k MACs (paper Eq. 4)."""
    op = matmul_op(64, 64, 64)
    tile = {"i": 8, "j": 16, "k": 32}
    assert op.tile_input_bytes(tile) == (8 * 32 + 32 * 16) * 2
    assert op.tile_macs(tile) == 8 * 16 * 32
    assert op.tile_psum_elems(tile) == 8 * 16


def test_conv_footprint_overlap():
    """Conv input windows overlap: extent = stride*(t-1) + dilated kernel."""
    op = conv2d_op(8, 4, 10, 10, 3, 3, stride=2, dilation=2)
    tile = {"co": 2, "y": 4, "x": 5, "ci": 4, "m": 3, "n": 3}
    I = op.inputs[0]
    # y axis: 2*(4-1) + 2*(3-1) + 1 = 11 rows
    assert I.index_exprs[1].extent(tile) == 11
    assert I.index_exprs[2].extent(tile) == 2 * 4 + 2 * 2 + 1


def test_invariant_dims_match_paper_fig2():
    """dA/dj = 0 -> A shareable along j (paper Fig. 2)."""
    op = matmul_op(8, 8, 8)
    A, B = op.inputs
    assert A.invariant_dims(op.dims) == ("j",)
    assert B.invariant_dims(op.dims) == ("i",)


def test_correlation_formulation():
    op = correlation_op(5, 5, 8, 8, 16)
    assert op.total_macs() == 5 * 5 * 8 * 8 * 16
    I1, I2 = op.inputs
    # I1 does not depend on the displacement dims (k, l): shareable
    assert set(I1.invariant_dims(op.dims)) == {"k", "l"}
    assert I2.invariant_dims(op.dims) == ()


def test_depthwise_no_channel_reduction():
    op = depthwise_conv2d_op(16, 8, 8, 3, 3)
    assert op.total_macs() == 16 * 8 * 8 * 9


def test_attention_is_spatial_matching():
    op = attention_scores_op(4, 16, 16, 8)
    Q, K = op.inputs
    assert "s" in Q.invariant_dims(op.dims)   # Q shared across kv positions
    assert "q" in K.invariant_dims(op.dims)   # K shared across queries


def test_tile_candidates_pow2_ladder():
    from repro.core.ndrange import tile_candidates
    op = matmul_op(64, 100, 8)
    pow2 = tile_candidates(op)
    # powers of two up to the dim size, plus the size itself
    assert pow2[0] == [1, 2, 4, 8, 16, 32, 64]
    assert pow2[1] == [1, 2, 4, 8, 16, 32, 64, 100]
    assert pow2[2] == [1, 2, 4, 8]


def test_tile_candidates_dense_ladder():
    """pow2=False adds the 1.5x midpoints — a strictly denser ladder, not
    the squared progression (1, 2, 4, 16, 256, ...) of the old bug."""
    from repro.core.ndrange import tile_candidates
    op = matmul_op(64, 100, 8)
    dense = tile_candidates(op, pow2=False)
    assert dense[0] == [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64]
    assert dense[2] == [1, 2, 3, 4, 6, 8]
    # every pow2 candidate is still present
    for p2, dn in zip(tile_candidates(op), dense):
        assert set(p2) <= set(dn)
    # enumerate_tiles agrees with the candidate lists
    from repro.core.ndrange import enumerate_tiles
    seen = {t["i"] for t in enumerate_tiles(op, pow2=False)}
    assert seen == set(dense[0])


def test_enumerate_tiles_respects_caps():
    from repro.core.ndrange import enumerate_tiles
    op = matmul_op(64, 64, 64)
    tiles = list(enumerate_tiles(op, caps={"i": 8}))
    assert max(t["i"] for t in tiles) == 8
    assert max(t["j"] for t in tiles) == 64


def test_output_on_temporal_rejected():
    with pytest.raises(ValueError):
        from repro.core.ndrange import OperandView, TensorOp
        dims = (Dim("i", 4, "parallel"), Dim("k", 4, TEMPORAL))
        bad_out = OperandView("C", (AffineExpr.of({"k": 1}),))
        ins = (OperandView("A", (AffineExpr.of({"i": 1}),)),)
        TensorOp("bad", dims, ins, bad_out)
