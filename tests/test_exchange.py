"""Data-exchange mesh analysis (paper Fig. 2) + Pallas grid ordering."""
import itertools
import math

from repro.core import (conv2d_op, grid_fetch_bytes, matmul_op,
                        order_grid_for_sharing, plan_mesh_exchange,
                        search_tiles, TEU_BUFFER)


def test_mesh_exchange_shares_invariant_operands():
    op = matmul_op(256, 256, 256)
    s = search_tiles(op, TEU_BUFFER)
    plan = plan_mesh_exchange(op, s.tile, (2, 2))
    # A invariant along j, B along i -> both shareable on a 2x2 mesh
    assert plan.sharing_factor > 1.5
    assert plan.fifo_hop_bytes > 0


def test_exchange_monotone_in_mesh_size():
    op = matmul_op(512, 512, 512)
    s = search_tiles(op, TEU_BUFFER)
    p22 = plan_mesh_exchange(op, s.tile, (2, 2))
    p44 = plan_mesh_exchange(op, s.tile, (4, 4))
    assert p44.sharing_factor >= p22.sharing_factor


def test_restricted_sharing_worse():
    """Eyeriss-style one-axis multicast shares less than the FIFO mesh."""
    op = matmul_op(256, 256, 256)
    s = search_tiles(op, TEU_BUFFER)
    full = plan_mesh_exchange(op, s.tile, (4, 4))
    restricted = plan_mesh_exchange(op, s.tile, (4, 4), share_cols=False)
    assert restricted.fetch_bytes >= full.fetch_bytes


def test_grid_order_beats_worst_order():
    op = matmul_op(512, 512, 512)
    s = search_tiles(op, TEU_BUFFER)
    best = order_grid_for_sharing(op, s.tile)
    names = [d.name for d in op.dims]
    worst = max(
        (grid_fetch_bytes(op, s.tile, tuple(p) )
         for p in itertools.permutations(names)))
    assert best.total_fetch_bytes <= worst


def test_grid_order_exhaustive_optimal():
    """The chosen parallel-dim order is optimal among permutations with
    temporal innermost."""
    op = conv2d_op(32, 16, 16, 16, 3, 3)
    s = search_tiles(op, TEU_BUFFER)
    best = order_grid_for_sharing(op, s.tile)
    par = [d.name for d in op.parallel_dims]
    tmp = [d.name for d in op.temporal_dims]
    for p in itertools.permutations(par):
        order = tuple(p) + tuple(tmp)
        assert grid_fetch_bytes(op, s.tile, order) >= best.total_fetch_bytes
