"""Simulator reproduces the paper's Table III claims (within analytical-model
tolerance; the paper's own Eyeriss repro differs ~10% from the reference)."""
import pytest

from repro.sim import CLASSIC, MODERN, SPATIAL, eyeriss, simulate, summarize, \
    tpu, vectormesh


@pytest.fixture(scope="module")
def table3():
    out = {}
    for n_pe in (128, 512):
        for name, mk in (("tpu", tpu), ("eyeriss", eyeriss),
                         ("vectormesh", vectormesh)):
            rs = [simulate(mk(n_pe), w) for w in CLASSIC]
            out[(n_pe, name)] = summarize(rs)
    return out


def test_glb_reduction_vs_tpu(table3):
    """Abstract: 'reduce global buffer fetches by 2-22x' (TPU is the 22x
    end; paper Table III: 935/42=22.3 at 128 PE, 534/29=18.4 at 512)."""
    for n_pe in (128, 512):
        ratio = table3[(n_pe, "tpu")]["norm_glb"] / \
            table3[(n_pe, "vectormesh")]["norm_glb"]
        assert 10 <= ratio <= 40, ratio


def test_glb_reduction_vs_eyeriss(table3):
    """Paper: VectorMesh consumes 2-4x less GLB bandwidth than Eyeriss."""
    ratio = table3[(128, "eyeriss")]["norm_glb"] / \
        table3[(128, "vectormesh")]["norm_glb"]
    assert 1.5 <= ratio <= 8, ratio


def test_dram_reduction_vs_tpu(table3):
    """Paper: 2-5x DRAM bandwidth reduction vs TPU."""
    ratio = table3[(128, "tpu")]["norm_dram"] / \
        table3[(128, "vectormesh")]["norm_dram"]
    assert 1.8 <= ratio <= 6, ratio


def test_dram_competitive_with_eyeriss(table3):
    """Paper: -14%..+44% DRAM vs Eyeriss (i.e. roughly comparable)."""
    for n_pe in (128, 512):
        ratio = table3[(n_pe, "eyeriss")]["norm_dram"] / \
            table3[(n_pe, "vectormesh")]["norm_dram"]
        assert 0.6 <= ratio <= 2.5, ratio


def test_vectormesh_closest_to_roofline(table3):
    """Fig. 3: VectorMesh performs closest to the roofline."""
    for n_pe in (128, 512):
        vm = table3[(n_pe, "vectormesh")]["roofline_frac"]
        assert vm >= table3[(n_pe, "tpu")]["roofline_frac"]
        assert vm >= table3[(n_pe, "eyeriss")]["roofline_frac"]
        assert vm > 0.6


def test_absolute_performance_band(table3):
    """Paper Table III: VM performance 20 GOPS @128PE, 68 @512PE (+-30%)."""
    assert 14 <= table3[(128, "vectormesh")]["gmacs"] <= 26
    assert 48 <= table3[(512, "vectormesh")]["gmacs"] <= 88


def test_vm_supports_modern_and_spatial():
    """Fig. 4: modern CNN + spatial matching run (exclusive workloads)."""
    arch = vectormesh(512)
    for w in MODERN + SPATIAL:
        r = simulate(arch, w)
        assert r.gmacs > 0
        assert r.roofline_frac <= 1.01


def test_mobilenet_depthwise_reaches_low_roofline():
    """Fig. 4: MobileNet layers are memory-bound: low absolute perf but at
    (or near) their own roofline."""
    from repro.sim import by_name
    r = simulate(vectormesh(512), by_name("MBN_DW_S1"))
    assert r.roofline_gmacs < 30          # memory-bound roofline
    assert r.roofline_frac > 0.4
