"""Real-fleet runtime: process supervisor, restart policy, striped restore.

Two layers of coverage:

* FAST units drive the Supervisor with trivial stand-in worker scripts
  (the ``cmd_builder`` seam exists exactly for this): restart-on-43,
  eviction + elastic gang re-mesh, failure-budget shutdown, hang
  detection, supervisor-side sigkill chaos, and the stripe-exchange
  transports.
* E2E drills launch REAL ``repro.launch.train`` worker processes under
  ``repro.launch.supervisor``: chaos kill -> exit 43 -> restart ->
  resume, with final params bit-identical to an uninterrupted fleet
  (compared via per-rank ``params_crc`` result files); a striped gang
  restore that reads strictly fewer checkpoint bytes per host than a
  full read (asserted from the obs-registry counters each worker
  exports); and an optional jax.distributed bring-up smoke.
"""
import json
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, restore_checkpoint,
                              restore_checkpoint_striped, save_checkpoint)
from repro.obs import REGISTRY
from repro.runtime import (LocalStripeExchange, RestartPolicy,
                           StripeExchangeTimeout, Supervisor,
                           TcpStripeExchange, allocate_ports,
                           split_spec_strings)

ARCH = "qwen3-4b"
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

FAST = RestartPolicy(max_restarts_per_rank=2, max_total_failures=6,
                     backoff_base_s=0.05, backoff_max_s=0.2,
                     hang_timeout_s=1.0, term_grace_s=2.0)


# ---------------------------------------------------------------------------
# restart policy units
# ---------------------------------------------------------------------------

def test_backoff_deterministic_jittered_capped():
    p = RestartPolicy(backoff_base_s=0.25, backoff_max_s=2.0,
                      backoff_jitter=0.25)
    a = p.backoff_s(1, seed=0, rank=1)
    assert a == p.backoff_s(1, seed=0, rank=1)      # replayable
    assert a != p.backoff_s(1, seed=0, rank=2)      # decorrelated by rank
    assert 0.25 <= a <= 0.25 * 1.25                 # base + bounded jitter
    assert 0.5 <= p.backoff_s(2, seed=0, rank=1) <= 0.5 * 1.25
    assert p.backoff_s(10, seed=0, rank=1) <= 2.0 * 1.25   # capped


def test_split_spec_strings_partitions_supervisor_kinds():
    sup, wrk = split_spec_strings(
        ["kill@5", "sigkill@9:host=2", "diskfull@3"])
    assert sup == ["sigkill@9:host=2"]
    assert wrk == ["kill@5", "diskfull@3"]


# ---------------------------------------------------------------------------
# supervisor over stand-in workers (fast)
# ---------------------------------------------------------------------------

def _fake_builder(tmp_path, fleet_dir, body):
    """cmd_builder whose worker script runs `body` with rank/world/tag/
    attempt/fleet_dir bound and a heartbeat() helper in scope."""
    script = tmp_path / "worker.py"
    script.write_text(textwrap.dedent("""\
        import json, os, sys, time
        rank, world, tag, attempt = map(int, sys.argv[1:5])
        fleet_dir = sys.argv[5]

        def heartbeat(step):
            d = os.path.join(fleet_dir, "hb")
            os.makedirs(d, exist_ok=True)
            p = os.path.join(d, f"rank_{tag}.json")
            with open(p + ".tmp", "w") as f:
                json.dump({"rank": rank, "step": step,
                           "wall": time.time()}, f)
            os.replace(p + ".tmp", p)
    """) + textwrap.dedent(body))

    def build(spec):
        return [sys.executable, str(script), str(spec.rank),
                str(spec.world), str(spec.tag), str(spec.attempt),
                fleet_dir]

    return build


def test_exit_43_restarts_until_success(tmp_path):
    fleet = str(tmp_path / "fleet")
    build = _fake_builder(tmp_path, fleet, """\
        heartbeat(attempt)
        sys.exit(43 if attempt == 1 else 0)
    """)
    report = Supervisor(2, build, fleet_dir=fleet, policy=FAST).run()
    assert report["outcome"] == "completed"
    assert report["total_failures"] == 2
    for w in report["workers"]:
        assert w["exit_history"] == [43, 0]
        assert w["attempts"] == 2 and w["state"] == "done"
    assert any(e["kind"] == "backoff" for e in report["events"])


def test_repeat_offender_evicted_and_gang_remeshed(tmp_path):
    """tag 1 fails every launch -> after the per-rank cap it is evicted;
    the surviving gang is SIGTERMed and relaunched re-meshed (world 2 ->
    1), after which it finishes: a degraded but completed fleet."""
    fleet = str(tmp_path / "fleet")
    build = _fake_builder(tmp_path, fleet, """\
        if tag == 1:
            sys.exit(1)
        if world == 1:
            sys.exit(0)       # post-remesh solo gang: finish
        time.sleep(60)        # pre-remesh: stay up until SIGTERMed
    """)
    policy = RestartPolicy(max_restarts_per_rank=1, max_total_failures=10,
                           backoff_base_s=0.05, backoff_max_s=0.1,
                           term_grace_s=2.0)
    report = Supervisor(2, build, fleet_dir=fleet, policy=policy).run()
    assert report["outcome"] == "degraded"
    by_tag = {w["tag"]: w for w in report["workers"]}
    assert by_tag[1]["state"] == "evicted"
    assert by_tag[0]["state"] == "done"
    assert report["plan"]["n_hosts"] == 1
    assert report["plan"]["data_parallel"] == 1
    assert report["plan"]["host_ranks"] in ({0: 0}, {"0": 0})
    kinds = [e["kind"] for e in report["events"]]
    assert "evict" in kinds and "remesh" in kinds


def test_failure_budget_exhaustion_shuts_down(tmp_path):
    fleet = str(tmp_path / "fleet")
    build = _fake_builder(tmp_path, fleet, "sys.exit(2)\n")
    policy = RestartPolicy(max_restarts_per_rank=10, max_total_failures=2,
                           backoff_base_s=0.05, backoff_max_s=0.1)
    report = Supervisor(2, build, fleet_dir=fleet, policy=policy).run()
    assert report["outcome"] == "budget_exhausted"
    assert report["total_failures"] == 3            # the one over budget
    assert any(e["kind"] == "escalate" for e in report["events"])
    assert all(w["state"] == "evicted" for w in report["workers"])


def test_hang_detector_kills_quiet_worker(tmp_path):
    """A worker that heartbeats once and goes dark (chaos partition /
    livelock) is SIGKILLed onto the ordinary restart path."""
    fleet = str(tmp_path / "fleet")
    build = _fake_builder(tmp_path, fleet, """\
        heartbeat(0)
        if attempt == 1:
            time.sleep(60)    # dark: no further heartbeats
        sys.exit(0)
    """)
    report = Supervisor(1, build, fleet_dir=fleet, policy=FAST).run()
    assert report["outcome"] == "completed"
    assert any(e["kind"] == "hang_kill" for e in report["events"])
    (w,) = report["workers"]
    assert w["exit_history"][0] == -9 and w["exit_history"][-1] == 0


def test_sigkill_chaos_fires_on_heartbeat_step(tmp_path):
    """Supervisor-side sigkill@N: an uncatchable SIGKILL once the target
    rank's heartbeat reaches step N — fired exactly once, so the restart
    (which replays the same steps) is not killed again."""
    fleet = str(tmp_path / "fleet")
    build = _fake_builder(tmp_path, fleet, """\
        heartbeat(100)
        if attempt == 1:
            time.sleep(60)
        sys.exit(0)
    """)
    report = Supervisor(1, build, fleet_dir=fleet, policy=FAST,
                        chaos_specs=["sigkill@50:host=0"]).run()
    assert report["outcome"] == "completed"
    assert [e["kind"] for e in report["events"]].count("chaos_sigkill") == 1
    (w,) = report["workers"]
    assert w["exit_history"] == [-9, 0]


# ---------------------------------------------------------------------------
# stripe exchange transports
# ---------------------------------------------------------------------------

def _threaded_allgather(exchanges, payloads, key="k"):
    world = len(payloads)
    out, errs = [None] * world, [None] * world

    def go(r):
        try:
            ex = exchanges[r] if isinstance(exchanges, list) else exchanges
            out[r] = ex.allgather(key, r, world, payloads[r])
        except Exception as e:           # surfaced to the test thread
            errs[r] = e

    ts = [threading.Thread(target=go, args=(r,)) for r in range(world)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return out, errs


def test_local_stripe_exchange_allgather_orders_by_rank():
    ex = LocalStripeExchange(3)
    payloads = [b"aaa", b"bb", b"c"]
    out, errs = _threaded_allgather(ex, payloads)
    assert errs == [None, None, None]
    assert all(got == payloads for got in out)


def test_local_stripe_exchange_timeout_is_timeout_error():
    """A missing peer is a TIMEOUT, never CheckpointCorruptError — the
    bytes on disk may be fine and falling back to an older checkpoint
    would silently lose steps."""
    assert issubclass(StripeExchangeTimeout, TimeoutError)
    assert not issubclass(StripeExchangeTimeout, CheckpointCorruptError)
    ex = LocalStripeExchange(2, timeout_s=0.2)
    with pytest.raises(StripeExchangeTimeout, match="ranks \\[1\\]"):
        ex.allgather("k", 0, 2, b"x")


def test_tcp_stripe_exchange_round_trip():
    ports = allocate_ports(2)
    exs = [TcpStripeExchange(r, ports, timeout_s=20) for r in range(2)]
    try:
        payloads = [b"\x00" * 70000, b"peer-bytes"]   # > one recv chunk
        out, errs = _threaded_allgather(exs, payloads)
        assert errs == [None, None]
        assert all(got == payloads for got in out)
    finally:
        for ex in exs:
            ex.close()


# ---------------------------------------------------------------------------
# striped restore: bit-identical, cheaper, corruption-detecting
# ---------------------------------------------------------------------------

def _striped_pair(path, step, like, world=2):
    ex = LocalStripeExchange(world)
    out, errs = [None] * world, [None] * world

    def go(r):
        try:
            out[r] = restore_checkpoint_striped(path, step, like, rank=r,
                                                world=world, exchange=ex)
        except Exception as e:
            errs[r] = e

    ts = [threading.Thread(target=go, args=(r,)) for r in range(world)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return out, errs


def test_striped_restore_matches_full_and_reads_fewer_bytes(tmp_path):
    path = str(tmp_path)
    rng = np.random.default_rng(0)
    tree = {"w": rng.normal(size=(64, 64)).astype(np.float32),
            "b": rng.normal(size=(64,)).astype(np.float32)}
    save_checkpoint(path, 9, tree)
    before = REGISTRY.snapshot()["counters"]
    out, errs = _striped_pair(path, 9, tree)
    assert errs == [None, None]
    full = restore_checkpoint(path, 9, tree)
    for got in out:
        np.testing.assert_array_equal(got["w"], full["w"])
        np.testing.assert_array_equal(got["b"], full["b"])
    after = REGISTRY.snapshot()["counters"]
    shard_bytes = os.path.getsize(
        os.path.join(path, "step_00000009", "shard_0.npz"))
    key = "checkpoint_read_bytes{mode=striped}"
    striped_delta = after.get(key, 0) - before.get(key, 0)
    # two ranks TOGETHER read ~one shard's worth; each strictly less
    assert 0 < striped_delta < 2 * shard_bytes
    assert striped_delta / 2 < shard_bytes


def test_striped_restore_detects_corruption_on_assembled_bytes(tmp_path):
    from repro.runtime.chaos import corrupt_checkpoint
    path = str(tmp_path)
    tree = {"w": np.arange(4096, dtype=np.float32)}
    save_checkpoint(path, 3, tree)
    corrupt_checkpoint(path, 3, mode="flip")
    out, errs = _striped_pair(path, 3, tree)
    assert out == [None, None]
    for e in errs:
        assert isinstance(e, CheckpointCorruptError)


# ---------------------------------------------------------------------------
# CLI exit-status contract (satellite: subprocess regression)
# ---------------------------------------------------------------------------

def _train_cli(*extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", ARCH,
           "--smoke", "--steps", "8", "--seq-len", "32",
           "--global-batch", "4", *extra]
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=300)


def test_chaos_kill_exits_43_from_cli(tmp_path):
    p = _train_cli("--chaos", "kill@4")
    assert p.returncode == 43, p.stderr


def test_chaos_kill_exit_43_survives_pending_save_error(tmp_path):
    """diskfull@4 leaves a failed async save pending when kill@6 fires;
    the preemption-grace wait must not let that OSError displace the
    kill — the supervisor keys its restart policy on status 43."""
    p = _train_cli("--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
                   "--chaos", "diskfull@4", "--chaos", "kill@6")
    assert p.returncode == 43, p.stderr
    assert "disk full" in p.stdout      # the failure was logged, not fatal


# ---------------------------------------------------------------------------
# E2E drills: real train workers under the real supervisor
# ---------------------------------------------------------------------------

def _run_supervisor(args):
    from repro.launch.supervisor import main
    return main([str(a) for a in args])


def _fleet_args(ckpt_dir, fleet_dir, report, steps=8, **kw):
    args = ["--nprocs", 2, "--arch", ARCH, "--steps", steps,
            "--seq-len", 32, "--global-batch", 4,
            "--ckpt-dir", ckpt_dir, "--ckpt-every", 4,
            "--fleet-dir", fleet_dir, "--report-out", report]
    for k, v in kw.items():
        args += [f"--{k.replace('_', '-')}", v]
    return args


def _results(fleet_dir, tags=(0, 1)):
    out = {}
    for t in tags:
        with open(os.path.join(fleet_dir, f"result_rank{t}.json")) as f:
            out[t] = json.load(f)
    return out


@pytest.fixture(scope="module")
def baseline_fleet(tmp_path_factory):
    """One uninterrupted 2-worker fleet run: the reference params_crc and
    a committed checkpoint dir for the striped-restore drill."""
    root = tmp_path_factory.mktemp("fleet-baseline")
    ckpt, fleet = str(root / "ckpt"), str(root / "fleet")
    report = str(root / "report.json")
    assert _run_supervisor(_fleet_args(ckpt, fleet, report)) == 0
    with open(report) as f:
        rep = json.load(f)
    assert rep["outcome"] == "completed"
    assert rep["final_checkpoint_step"] == 8
    return {"ckpt": ckpt, "fleet": fleet, "results": _results(fleet)}


def test_fleet_kill_restart_resumes_bit_identical(baseline_fleet, tmp_path):
    """THE acceptance drill: chaos kill@5 on rank 1 -> worker exits 43 ->
    supervisor restarts it -> it resumes from the committed step-4
    checkpoint -> final params bit-identical to the uninterrupted fleet,
    on every rank."""
    ckpt, fleet = str(tmp_path / "ckpt"), str(tmp_path / "fleet")
    report = str(tmp_path / "report.json")
    assert _run_supervisor(_fleet_args(ckpt, fleet, report,
                                       chaos="kill@5")) == 0
    with open(report) as f:
        rep = json.load(f)
    assert rep["outcome"] == "completed"
    by_tag = {w["tag"]: w for w in rep["workers"]}
    assert by_tag[1]["exit_history"][0] == 43       # died AS exit status 43
    assert by_tag[1]["attempts"] == 2               # exactly one restart
    assert by_tag[0]["attempts"] == 1               # untargeted rank rode on
    ref = baseline_fleet["results"][0]["params_crc"]
    for t, res in _results(fleet).items():
        assert res["params_crc"] == ref, (t, res)


def test_fleet_striped_restore_reads_fewer_bytes_per_host(baseline_fleet,
                                                          tmp_path):
    """Gang restart over the baseline checkpoint with striped restore:
    every worker restores the SAME state while reading strictly fewer
    checkpoint-dir bytes than one full shard read, proven by the
    obs-registry counters each worker exports."""
    ckpt = baseline_fleet["ckpt"]
    shard = os.path.join(ckpt, "step_00000008", "shard_0.npz")
    full_bytes = os.path.getsize(shard)
    fleet = str(tmp_path / "fleet")
    report = str(tmp_path / "report.json")
    assert _run_supervisor(_fleet_args(ckpt, fleet, report, steps=12,
                                       striped_restore="always")) == 0
    with open(report) as f:
        assert json.load(f)["outcome"] == "completed"
    for t in (0, 1):
        with open(os.path.join(fleet, f"metrics_rank{t}.json")) as f:
            counters = json.load(f)["counters"]
        assert counters.get("checkpoint_ops{op=restore_striped}") == 1
        striped = counters.get("checkpoint_read_bytes{mode=striped}", 0)
        assert 0 < striped < full_bytes, (t, striped, full_bytes)
        # and the gang really exchanged stripes instead of re-reading
        assert counters.get("checkpoint_stripe_bytes{dir=recv}", 0) > 0
    res = _results(fleet)
    assert res[0]["start_step"] == 8                # resumed, not recomputed
    assert res[0]["params_crc"] == res[1]["params_crc"]


def test_fleet_distributed_jax_smoke(tmp_path):
    """Optional jax.distributed bring-up: 2 real processes form one
    2-device fleet through the compat shim (no chaos — coordinator
    rejoin after restart is deliberately out of contract).

    The shim's contract is "an upgrade, not a requirement": under heavy
    machine load the coordinator barrier can time out, in which case the
    workers degrade to warned single-process mode by design.  The run
    must still complete with bit-identical params either way; the
    2-device assertions apply only when the barrier actually formed."""
    ckpt, fleet = str(tmp_path / "ckpt"), str(tmp_path / "fleet")
    report = str(tmp_path / "report.json")
    rc = _run_supervisor(_fleet_args(ckpt, fleet, report, steps=4,
                                     distributed="jax"))
    assert rc == 0
    res = _results(fleet)
    assert res[0]["params_crc"] == res[1]["params_crc"]
    if not all(r["dist_ok"] for r in res.values()):
        pytest.skip("jax.distributed barrier timed out under load; "
                    "workers degraded to single-process as designed")
    for t, r in res.items():
        # process_count, not device_count: a prior in-process import of
        # launch.dryrun force-multiplies host devices via XLA_FLAGS and
        # worker subprocesses inherit it — the barrier invariant is the
        # number of JOINED PROCESSES.
        assert r["process_count"] == 2, r
