"""Ring-attention policy plumbing (single device — the multi-device
numerics live in tests/test_distributed.py).

The policy replaced the old mutable ``layers.RING_PPERMUTE`` module
global: resolution is explicit-override > REPRO_RING_ATTN env > default,
and 'auto' picks ring vs the replicated XLA fallback by sequence
threshold and per-device shard cap."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import (DEFAULT_RING_POLICY, RingAttnPolicy,
                                decide_ring, ring_attn_policy)
from repro.parallel.ring_attention import ring_attention


def test_auto_policy_thresholds():
    pol = DEFAULT_RING_POLICY
    # long sequence, sane shard -> the ring is the default path
    assert decide_ring(pol, seq_len=4096, ring_size=8) == "ring"
    assert decide_ring(pol, seq_len=32768, ring_size=16) == "ring"
    # short sequence -> XLA fallback (replicated k/v)
    assert decide_ring(pol, seq_len=2048, ring_size=8) == "replicated"
    # shard above the per-device cap -> fall back too
    assert decide_ring(pol, seq_len=65536, ring_size=8) == "replicated"
    # non-auto modes pass through
    for mode in ("ring", "replicated", "off"):
        assert decide_ring(RingAttnPolicy(mode=mode), seq_len=1,
                           ring_size=2) == mode


def test_policy_resolution_order(monkeypatch):
    monkeypatch.delenv("REPRO_RING_ATTN", raising=False)
    assert ring_attn_policy().mode == "auto"
    monkeypatch.setenv("REPRO_RING_ATTN", "replicated")
    assert ring_attn_policy().mode == "replicated"
    # explicit override beats the env
    assert ring_attn_policy("ring").mode == "ring"
    monkeypatch.setenv("REPRO_RING_ATTN_THRESHOLD", "128")
    monkeypatch.setenv("REPRO_RING_ATTN_MAX_SHARD", "256")
    pol = ring_attn_policy("auto")
    assert pol.seq_threshold == 128 and pol.max_seq_per_device == 256
    monkeypatch.setenv("REPRO_RING_ATTN", "bogus")
    with pytest.raises(ValueError):
        ring_attn_policy()


def test_ring_attention_inapplicable_returns_none():
    q = jnp.zeros((1, 8, 2, 4))
    kv = jnp.zeros((1, 8, 2, 4))
    # no ambient mesh
    assert ring_attention(q, kv, kv) is None
    # cross-attention (Sk != Sq) under a 1-wide mesh is also a no
    assert ring_attention(q, kv[:, :4], kv[:, :4]) is None
