"""Butterfly-network conflict-free condition (paper §II-C) — property tests."""
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import bfn

X = 5  # 32 banks / PEs, as in the paper


odd = st.integers(min_value=-31, max_value=31).filter(lambda v: v % 2 == 1)


@given(base=st.integers(0, 4096), coeffs=st.lists(odd, min_size=X, max_size=X))
@settings(max_examples=200, deadline=None)
def test_merit_patterns_served_in_one_cycle(base, coeffs):
    """The MERIT address form is ALWAYS conflict-free + butterfly-routable."""
    addrs = bfn.merit_addresses(base, coeffs, X)
    assert bfn.serves_in_one_cycle(addrs, X)


@given(base=st.integers(0, 4096), stride=st.integers(1, 255))
@settings(max_examples=200, deadline=None)
def test_odd_strides_ok_even_strides_conflict(base, stride):
    addrs = bfn.strided_addresses(base, stride, X)
    if stride % 2 == 1:
        assert bfn.serves_in_one_cycle(addrs, X)
    else:
        assert not bfn.is_conflict_free(addrs, X)


@given(base=st.integers(0, 4096), stride=st.integers(2, 254))
@settings(max_examples=100, deadline=None)
def test_padding_fix(base, stride):
    """The paper's padding technique: bump even strides to odd."""
    padded = bfn.pad_stride(stride)
    assert padded % 2 == 1
    assert bfn.serves_in_one_cycle(
        bfn.strided_addresses(base, padded, X), X)


def test_even_coefficient_rejected():
    import pytest
    with pytest.raises(ValueError):
        bfn.merit_addresses(0, [2, 1, 1, 1, 1], X)


@given(key=st.integers(0, 31), base=st.integers(0, 1024),
       stride=st.integers(1, 63).filter(lambda v: v % 2 == 1))
@settings(max_examples=100, deadline=None)
def test_xor_shuffle_preserves_conflict_freedom(key, base, stride):
    addrs = bfn.strided_addresses(base, stride, X)
    shuffled = bfn.xor_shuffle(addrs, key, X)
    assert bfn.is_conflict_free(shuffled, X)
