"""Data pipeline, optimizer, compression, checkpointing, fault runtime."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, latest_step, save_checkpoint
from repro.data import DataConfig, ShardedLoader, SyntheticLM, \
    make_train_iterator
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compress_int8, cosine_schedule,
                         decompress_int8)
from repro.runtime import (HeartbeatMonitor, StragglerPolicy,
                           plan_elastic_remesh)


# ---------------- data ----------------

def test_data_deterministic_and_indexable():
    cfg = DataConfig(vocab=101, seq_len=32, global_batch=8, seed=3)
    src = SyntheticLM(cfg)
    b1 = src.batch(5, 0, 8)
    b2 = src.batch(5, 0, 8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 32)
    # labels are next tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_host_sharding_partitions_batch():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=8)
    src = SyntheticLM(cfg)
    full = src.batch(0, 0, 8)["tokens"]
    l0 = ShardedLoader(src, 0, 2).batch(0)["tokens"]
    l1 = ShardedLoader(src, 1, 2).batch(0)["tokens"]
    np.testing.assert_array_equal(np.concatenate([l0, l1]), full)


def test_prefetcher_orders_batches():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=4)
    it = make_train_iterator(cfg, start_step=7)
    try:
        s0, _ = it.next()
        s1, _ = it.next()
        assert (s0, s1) == (7, 8)
    finally:
        it.close()


def test_learnable_structure():
    """The bigram skeleton makes next-token prediction learnable."""
    cfg = DataConfig(vocab=64, seq_len=64, global_batch=16)
    b = SyntheticLM(cfg).batch(0, 0, 16)
    src = SyntheticLM(cfg)
    follow = src._bigram[b["tokens"]]
    agree = (follow == b["labels"]).mean()
    assert agree > 0.5   # ~0.75 by construction


# ---------------- optimizer ----------------

def test_adamw_decreases_quadratic_loss():
    params = {"w": jnp.array([2.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(cosine_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(cosine_schedule(cfg, jnp.asarray(100))) == pytest.approx(
        cfg.min_lr_frac, rel=1e-3)


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=64))
@settings(max_examples=50, deadline=None)
def test_int8_compression_bounded_error(vals):
    x = jnp.asarray(np.array(vals, np.float32))
    q, scale = compress_int8(x)
    err = jnp.abs(decompress_int8(q, scale) - x).max()
    amax = jnp.abs(x).max()
    assert float(err) <= float(amax) / 127 + 1e-6


# ---------------- checkpointing ----------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones((2,), np.int32)}}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    from repro.checkpoint import restore_checkpoint
    like = jax.tree.map(lambda x: np.zeros_like(x), tree)
    out = restore_checkpoint(str(tmp_path), 7, like)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3):
        mgr.save_async(step, {"w": np.full((4,), step, np.float32)})
        mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [2, 3]
    got = mgr.restore({"w": np.zeros((4,), np.float32)})
    assert got is not None and got[0] == 3
    np.testing.assert_array_equal(got[1]["w"], np.full((4,), 3, np.float32))


def test_checkpoint_restore_reshards(tmp_path):
    """Elastic path: restore applies a caller-provided sharding_fn."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(1, {"w": np.arange(8, dtype=np.float32)})
    mgr.wait()
    calls = []
    def shard(tree):
        calls.append(True)
        return tree
    mgr.restore({"w": np.zeros(8, np.float32)}, sharding_fn=shard)
    assert calls


# ---------------- fault runtime ----------------

def _clock():
    t = [0.0]
    def now():
        return t[0]
    return t, now


def test_heartbeat_timeout_detection():
    t, now = _clock()
    mon = HeartbeatMonitor([0, 1, 2],
                           StragglerPolicy(heartbeat_timeout_s=10),
                           clock=now)
    t[0] = 8.0
    mon.heartbeat(0); mon.heartbeat(1)
    t[0] = 16.0
    failed = mon.check()
    assert failed == [2]
    assert mon.alive_hosts() == [0, 1]


def test_straggler_eviction():
    t, now = _clock()
    pol = StragglerPolicy(straggler_factor=2.0, patience=3,
                          heartbeat_timeout_s=1e9)
    mon = HeartbeatMonitor([0, 1, 2, 3], pol, clock=now)
    for step in range(5):
        for h in (0, 1, 2):
            mon.heartbeat(h, step_time_s=1.0)
        mon.heartbeat(3, step_time_s=5.0)   # chronically slow
        mon.check()
    assert 3 not in mon.alive_hosts()


def test_elastic_remesh_power_of_two_dp():
    plan = plan_elastic_remesh(list(range(7)), chips_per_host=8,
                               model_parallel=16)
    # 7 hosts * 8 chips = 56 chips; mp=16 -> dp in {1, 2} -> dp=2, 4 hosts
    assert plan.data_parallel == 2
    assert plan.n_hosts == 4
    assert set(plan.host_ranks.values()) == set(range(4))


def test_elastic_remesh_insufficient_raises():
    with pytest.raises(AssertionError):
        plan_elastic_remesh([0], chips_per_host=8, model_parallel=16)
