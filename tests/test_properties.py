"""Hypothesis property tests on the scheduler's invariants."""
import math

import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import assume, given, settings, strategies as st

from repro.core import (BufferSpec, conv2d_op, matmul_op, search_tiles,
                        schedule_for, tile_fits, traffic,
                        plan_mesh_exchange, order_grid_for_sharing,
                        grid_fetch_bytes)

dims = st.integers(min_value=8, max_value=512).map(lambda v: (v // 8) * 8)


@given(M=dims, N=dims, K=dims)
@settings(max_examples=30, deadline=None)
def test_matmul_bytes_per_mac_closed_form(M, N, K):
    """Eq. 4: bytes/MAC = bpe*(t_i + t_j)/(t_i*t_j) for any valid tile."""
    op = matmul_op(M, N, K)
    tile = {"i": min(16, M), "j": min(32, N), "k": min(64, K)}
    got = op.tile_bytes_per_mac(tile)
    want = 2 * (tile["i"] + tile["j"]) / (tile["i"] * tile["j"])
    assert abs(got - want) < 1e-12


@given(M=dims, N=dims, K=dims,
       ib=st.integers(2_000, 64_000), pb=st.integers(1_000, 16_000))
@settings(max_examples=30, deadline=None)
def test_search_always_fits(M, N, K, ib, pb):
    op = matmul_op(M, N, K)
    buf = BufferSpec(input_bytes=ib, psum_bytes=pb)
    try:
        s = search_tiles(op, buf)
    except ValueError:
        return  # genuinely infeasible is acceptable
    assert s.input_bytes <= ib and s.psum_bytes <= pb
    assert all(1 <= s.tile[d.name] <= d.size for d in op.dims)


@given(M=dims, N=dims, K=dims, R=st.integers(1, 4), C=st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_sharing_never_increases_fetches(M, N, K, R, C):
    """FIFO-mesh exchange can only reduce global fetches (paper Fig. 2)."""
    op = matmul_op(M, N, K)
    s = search_tiles(op)
    plan = plan_mesh_exchange(op, s.tile, (R, C))
    assert plan.fetch_bytes <= plan.fetch_bytes_unshared
    # conservation: shared bytes moved over FIFOs instead of the GLB
    assert plan.fetch_bytes + plan.fifo_hop_bytes >= plan.fetch_bytes_unshared


@given(M=dims, N=dims, K=dims)
@settings(max_examples=20, deadline=None)
def test_grid_order_no_worse_than_lexicographic(M, N, K):
    op = matmul_op(M, N, K)
    s = search_tiles(op)
    best = order_grid_for_sharing(op, s.tile)
    lex = tuple(d.name for d in op.dims)
    assert best.total_fetch_bytes <= grid_fetch_bytes(op, s.tile, lex)


@given(Co=st.integers(8, 64), Ci=st.integers(4, 64),
       o=st.integers(8, 64), k=st.sampled_from([1, 3, 5, 7]))
@settings(max_examples=30, deadline=None)
def test_traffic_lower_bound_is_unique_data(Co, Ci, o, k):
    """No schedule fetches less than one pass over the unique data."""
    assume(o > k)
    op = conv2d_op(Co, Ci, o, o, k, k)
    s = search_tiles(op)
    t = traffic(op, s.tile, shared_axes=tuple(d.name for d in op.dims))
    full = op.full_tile()
    unique = sum(v.footprint_bytes(full) for v in op.inputs)
    assert t.input_fetch_bytes >= unique


@given(b=st.integers(1, 4), s=st.integers(4, 32), h=st.integers(1, 4),
       d=st.sampled_from([4, 8]))
@settings(max_examples=20, deadline=None)
def test_blocked_attention_equals_full(b, s, h, d):
    """Property: the flash-style blocked XLA attention == full softmax."""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.layers import _attention_blocked, _grouped_scores_full
    key = jax.random.PRNGKey(b * 1000 + s * 10 + h)
    q = jax.random.normal(key, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    full = _grouped_scores_full(q, k, v, causal=True, window=None)
    blocked = _attention_blocked(q, k, v, causal=True, window=None,
                                 q_chunk=4, k_chunk=8)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
