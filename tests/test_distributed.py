"""Distributed pieces on 8 virtual devices.

These spawn subprocesses because the device count must be fixed BEFORE jax
initializes (and the rest of the suite runs on 1 device per instructions).
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert p.returncode == 0, p.stdout + "\n" + p.stderr
    return p.stdout


def test_ring_matmul_and_baseline():
    """Forward vs the dense oracle, and the custom-VJP backward (dA
    output-stationary, dB circulating) vs the oracle's grads."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import compat
from repro.parallel.ring_matmul import ring_matmul, ring_matmul_ref, allgather_matmul
mesh = compat.make_mesh((2, 4), ("data", "model"))
a = jax.random.normal(jax.random.PRNGKey(0), (16, 32), jnp.float32)
b = jax.random.normal(jax.random.PRNGKey(1), (32, 24), jnp.float32)
with compat.set_mesh(mesh):
    out = ring_matmul(a, b, mesh, axis="model")
    out2 = allgather_matmul(a, b, mesh, axis="model")
ref = ring_matmul_ref(a, b)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(out2), np.asarray(ref), rtol=1e-4, atol=1e-4)
def loss(a, b):
    return (ring_matmul(a, b, mesh, axis="model").astype(jnp.float32) ** 2).sum()
def loss_ref(a, b):
    return (ring_matmul_ref(a, b).astype(jnp.float32) ** 2).sum()
with compat.set_mesh(mesh):
    da, db = jax.jit(jax.grad(loss, argnums=(0, 1)))(a, b)
da_r, db_r = jax.grad(loss_ref, argnums=(0, 1))(a, b)
np.testing.assert_allclose(np.asarray(da), np.asarray(da_r), rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(db), np.asarray(db_r), rtol=1e-4, atol=1e-4)
print("ring matmul fwd+grads ok")
""")


def test_ring_matmul_fewer_resident_bytes():
    """The paper's claim at chip scale: ring exchange never duplicates the
    full B operand in memory; the all-gather baseline does."""
    _run("""
import jax, jax.numpy as jnp
from repro.runtime import compat
from repro.parallel.ring_matmul import ring_matmul, allgather_matmul
mesh = compat.make_mesh((1, 8), ("data", "model"))
a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
b = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
with compat.set_mesh(mesh):
    ring = jax.jit(lambda a, b: ring_matmul(a, b, mesh, axis="model")).lower(a, b).compile()
    ag = jax.jit(lambda a, b: allgather_matmul(a, b, mesh, axis="model")).lower(a, b).compile()
rt = ring.memory_analysis().temp_size_in_bytes
at = ag.memory_analysis().temp_size_in_bytes
assert rt < at, (rt, at)
print("ring temp", rt, "< allgather temp", at)
""")


def test_pipeline_parallel_forward():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import compat
from repro.parallel.pipeline import pipeline_forward
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
def stage_fn(params, x):
    return jnp.tanh(x @ params["w"])
sp = {"w": jax.random.normal(jax.random.PRNGKey(2), (2, 8, 8), jnp.float32) * 0.5}
xm = jax.random.normal(jax.random.PRNGKey(3), (4, 3, 8), jnp.float32)
with compat.set_mesh(mesh):
    out = jax.jit(lambda p, x: pipeline_forward(stage_fn, p, x, mesh))(sp, xm)
ref = xm
for s in range(2):
    ref = jnp.tanh(ref @ sp["w"][s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)
""")


def test_moe_distribution_modes():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import compat
from repro.models.layers import MoEConfig, _moe_local, moe_layer
key = jax.random.PRNGKey(0); D = 12
mesh = compat.make_mesh((2, 4), ("data", "model"))
for E, S in [(8, 8), (8, 1), (6, 8), (6, 1)]:
    cfg = MoEConfig(n_experts=E, top_k=2, d_ff=16, capacity_factor=8.0)
    p = {
        "router": jax.random.normal(key, (D, E), jnp.float32) * 0.5,
        "w_gate": jax.random.normal(jax.random.fold_in(key,1), (E, D, 16), jnp.float32) * 0.3,
        "w_up": jax.random.normal(jax.random.fold_in(key,2), (E, D, 16), jnp.float32) * 0.3,
        "w_down": jax.random.normal(jax.random.fold_in(key,3), (E, 16, D), jnp.float32) * 0.3,
    }
    x = jax.random.normal(jax.random.fold_in(key,4), (4, S, D), jnp.float32)
    ref, _ = _moe_local(x, p, cfg)
    with compat.set_mesh(mesh):
        out, _ = jax.jit(lambda x, p: moe_layer(x, p, cfg))(x, p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
print("all moe modes ok")
""")


def test_sharded_train_step_matches_single_device():
    """The jit'd train step under a (2,4) mesh produces the same loss as the
    unsharded step — distribution must not change the math."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import compat
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_bundle
from repro.optim import adamw_init
from repro.parallel.sharding import param_specs
from repro.training import TrainHyper, make_train_step
bundle = get_bundle("qwen3-4b", smoke=True)
params = bundle.init_params(jax.random.PRNGKey(0))
opt = adamw_init(params)
k = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(k, (8, 16), 0, bundle.cfg.vocab),
         "labels": jax.random.randint(k, (8, 16), 0, bundle.cfg.vocab)}
step = make_train_step(bundle.forward, TrainHyper())
_, _, m_ref = jax.jit(step)(params, opt, batch)

mesh = compat.make_mesh((2, 4), ("data", "model"))
pspecs = param_specs(bundle.kind, params, mesh)
psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                   is_leaf=lambda x: isinstance(x, P))
with compat.set_mesh(mesh):
    params_s = jax.device_put(params, psh)
    opt_s = adamw_init(params_s)
    _, _, m_sh = jax.jit(step)(params_s, opt_s, batch)
assert abs(float(m_ref["ce"]) - float(m_sh["ce"])) < 2e-2, (float(m_ref["ce"]), float(m_sh["ce"]))
print("sharded ce", float(m_sh["ce"]), "ref", float(m_ref["ce"]))
""", timeout=560)


def test_compressed_gradient_psum():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import compat
from jax.sharding import PartitionSpec as P
from repro.optim.compression import ef_compressed_psum, init_error_feedback
mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
g = {"w": jax.random.normal(jax.random.PRNGKey(4), (8, 8), jnp.float32)}
e = init_error_feedback(g)
fn = compat.shard_map(lambda g, e: ef_compressed_psum(g, e, "pod"),
                   mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
with compat.set_mesh(mesh):
    rg, re = jax.jit(fn)(g, e)
err = np.abs(np.asarray(rg["w"]) - np.asarray(g["w"])).max()
amax = np.abs(np.asarray(g["w"])).max()
assert err <= amax / 127 + 1e-6
# error feedback: the residual equals what quantization dropped
np.testing.assert_allclose(np.asarray(re["w"]),
                           np.asarray(g["w"] - rg["w"]), rtol=1e-5, atol=1e-6)
""")


def test_ring_attention_matches_reference():
    """Both context-parallel modes ('replicated' B5 and 'ring' B6, selected
    via the policy argument — no module-global monkeypatching) match the
    full oracle; the replicated path's shard_map-AD grads still match."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import compat
from repro.models.layers import _attention_ring, _grouped_scores_full
mesh = compat.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
B, S, H, Dh = 4, 32, 8, 16
q = jax.random.normal(key, (B, S, H, Dh), jnp.float32)
k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, 2, Dh), jnp.float32)
v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, 2, Dh), jnp.float32)
ref = _grouped_scores_full(q, k, v, causal=True, window=None)
for mode in ("replicated", "ring"):
    with compat.set_mesh(mesh):
        out = jax.jit(lambda q, k, v: _attention_ring(q, k, v, causal=True, window=None, ring=mode))(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-4)
def loss(q, k, v):
    return (_attention_ring(q, k, v, causal=True, window=None, ring="replicated") ** 2).sum()
def loss_ref(q, k, v):
    return (_grouped_scores_full(q, k, v, causal=True, window=None) ** 2).sum()
with compat.set_mesh(mesh):
    g = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v)
g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
for a, b in zip(g, g_ref):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4)
print("ring attention ok")
""")


def test_ring_vjp_grads_match_dense():
    """The memory-flat ring custom VJP: dq/dk/dv vs dense XLA attention
    grads for causal, sliding-window, GQA and non-causal cases (fp32
    tolerance on the 8-device host mesh) — the §Perf B6 acceptance."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import compat
from repro.parallel.ring_attention import ring_attention
from repro.models.layers import _grouped_scores_full
mesh = compat.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
cases = [
    (4, 32, 8, 2, 16, True, None),    # GQA (G=4), causal
    (4, 32, 8, 8, 16, True, 8),       # MHA, sliding window
    (2, 64, 4, 2, 8, True, 12),       # GQA + window
    (2, 64, 4, 4, 8, False, None),    # non-causal, unmasked
]
for B, S, H, Hkv, Dh, causal, window in cases:
    q = jax.random.normal(key, (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, Dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, Dh), jnp.float32)
    ref = _grouped_scores_full(q, k, v, causal=causal, window=window)
    def loss(q, k, v):
        return (ring_attention(q, k, v, causal=causal, window=window).astype(jnp.float32) ** 2).sum()
    def loss_ref(q, k, v):
        return (_grouped_scores_full(q, k, v, causal=causal, window=window).astype(jnp.float32) ** 2).sum()
    with compat.set_mesh(mesh):
        out = jax.jit(lambda q, k, v: ring_attention(q, k, v, causal=causal, window=window))(q, k, v)
        g = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-4)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(g, g_ref, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4,
            err_msg=f"d{nm} causal={causal} window={window} Hkv={Hkv}")
    print("ok", B, S, H, Hkv, causal, window)
print("ring vjp grads match dense")
""")


def test_ring_vjp_saves_no_score_tiles():
    """Saved-residual-size assertion: the naive differentiated ring keeps
    the stacked per-hop score tiles (an f32[m, B/d, Hkv, G, S/m, S/m]
    buffer in its backward HLO); the custom-VJP backward must have no f32
    buffer that large, and a smaller XLA temp arena."""
    _run("""
import re
import jax, jax.numpy as jnp
from repro.runtime import compat
from repro.parallel.ring_attention import ring_attention
mesh = compat.make_mesh((2, 4), ("data", "model"))
B, S, H, Hkv, Dh = 4, 64, 4, 2, 8      # B_l=2, S_l=16, G=2, m=4
key = jax.random.PRNGKey(0)
q = jax.random.normal(key, (B, S, H, Dh), jnp.float32)
k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, Dh), jnp.float32)
v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, Dh), jnp.float32)
m, B_l, S_l, G = 4, 2, 16, 2
stack_elems = m * B_l * Hkv * G * S_l * S_l

def max_f32_elems(txt):
    best = 0
    for mt in re.finditer(r"f32\\[([\\d,]+)\\]", txt):
        n = 1
        for d in mt.group(1).split(","):
            n *= int(d)
        best = max(best, n)
    return best

stats = {}
for impl in ("naive", "vjp"):
    def loss(q, k, v):
        return (ring_attention(q, k, v, causal=True, window=None, impl=impl).astype(jnp.float32) ** 2).sum()
    with compat.set_mesh(mesh):
        comp = jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2))).lower(q, k, v).compile()
    stats[impl] = (max_f32_elems(comp.as_text()),
                   compat.memory_stats(comp)["temp_bytes"])
# detector sanity: the naive backward DOES stack one tile per hop...
assert stats["naive"][0] >= stack_elems, stats
# ...and the custom VJP retains no buffer anywhere near the stack
assert stats["vjp"][0] < stack_elems, stats
assert stats["vjp"][1] < stats["naive"][1], stats
print("no score tiles saved:", stats)
""")


def test_ring_is_default_long_seq_path():
    """Policy wiring: with the default 'auto' policy, attention() routes
    long sequences through the ppermute ring (the jaxpr carries ppermute
    collectives); REPRO_RING_ATTN=off routes back to the constraint
    path.  The threshold env shrinks 'long' to test-sized sequences."""
    _run("""
import os
import jax, jax.numpy as jnp
from repro.runtime import compat
from repro.models.layers import attention
mesh = compat.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
q = jax.random.normal(key, (4, 64, 8, 16), jnp.float32)
k = jax.random.normal(key, (4, 64, 2, 16), jnp.float32)
v = jax.random.normal(key, (4, 64, 2, 16), jnp.float32)
os.environ["REPRO_RING_ATTN_THRESHOLD"] = "64"
def jaxpr(q, k, v):
    with compat.set_mesh(mesh):
        return str(jax.make_jaxpr(
            lambda q, k, v: attention(q, k, v, causal=True, full_threshold=32))(q, k, v))
assert "ppermute" in jaxpr(q, k, v)            # default auto -> ring
os.environ["REPRO_RING_ATTN_THRESHOLD"] = "128"
assert "ppermute" not in jaxpr(q, k, v)        # below threshold -> replicated
os.environ["REPRO_RING_ATTN"] = "ring"
assert "ppermute" in jaxpr(q, k, v)            # forced ring beats threshold
os.environ["REPRO_RING_ATTN"] = "off"
assert "ppermute" not in jaxpr(q, k, v)
print("ring default-path policy ok")
""")


def test_paged_pool_sharded_across_mesh():
    """The serving block pool lives across the mesh: pool pages carry the
    paged_pool_specs sharding and the paged engine still emits the same
    greedy tokens as the single-host dense engine."""
    _run("""
import jax, numpy as np
from repro.runtime import compat
from repro.launch.serve import build_engine

mesh = compat.make_mesh((2, 4), ("data", "model"))
prompts = [np.arange(4 + 3 * i, dtype=np.int32) % 96 for i in range(4)]

dense, _ = build_engine("qwen3-4b", slots=2, max_len=48, max_new=4)
for p in prompts:
    dense.submit(p)
ref = dense.run()

paged, _ = build_engine("qwen3-4b", slots=2, max_len=48, max_new=4,
                        kv_mode="paged", page_size=8, mesh=mesh)
for p in prompts:
    paged.submit(p)
out = paged.run()
shardings = {k: v.sharding for k, v in paged.pool.items()}
assert any(s.is_fully_replicated is False for s in shardings.values()), shardings
assert out == ref, (out, ref)
print("paged pool sharded ok")
""")


def test_ring_fused_pallas_hop_matches_einsum():
    """The fused per-hop fold (Pallas flash kernels inside both ring
    passes, traced axis-index offsets through the scalar-prefetch
    operand) is numerically the einsum fold: outputs and dq/dk/dv grads
    match for causal, windowed and GQA cases on the 8-device host mesh
    (interpret mode — the compiled-Mosaic run is a ROADMAP item)."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.runtime import compat
from repro.parallel.ring_attention import ring_attention
mesh = compat.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(3)
cases = [
    (2, 64, 4, 4, 16, True, None),    # MHA causal
    (2, 64, 8, 2, 16, True, 24),      # GQA + sliding window
    (2, 64, 4, 2, 8, False, None),    # non-causal GQA
]
for B, S, H, Hkv, Dh, causal, window in cases:
    q = jax.random.normal(key, (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, Dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, Dh), jnp.float32)
    def loss(q, k, v, fused):
        return (ring_attention(q, k, v, causal=causal, window=window, fused=fused).astype(jnp.float32) ** 2).sum()
    with compat.set_mesh(mesh):
        o_e = jax.jit(lambda q, k, v: ring_attention(q, k, v, causal=causal, window=window, fused=False))(q, k, v)
        o_f = jax.jit(lambda q, k, v: ring_attention(q, k, v, causal=causal, window=window, fused=True))(q, k, v)
        g_e = jax.jit(jax.grad(lambda q, k, v: loss(q, k, v, False), argnums=(0, 1, 2)))(q, k, v)
        g_f = jax.jit(jax.grad(lambda q, k, v: loss(q, k, v, True), argnums=(0, 1, 2)))(q, k, v)
    np.testing.assert_allclose(np.asarray(o_f), np.asarray(o_e), rtol=3e-4, atol=3e-4)
    for a, b, nm in zip(g_f, g_e, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4,
                                   err_msg=f"d{nm} causal={causal} window={window}")
    print("ok", B, S, H, Hkv, causal, window)
print("fused ring hop matches einsum fold")
""")
