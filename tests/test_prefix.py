"""Property/differential tests for the radix prefix cache + refcounted pool.

Host-side only (the prefix cache and block pool are deliberately jax-free)
so hundreds of random lifecycles run in milliseconds.  The invariants under
test, maintained across ANY interleaving of insert / match / map_shared /
ensure / advance / free_slot / evict:

  * every page's refcount equals its slot-table mappings plus its trie
    references (``BlockPoolKV.check_invariants(external_refs=...)``);
  * free pages + referenced pages partition the pool exactly (no leak, no
    double-free, trash page 0 never circulates);
  * no trie node outlives its page's refcount — eviction only ever drops
    pages the trie alone holds (refcount 1);
  * copy-on-write never mutates a shared page: the COW destination is
    always a PRIVATE page (refcount 1) and a slot's write positions never
    reach its read-only shared prefix.

The hypothesis suite (skipped when hypothesis is not installed — CI
installs it, the pinned-jax images may not) drives the same model with
minimized counterexamples; the seeded-numpy sweep below always runs.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.serving.kv import BlockPoolKV, PagedKVConfig
from repro.serving.prefix import RadixPrefixCache

PAGE = 4


def _pool(num_pages=16, num_slots=3, page_size=PAGE, max_len=64):
    kv = BlockPoolKV(PagedKVConfig(
        num_slots=num_slots, max_len=max_len, page_size=page_size,
        num_pages=num_pages))
    return kv, RadixPrefixCache(kv)


def _cache_seq(kv, pc, tokens, slot=0):
    """Cold-path lifecycle: compute ``tokens`` into ``slot`` and adopt the
    pages into the trie (what the engine does on request finish)."""
    kv.ensure(slot, len(tokens))
    kv.advance(slot, len(tokens))
    adopted = pc.insert(tokens, kv.slot_pages(slot), len(tokens))
    kv.free_slot(slot)
    pc.check_invariants()
    return adopted


# ---------------------------------------------------------------------------
# unit: match / insert / COW planning
# ---------------------------------------------------------------------------

def test_match_full_pages_and_mid_page_cow():
    kv, pc = _pool()
    seq = list(range(8))                       # two full pages
    assert _cache_seq(kv, pc, seq) == 2
    # prompt strictly longer: both pages match in full
    m = pc.match(seq + [99])
    assert len(m.full_pages) == 2 and m.matched == 8 and m.cow is None
    # prompt == cached seq: the last token must be recomputed, so only one
    # full page matches and the second is served by COW (3 valid tokens)
    m = pc.match(seq)
    assert len(m.full_pages) == 1 and m.matched_full == 4
    assert m.cow is not None and m.cow[1] == 3
    assert m.matched == 7
    # divergence inside page 2 -> COW with the common-overlap length
    m = pc.match([0, 1, 2, 3, 4, 5, 77, 88, 99])
    assert m.matched_full == 4 and m.cow[1] == 2
    # cold prompt: miss
    assert not pc.match([42, 43, 44, 45, 46]).hit
    assert pc.stats()["hits"] == 3


def test_match_never_covers_whole_prompt():
    kv, pc = _pool()
    _cache_seq(kv, pc, list(range(12)))
    for n in (2, 5, 8, 12):
        m = pc.match(list(range(n)))
        assert m.matched < n        # >= 1 token always left to prefill


def test_insert_dedup_and_partial_tail_subsumption():
    kv, pc = _pool()
    seq = list(range(10))
    _cache_seq(kv, pc, seq)                        # 2 full + 1 partial(2)
    assert pc.n_pages == 3
    # identical sequence from another request: nothing new to adopt, and
    # the duplicate slot pages go back to the free list on release
    free_before = kv.free_pages
    assert _cache_seq(kv, pc, seq, slot=1) == 0
    assert kv.free_pages == free_before and pc.n_pages == 3
    # a shorter partial tail subsumed by the cached one is also skipped
    assert _cache_seq(kv, pc, list(range(9)), slot=2) == 0
    # but a LONGER partial tail is a distinct node alongside it
    assert _cache_seq(kv, pc, list(range(11)), slot=1) == 1
    pc.check_invariants()


def test_evict_lru_leaf_first():
    kv, pc = _pool(num_pages=32)
    a = list(range(0, 8))                      # branch A, 2 pages
    b = list(range(8, 20))                     # branch B, 3 pages
    _cache_seq(kv, pc, a)
    _cache_seq(kv, pc, b, slot=1)
    pc.match(a + [99])                         # touch A: B becomes LRU
    held = pc.n_pages
    assert pc.evict(1) == 1                    # B's LEAF page goes first
    assert pc.n_pages == held - 1
    assert pc.match(b[:9]).matched_full == 8   # B's first 2 pages survive
    # drain everything: leaf-first along cold paths, A last
    assert pc.evict(100) == held - 1
    assert pc.n_pages == 0 and kv.free_pages == kv.cfg.total_pages - 1
    pc.check_invariants()


def test_evict_skips_pages_mapped_by_live_slots():
    kv, pc = _pool()
    seq = list(range(8))
    _cache_seq(kv, pc, seq)
    m = pc.match(seq + [99])
    kv.map_shared(1, list(m.full_pages))       # live slot maps both pages
    assert pc.evict(10) == 0                   # nothing evictable
    kv.free_slot(1)
    assert pc.evict(10) == 2                   # now the trie alone holds them
    pc.check_invariants()


def test_reserve_drains_trie_through_reclaim_hook():
    kv, pc = _pool(num_pages=9)                # 8 usable
    _cache_seq(kv, pc, list(range(16)))        # trie holds 4 pages
    _cache_seq(kv, pc, list(range(100, 116)))  # + 4 more: pool exhausted
    assert kv.free_pages == 0
    assert kv.reserve(3)                       # hook evicts cold leaves
    assert kv.free_pages >= 3
    assert kv.reserve(8)                       # drains the whole cache
    assert pc.n_pages == 0
    pc.check_invariants()


def test_ensure_reclaims_before_memory_error():
    kv, pc = _pool(num_pages=9)
    _cache_seq(kv, pc, list(range(32)))        # 8 pages, all trie-held
    kv.ensure(0, 12)                           # needs 3: evicts, no raise
    assert len(kv.slot_pages(0)) == 3
    pc.check_invariants()
    kv.free_slot(0)


def test_cow_source_pin_survives_reclaim():
    kv, pc = _pool(num_pages=9)
    seq = list(range(8))
    _cache_seq(kv, pc, seq)
    m = pc.match(seq)                          # full page + COW(page2, 3)
    src = m.cow[0]
    kv.retain(src)                             # admission pins the source
    assert kv.reserve(8) is False              # reclaim evicts all it can
    assert kv.refcount[src] >= 1               # ...but not the pinned page
    kv.release(src)
    pc.check_invariants()


# ---------------------------------------------------------------------------
# randomized model: full request lifecycles against the pool + trie
# ---------------------------------------------------------------------------

class _Model:
    """Drives BlockPoolKV + RadixPrefixCache exactly as the scheduler and
    engine do (pin matched pages, map shared, COW into the first private
    page, advance, finish-with-insert), checking every invariant after
    every operation."""

    VOCAB = 3        # tiny vocab -> heavy prefix collisions

    def __init__(self, rng, num_pages, num_slots=3):
        self.rng = rng
        self.kv, self.pc = _pool(num_pages=num_pages, num_slots=num_slots,
                                 max_len=32)
        self.live = {}                         # slot -> token list
        self.num_slots = num_slots

    def check(self):
        self.pc.check_invariants()

    def op_admit(self):
        free = [s for s in range(self.num_slots) if s not in self.live]
        if not free:
            return
        slot = free[0]
        n = int(self.rng.integers(2, 17))
        tokens = self.rng.integers(0, self.VOCAB, n).tolist()
        kv, pc = self.kv, self.pc
        m = pc.match(tokens)
        shared = list(m.full_pages)
        pinned = shared + ([m.cow[0]] if m.cow else [])
        for p in pinned:
            kv.retain(p)
        need = kv.pages_for(n) - len(shared) + 1
        if not kv.reserve(need):
            for p in pinned:
                kv.release(p)
            self.check()
            return
        if shared:
            kv.map_shared(slot, shared)
            # the shared prefix is strictly before any write position
            assert len(shared) * PAGE <= m.matched
        kv.ensure(slot, n + PAGE)
        kv.set_length(slot, m.matched)
        if m.cow is not None:
            # COW destination = first private page: must be exclusive
            dst = int(kv.page_table[slot, len(shared)])
            assert kv.refcount[dst] == 1, "COW would write a shared page"
            assert dst != m.cow[0]
        for p in pinned:
            kv.release(p)
        kv.advance(slot, n - m.matched)        # suffix prefill
        self.live[slot] = tokens
        self.check()

    def op_decode(self):
        if not self.live:
            return
        slot = int(self.rng.choice(list(self.live)))
        kv = self.kv
        tok = int(self.rng.integers(0, self.VOCAB))
        try:
            kv.ensure(slot, int(kv.lengths[slot]) + 1)
        except MemoryError:
            # page pressure with everything pinned by live slots: the
            # scheduler would preempt; the model just drops the request
            kv.free_slot(slot, evicted=True)
            del self.live[slot]
            self.check()
            return
        kv.advance(slot, 1)
        self.live[slot].append(tok)
        self.check()

    def op_finish(self):
        if not self.live:
            return
        slot = int(self.rng.choice(list(self.live)))
        kv, pc = self.kv, self.pc
        n = int(kv.lengths[slot])
        pc.insert(self.live[slot][:n], kv.slot_pages(slot), n)
        kv.free_slot(slot)
        del self.live[slot]
        self.check()

    def op_evict_request(self):
        if not self.live:
            return
        slot = int(self.rng.choice(list(self.live)))
        self.kv.free_slot(slot, evicted=True)
        del self.live[slot]
        self.check()

    def op_reclaim(self):
        self.pc.evict(int(self.rng.integers(1, 4)))
        self.check()

    def run(self, steps):
        ops = [self.op_admit, self.op_admit, self.op_decode, self.op_decode,
               self.op_finish, self.op_evict_request, self.op_reclaim]
        for _ in range(steps):
            ops[int(self.rng.integers(0, len(ops)))]()
        # teardown: everything drains back to an empty pool
        for slot in list(self.live):
            self.kv.free_slot(slot)
        self.live.clear()
        self.pc.evict(10 ** 6)
        assert self.pc.n_pages == 0
        assert self.kv.free_pages == self.kv.cfg.total_pages - 1
        self.check()


def test_random_lifecycles_seeded_sweep():
    """200+ random insert/match/COW/advance/release/evict sequences (the
    always-on counterpart of the hypothesis suite below)."""
    for seed in range(200):
        rng = np.random.default_rng(seed)
        _Model(rng, num_pages=int(rng.integers(8, 28))).run(steps=50)


def test_random_lifecycles_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=200, deadline=None)
    @hyp.given(seed=st.integers(0, 2 ** 31 - 1),
               num_pages=st.integers(8, 40),
               steps=st.integers(1, 80))
    def drive(seed, num_pages, steps):
        _Model(np.random.default_rng(seed), num_pages=num_pages).run(steps)

    drive()


def test_refcount_misuse_raises():
    kv, pc = _pool()
    with pytest.raises(ValueError):
        kv.retain(BlockPoolKV.TRASH)
    with pytest.raises(ValueError):
        kv.retain(3)                           # unallocated
    with pytest.raises(ValueError):
        kv.release(3)
    kv.ensure(0, 4)
    page = kv.slot_pages(0)[0]
    kv.retain(page)                            # trie-style second ref
    assert kv.free_slot(0) == 0                # still referenced: not freed
    assert kv.release(page)                    # last ref -> free list
    kv.check_invariants()
