"""Paged KV serving subsystem: block pool, paged kernel, scheduler, engine.

Covers the acceptance checklist of the paged-serving PR: paged-vs-dense
decode equivalence, block-pool alloc/free/evict invariants (hypothesis),
preemption of low-priority work by a high-priority late arrival under page
pressure, the paged flash-decode kernel against its pure-JAX oracle, and
the slot-write layout regression (cache entries whose batch axis is NOT
axis 1)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import (BlockPoolKV, PagedKVConfig, Phase, PhaseScheduler,
                           RadixPrefixCache, Request, SchedulerConfig,
                           ServeConfig, ServingEngine)


# ---------------------------------------------------------------------------
# paged flash-decode kernel vs oracle
# ---------------------------------------------------------------------------

def _pool_setup(seed=0, B=3, H=8, Hkv=2, Dh=32, P=12, pg=16, MP=4):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(P, pg, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(P, pg, Hkv, Dh)), jnp.float32)
    pt = jnp.asarray([[1, 2, 3, 0], [4, 5, 0, 0], [6, 7, 8, 9]], jnp.int32)
    lens = jnp.asarray([40, 17, 64], jnp.int32)
    return q, k, v, pt, lens


def test_paged_kernel_matches_ref():
    from repro.kernels import ops
    from repro.kernels.ref import paged_decode_ref
    q, k, v, pt, lens = _pool_setup()
    out = ops.paged_flash_decode(q, k, v, pt, lens)
    ref = paged_decode_ref(q, k, v, pt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_paged_kernel_int8_matches_ref():
    from repro.kernels import ops
    from repro.kernels.ref import paged_decode_ref
    q, _, _, pt, lens = _pool_setup()
    rng = np.random.default_rng(1)
    P, pg, Hkv, Dh = 12, 16, 2, 32
    kq = jnp.asarray(rng.integers(-127, 127, (P, pg, Hkv, Dh)), jnp.int8)
    vq = jnp.asarray(rng.integers(-127, 127, (P, pg, Hkv, Dh)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.02, (P, pg, Hkv)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.01, 0.02, (P, pg, Hkv)), jnp.float32)
    out = ops.paged_flash_decode(q, kq, vq, pt, lens, ks, vs)
    ref = paged_decode_ref(q, kq, vq, pt, lens, ks, vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_paged_attention_trash_page_isolated():
    """Pages beyond a slot's length (incl. trash page 0) never leak into
    the output: doubling garbage in unmapped pages leaves results bitwise
    identical."""
    from repro.kernels import ops
    q, k, v, pt, lens = _pool_setup()
    out1 = ops.paged_flash_decode(q, k, v, pt, lens)
    k2 = k.at[0].mul(2.0).at[10, :, :, :].add(7.0)   # trash + unmapped page
    v2 = v.at[0].mul(-3.0).at[11, :, :, :].add(1.0)
    out2 = ops.paged_flash_decode(q, k2, v2, pt, lens)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------

def _kvcfg(**kw):
    base = dict(num_slots=4, max_len=64, page_size=8, num_pages=17)
    base.update(kw)
    return PagedKVConfig(**base)


def test_block_pool_basics():
    kv = BlockPoolKV(_kvcfg())
    assert kv.free_pages == 16
    kv.ensure(0, 20)                       # 3 pages
    kv.advance(0, 20)
    assert kv.used_pages == 3 and kv.capacity(0) == 24
    st = kv.stats()
    assert st["tokens_resident"] == 20
    assert st["bytes_resident"] == 3 * kv.cfg.page_bytes
    assert 0.0 < st["fragmentation"] < 1.0
    kv.check_invariants()
    kv.free_slot(0)
    assert kv.free_pages == 16 and kv.capacity(0) == 0
    kv.check_invariants()


def test_block_pool_dry_raises():
    kv = BlockPoolKV(_kvcfg(num_pages=4))   # 3 usable
    kv.ensure(0, 24)
    with pytest.raises(MemoryError):
        kv.ensure(1, 8)
    kv.check_invariants()


def test_block_pool_property_random_ops():
    pytest.importorskip("hypothesis")  # optional (requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    ops_strategy = st.lists(
        st.tuples(st.sampled_from(["ensure", "advance", "free"]),
                  st.integers(0, 3), st.integers(1, 64)),
        min_size=1, max_size=60)

    @given(ops=ops_strategy)
    @settings(max_examples=80, deadline=None)
    def run(ops):
        kv = BlockPoolKV(_kvcfg())
        for op, slot, n in ops:
            if op == "ensure":
                try:
                    kv.ensure(slot, n)
                except MemoryError:
                    pass
            elif op == "advance":
                room = kv.capacity(slot) - int(kv.lengths[slot])
                if room > 0:
                    kv.advance(slot, min(n, room))
            else:
                kv.free_slot(slot)
            # the PR's property: alloc/free/evict never double-assigns a
            # page, never allocates trash, never leaks
            kv.check_invariants()

    run()


# ---------------------------------------------------------------------------
# scheduler: phases + preemption
# ---------------------------------------------------------------------------

def _req(rid, n_prompt, prio, max_new=8):
    return Request(rid=rid, prompt=np.zeros(n_prompt, np.int32),
                   priority=prio, arrival=rid, max_new_tokens=max_new)


def test_scheduler_high_priority_late_arrival_preempts():
    """Two low-priority requests hold the whole pool in DECODE; a
    high-priority arrival evicts the lowest/latest one and is admitted."""
    kv = BlockPoolKV(_kvcfg(num_slots=2, num_pages=7))   # 6 usable pages
    sched = PhaseScheduler(SchedulerConfig(num_slots=2))
    lo0, lo1 = _req(0, 16, prio=0), _req(1, 16, prio=0)
    sched.submit(lo0)
    sched.submit(lo1)
    assert len(sched.admit(kv)) == 2                     # 3 pages each
    for r in (lo0, lo1):
        kv.advance(r.slot, 16)
        r.prefill_pos = 16
        r.phase = Phase.DECODE
        r.generated = [7]
    assert kv.free_pages == 0

    hi = _req(2, 16, prio=5)
    sched.submit(hi)
    admitted = sched.admit(kv)
    assert admitted == [hi] and hi.phase is Phase.PREFILL
    # the LATEST low-priority arrival was evicted back to waiting with its
    # generated token folded into the prompt for recompute
    assert lo1.phase is Phase.WAITING and lo1.preemptions == 1
    assert lo1.history == [7] and len(lo1.prompt) == 17
    assert lo0.phase is Phase.DECODE                    # survivor
    assert kv.stats()["evictions"] == 1
    kv.check_invariants()


def test_scheduler_no_preemption_of_equal_or_higher_priority():
    kv = BlockPoolKV(_kvcfg(num_slots=2, num_pages=7))
    sched = PhaseScheduler(SchedulerConfig(num_slots=2))
    a, b = _req(0, 16, prio=3), _req(1, 16, prio=3)
    sched.submit(a)
    sched.submit(b)
    sched.admit(kv)
    c = _req(2, 16, prio=3)                             # equal priority
    sched.submit(c)
    assert sched.admit(kv) == []                        # must wait
    assert a.preemptions == b.preemptions == 0


def test_decode_page_pressure_self_evicts_not_equal_peer():
    """When a decoding slot needs its next page and only EQUAL-priority
    peers are active, it evicts itself — peers are never targeted."""
    kv = BlockPoolKV(_kvcfg(num_slots=2, num_pages=5))   # 4 usable pages
    sched = PhaseScheduler(SchedulerConfig(num_slots=2,
                                           decode_headroom_pages=0))
    a, b = _req(0, 16, prio=2), _req(1, 16, prio=2)
    sched.submit(a)
    sched.submit(b)
    sched.admit(kv)                                      # 2 pages each
    for r in (a, b):
        kv.advance(r.slot, 16)
        r.prefill_pos = 16
        r.phase = Phase.DECODE
        r.generated = [1]
    assert kv.free_pages == 0
    evicted = sched.ensure_decode_pages(kv)              # a needs page 3
    assert a in evicted and a.phase is Phase.WAITING
    assert b.phase is Phase.DECODE and b.preemptions == 0
    kv.check_invariants()


def test_scheduler_prefill_budget_chunks():
    kv = BlockPoolKV(_kvcfg(num_slots=4, num_pages=33, max_len=128))
    cfg = SchedulerConfig(num_slots=4, prefill_chunk=16,
                          prefill_token_budget=24)
    sched = PhaseScheduler(cfg)
    long_req, short_req = _req(0, 40, 0), _req(1, 8, 0)
    sched.submit(long_req)
    sched.submit(short_req)
    sched.admit(kv)
    jobs = sched.prefill_jobs()
    # one chunk per request per tick, budget-capped: 16 (long) + 8 (short)
    assert [(j.req.rid, j.count) for j in jobs] == [(0, 16), (1, 8)]
    for j in jobs:
        sched.finish_prefill_chunk(j.req, j.count)
    assert short_req.phase is Phase.DECODE
    assert long_req.phase is Phase.PREFILL and long_req.prefill_pos == 16


# ---------------------------------------------------------------------------
# engine: dense/paged equivalence + slot-write layout
# ---------------------------------------------------------------------------

def _serve(arch, kv_mode, prompts, **kw):
    from repro.launch.serve import build_engine
    engine, vocab = build_engine(arch, slots=2, max_len=48, max_new=6,
                                 kv_mode=kv_mode, page_size=8, **kw)
    for p in prompts:
        engine.submit(p)
    return engine.run(), engine


def _prompts(vocab=256, n=5, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, int(n_)).astype(np.int32)
            for n_ in rng.integers(4, 20, n)]


def test_paged_vs_dense_equivalence():
    """Same prompts, same seeds -> identical greedy tokens from the dense
    slot engine and the paged block-pool engine (and its int8 variant must
    produce full-length outputs too)."""
    prompts = _prompts()
    dense, _ = _serve("qwen3-4b", "dense", prompts)
    paged, eng = _serve("qwen3-4b", "paged", prompts)
    assert dense == paged
    assert eng.kv_stats()["peak_bytes"] > 0
    int8, _ = _serve("qwen3-4b", "paged_int8", prompts)
    assert sorted(int8) == sorted(dense)
    assert all(len(v) == 6 for v in int8.values())


def test_engine_preemption_under_page_pressure():
    """Decode growth past the admission reservation triggers eviction of
    the lowest-priority request; everyone still completes."""
    from repro.launch.serve import build_engine
    engine, vocab = build_engine("qwen3-4b", slots=3, max_len=64,
                                 max_new=16, kv_mode="paged", page_size=8,
                                 num_pages=11)
    rng = np.random.default_rng(1)
    for prio in (0, 0, 5):
        engine.submit(rng.integers(0, vocab, 12).astype(np.int32),
                      priority=prio)
    res = engine.run()
    assert len(res) == 3 and all(len(v) == 16 for v in res.values())
    assert engine.kv_stats()["evictions"] >= 1
    assert engine._requests[2].preemptions == 0   # high priority survives


def test_write_slot_uses_declared_batch_axes():
    """Regression for the seed's hardwired (L, B, ...) slot-write layout:
    a cache entry with batch at axis 2 (recurrentgemma's grouped states)
    round-trips correctly when the bundle declares its axes."""

    class DeclaredBundle:
        def cache_batch_axes(self, cache):
            return {"weird": 2, "k": 1, "length": 0}

    eng = ServingEngine.__new__(ServingEngine)     # no model needed
    eng.bundle = DeclaredBundle()
    eng._cache_axes = None
    cache = {
        "weird": jnp.zeros((2, 3, 4, 5)),          # batch axis 2 (size 4)
        "k": jnp.zeros((2, 4, 6)),                 # batch axis 1
        "length": jnp.zeros((4,), jnp.int32),
    }
    one = {
        "weird": jnp.ones((2, 3, 1, 5)) * 7,
        "k": jnp.ones((2, 1, 6)) * 3,
        "length": jnp.asarray([9], jnp.int32),
    }
    out = eng._write_slot(cache, one, 2)
    np.testing.assert_array_equal(np.asarray(out["weird"][:, :, 2]), 7.0)
    np.testing.assert_array_equal(np.asarray(out["weird"][:, :, 1]), 0.0)
    np.testing.assert_array_equal(np.asarray(out["k"][:, 2]), 3.0)
    np.testing.assert_array_equal(np.asarray(out["k"][:, 0]), 0.0)
    assert int(out["length"][2]) == 9 and int(out["length"][0]) == 0


def test_serving_recurrentgemma_grouped_states():
    """The family whose cache layout violates the old axis-1 assumption
    now serves through the pooled engine (declared CACHE_BATCH_AXES)."""
    from repro.launch.serve import run as serve_run
    results = serve_run("recurrentgemma-9b", smoke=True, n_requests=3,
                        slots=2, prompt_len=6, max_new=4, max_len=32)
    assert len(results) == 3
    assert all(len(v) == 4 for v in results.values())


def test_dense_prefill_bucketing_trace_reuse():
    """Length-bucketed prefill: distinct prompt lengths within one bucket
    share a single jit trace (the seed retraced per length)."""
    from repro.launch.serve import build_engine
    engine, vocab = build_engine("qwen3-4b", slots=2, max_len=64, max_new=2)
    rng = np.random.default_rng(0)
    for n in (5, 6, 7, 8):                  # one bucket (8)
        engine.submit(rng.integers(0, vocab, n).astype(np.int32))
    engine.run()
    n_traces = engine._prefill._cache_size()
    assert n_traces == 1, n_traces


def test_paged_pool_specs_shapes():
    from repro.parallel.sharding import paged_pool_specs
    from repro.runtime import compat
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    specs = paged_pool_specs(mesh, kv_heads=4, head_dim=64)
    assert set(specs) >= {"k", "v", "k_scale", "v_scale", "page_table",
                          "lengths"}
    assert len(specs["k"]) == 5 and len(specs["k_scale"]) == 4


# ---------------------------------------------------------------------------
# graceful degradation: deadlines, admission retry/shed, load-shed mode
# ---------------------------------------------------------------------------

def test_paged_deadline_evicts_but_engine_keeps_serving():
    """A request whose deadline passes mid-decode is evicted with its
    partial output (outcome "timeout") while the other request runs to
    completion — one stuck request cannot hold pages forever."""
    from repro.launch.serve import build_engine
    engine, vocab = build_engine("qwen3-4b", slots=2, max_len=48,
                                 max_new=10, kv_mode="paged", page_size=8)
    rng = np.random.default_rng(0)
    doomed = engine.submit(rng.integers(0, vocab, 8).astype(np.int32),
                           deadline=4)
    healthy = engine.submit(rng.integers(0, vocab, 8).astype(np.int32))
    res = engine.run()
    assert engine.outcomes == {doomed: "timeout", healthy: "ok"}
    assert 0 < len(res[doomed]) < 10               # partial output kept
    assert len(res[healthy]) == 10
    engine.kv.check_invariants()                   # pages were returned
    stats = engine.degradation_stats()
    assert stats["timeout"] == 1 and stats["ok"] == 1


def test_admission_backoff_terminates_without_deadlock():
    """With retry/backoff configured, a request that cannot fit yet stops
    blocking the queue head, retries with exponential hold-off, and still
    completes once capacity frees — no shed, no deadlock."""
    from repro.launch.serve import build_engine
    engine, vocab = build_engine("qwen3-4b", slots=2, max_len=48,
                                 max_new=6, kv_mode="paged", page_size=8,
                                 num_pages=7, max_admission_retries=0,
                                 admission_backoff=1)
    rng = np.random.default_rng(0)
    for prio in (5, 5, 0):                         # third can't fit at first
        engine.submit(rng.integers(0, vocab, 16).astype(np.int32),
                      priority=prio)
    res = engine.run()
    assert len(res) == 3 and all(len(v) == 6 for v in res.values())
    assert set(engine.outcomes.values()) == {"ok"}


def test_admission_retry_budget_sheds():
    """When the retry budget blows before capacity frees, the request is
    SHED (outcome "shed", empty output) instead of waiting forever; the
    admitted work is unaffected."""
    from repro.launch.serve import build_engine
    engine, vocab = build_engine("qwen3-4b", slots=2, max_len=64,
                                 max_new=24, kv_mode="paged", page_size=8,
                                 num_pages=11, max_admission_retries=2,
                                 admission_backoff=1)
    rng = np.random.default_rng(0)
    a = engine.submit(rng.integers(0, vocab, 16).astype(np.int32), priority=5)
    b = engine.submit(rng.integers(0, vocab, 16).astype(np.int32), priority=5)
    c = engine.submit(rng.integers(0, vocab, 40).astype(np.int32), priority=0)
    res = engine.run()
    assert engine.outcomes[c] == "shed" and res[c] == []
    assert engine.outcomes[a] == engine.outcomes[b] == "ok"
    assert len(res[a]) == 24 and len(res[b]) == 24


def test_load_shed_mode_under_sustained_pool_pressure():
    """When the page pool stays critical for `shed_patience` consecutive
    ticks, waiting sub-priority work is dropped wholesale; requests
    already holding pages keep running."""
    from repro.launch.serve import build_engine
    engine, vocab = build_engine("qwen3-4b", slots=2, max_len=64,
                                 max_new=24, kv_mode="paged", page_size=8,
                                 num_pages=7, shed_pressure=0.9,
                                 shed_patience=2, shed_min_priority=1)
    rng = np.random.default_rng(0)
    a = engine.submit(rng.integers(0, vocab, 16).astype(np.int32), priority=5)
    b = engine.submit(rng.integers(0, vocab, 16).astype(np.int32), priority=5)
    c = engine.submit(rng.integers(0, vocab, 16).astype(np.int32), priority=0)
    res = engine.run()
    assert engine.outcomes[c] == "shed"
    assert engine.degradation_stats()["shed_mode_ticks"] >= 1
    assert len(res[a]) == 24 and len(res[b]) == 24


def test_seeded_burst_composes_backoff_shed_and_preemption():
    """One seeded burst must light up every pressure valve AT ONCE — the
    degradation paths are only trustworthy composed, not just in the
    isolated single-mechanism tests above: admission backoff (a retried
    request eventually admits and completes), preemption by page pressure
    (a high-priority late arrival evicts a low-priority victim), and
    load-shed mode (sub-priority waiting work dropped wholesale) — with
    every request reaching exactly one outcome and pool + trie invariants
    intact."""
    from repro.launch.serve import build_engine
    engine, vocab = build_engine(
        "qwen3-4b", slots=3, max_len=64, max_new=8, kv_mode="paged",
        page_size=8, num_pages=11, max_admission_retries=6,
        admission_backoff=1, shed_pressure=0.85, shed_patience=4,
        shed_min_priority=1)
    rng = np.random.default_rng(9)

    def sub(n_tokens, priority):
        return engine.submit(rng.integers(0, vocab, n_tokens)
                             .astype(np.int32), priority=priority)

    # t=0 burst: three low-priority requests fill the slots and 9 of the
    # 10 usable pages (2 prompt pages + 1 headroom each)
    victims = [sub(12, 0), sub(12, 0), sub(12, 0)]
    for _ in range(2):
        engine.step()
    # late arrivals against a hot pool: the VIPs preempt every victim,
    # the mid-priority request finds only VIPs active (nothing evictable
    # below it) and must back off, the sub-priority pair is shed bait
    vips = [sub(12, 5), sub(12, 5), sub(12, 5)]
    backoff = sub(12, 2)
    doomed = [sub(16, 0), sub(16, 0)]
    res = engine.run()

    stats = engine.degradation_stats()
    counts = {k: stats[k] for k in ("ok", "timeout", "shed")}
    assert sum(counts.values()) == 9       # every rid reached one outcome
    # high priority never preempted, full output
    for vip in vips:
        assert engine.outcomes[vip] == "ok" and len(res[vip]) == 8
        assert engine._requests[vip].preemptions == 0
    # preemption-by-page-pressure fired on the low-priority victims
    assert engine.kv_stats()["evictions"] >= 1
    assert max(engine._requests[r].preemptions for r in victims) >= 1
    # admission backoff fired (next_admit_tick is only ever set by the
    # hold-off path; admit_attempts resets to 0 on the admission that
    # finally lands) and the retried request still completed
    assert engine._requests[backoff].next_admit_tick > 0
    assert engine.outcomes[backoff] == "ok" and len(res[backoff]) == 8
    # sustained pressure tripped shed mode and dropped sub-priority work
    assert stats["shed_mode_ticks"] >= 1
    assert counts["shed"] >= 1
    assert all(engine.outcomes[r] in ("ok", "shed") for r in doomed)
    engine.check_kv()                      # no page leaked through any path


def test_dense_deadline_timeout():
    """The dense path honours deadlines too: queued requests past deadline
    never start; a decoding slot past deadline frees with its partial
    output."""
    from repro.launch.serve import build_engine
    engine, vocab = build_engine("qwen3-4b", slots=1, max_len=48,
                                 max_new=10)
    rng = np.random.default_rng(0)
    slow = engine.submit(rng.integers(0, vocab, 8).astype(np.int32),
                         deadline=3)
    queued = engine.submit(rng.integers(0, vocab, 8).astype(np.int32),
                           deadline=2)            # expires before a slot frees
    ok = engine.submit(rng.integers(0, vocab, 8).astype(np.int32))
    res = engine.run()
    assert engine.outcomes[slow] == "timeout" and 0 < len(res[slow]) < 10
    assert engine.outcomes[queued] == "timeout" and res[queued] == []
    assert engine.outcomes[ok] == "ok" and len(res[ok]) == 10


# ---------------------------------------------------------------------------
# sampling: temperature + top-k (seeded host RNG)
# ---------------------------------------------------------------------------

def test_sampling_seeded_replayable_and_topk1_greedy():
    """Sampled decode is deterministic for a fixed (seed, trace) pair,
    top_k=1 collapses to greedy regardless of temperature, and the
    temperature=0 default is untouched argmax decode."""
    from repro.launch.serve import build_engine

    def serve(**kw):
        engine, vocab = build_engine("qwen3-4b", slots=2, max_len=48,
                                     max_new=4, **kw)
        rng = np.random.default_rng(3)
        for i in range(3):
            engine.submit(rng.integers(0, vocab, 5 + 2 * i).astype(np.int32))
        return engine.run()

    greedy = serve()
    assert serve() == greedy                       # greedy is deterministic
    hot1 = serve(temperature=0.9, top_k=8, sample_seed=11)
    hot2 = serve(temperature=0.9, top_k=8, sample_seed=11)
    assert hot1 == hot2                            # same seed -> same trace
    assert hot1.keys() == greedy.keys()
    assert all(len(v) == 4 for v in hot1.values())
    # top_k=1 == argmax even at high temperature
    assert serve(temperature=5.0, top_k=1, sample_seed=7) == greedy


def test_sampling_paged_mode_seeded():
    """The paged engine samples through the same seeded picker (prefill
    final token + decode ticks)."""
    from repro.launch.serve import build_engine

    def serve(seed):
        engine, vocab = build_engine("qwen3-4b", slots=2, max_len=48,
                                     max_new=4, kv_mode="paged", page_size=8,
                                     temperature=0.7, top_k=4,
                                     sample_seed=seed)
        rng = np.random.default_rng(5)
        for i in range(3):
            engine.submit(rng.integers(0, vocab, 6 + i).astype(np.int32))
        return engine.run()

    assert serve(seed=2) == serve(seed=2)


# ---------------------------------------------------------------------------
# prefix cache: differential correctness (cache-on == cache-off, exactly)
# ---------------------------------------------------------------------------

def _prefix_prompts(vocab, n=5, prefix_len=16, seed=7):
    """n prompts sharing a `prefix_len`-token common prefix (two full
    pages at page_size=8) with short random suffixes."""
    rng = np.random.default_rng(seed)
    common = rng.integers(0, vocab, prefix_len)
    return [np.concatenate(
        [common, rng.integers(0, vocab, int(rng.integers(3, 10)))]
    ).astype(np.int32) for _ in range(n)]


def _serve_cached(arch, kv_mode, prompts, prefix_cache, **kw):
    from repro.launch.serve import build_engine
    engine, vocab = build_engine(arch, slots=2, max_len=64, max_new=6,
                                 kv_mode=kv_mode, page_size=8,
                                 prefix_cache=prefix_cache, **kw)
    for p in prompts:
        engine.submit(p)
    return engine.run(), engine


@pytest.mark.parametrize("kv_mode", ["paged", "paged_int8"])
def test_prefix_cache_differential_token_exact(kv_mode):
    """Shared-prefix requests served THROUGH the radix cache produce
    token-identical outputs to the cold path (cache disabled) — the
    matched prefix's KV pages really are the same computation."""
    vocab = 256
    prompts = _prefix_prompts(vocab)
    hot, eng = _serve_cached("qwen3-4b", kv_mode, prompts, True)
    cold, _ = _serve_cached("qwen3-4b", kv_mode, prompts, False)
    assert hot == cold
    st = eng.prefix_stats()
    assert st["hits"] >= 3 and st["matched_tokens"] > 0
    assert eng.kv.stats()["shares"] >= 2      # >= one 2-page shared mapping
    eng.check_kv()


def test_prefix_cache_matches_dense_golden():
    """The cached paged path stays exactly equal to the DENSE engine (the
    no-pool golden): dense == paged(cache off) == paged(cache on)."""
    vocab = 256
    prompts = _prefix_prompts(vocab, seed=11)
    dense, _ = _serve_cached("qwen3-4b", "dense", prompts, False)
    hot, eng = _serve_cached("qwen3-4b", "paged", prompts, True)
    assert hot == dense
    assert eng.prefix_stats()["hits"] >= 1


def test_prefix_cache_cow_divergence_matches_cold():
    """A prompt diverging MID-PAGE from a cached sequence triggers
    copy-on-write (private copy of the partially matched page) and still
    decodes token-identically to the cold path."""
    from repro.launch.serve import build_engine
    rng = np.random.default_rng(13)
    vocab = 256
    common = rng.integers(0, vocab, 16)
    a = np.concatenate([common, rng.integers(0, vocab, 6)]).astype(np.int32)
    b = np.concatenate([common[:10],                   # diverge at token 10
                        rng.integers(0, vocab, 8)]).astype(np.int32)

    def serve_seq(prefix_cache):
        engine, _ = build_engine("qwen3-4b", slots=2, max_len=64, max_new=6,
                                 kv_mode="paged", page_size=8,
                                 prefix_cache=prefix_cache)
        engine.submit(a)
        engine.run()                  # a finishes -> pages enter the trie
        engine.submit(b)
        return engine.run(), engine

    hot, eng = serve_seq(True)
    cold, _ = serve_seq(False)
    assert hot == cold
    assert eng.cow_copies >= 1                   # the device copy ran
    assert eng.prefix_stats()["cow_count"] >= 1
    # b matched one full page + 2 tokens of the diverging page
    assert eng._requests[1].matched_tokens == 10
    eng.check_kv()


def test_prefix_cache_page_dedup_under_shared_load():
    """With many live shared-prefix requests, the pool holds each prefix
    page ONCE (refcount > 1) — the dedup the traffic benchmark measures."""
    from repro.launch.serve import build_engine
    engine, vocab = build_engine("qwen3-4b", slots=4, max_len=64, max_new=4,
                                 kv_mode="paged", page_size=8)
    prompts = _prefix_prompts(vocab, n=6, seed=23)
    for p in prompts:
        engine.submit(p)
    shared_seen = 0
    while engine.pending():
        engine.step()
        shared_seen = max(shared_seen, engine.kv.stats()["pages_shared"])
    assert shared_seen >= 2        # both prefix pages lived shared at once
    engine.check_kv()


def test_token_streaming_matches_batch_run():
    """The per-request stream() generators, consumed interleaved, drive
    the same continuous-batching ticks and yield exactly the tokens the
    batch run() API returns."""
    from repro.launch.serve import build_engine

    def build(submit_all=True):
        engine, vocab = build_engine("qwen3-4b", slots=2, max_len=48,
                                     max_new=5, kv_mode="paged", page_size=8)
        rng = np.random.default_rng(31)
        rids = [engine.submit(rng.integers(0, vocab, 7 + i).astype(np.int32))
                for i in range(3)]
        return engine, rids

    engine, rids = build()
    golden = engine.run()

    engine, rids = build()
    gens = {rid: engine.stream(rid) for rid in rids}
    got = {rid: [] for rid in rids}
    live = dict(gens)
    while live:                      # round-robin the consumers
        for rid, g in list(live.items()):
            try:
                got[rid].append(next(g))
            except StopIteration:
                del live[rid]
    assert got == golden


# ---------------------------------------------------------------------------
# regression: preemption of a request holding SHARED prefix pages
# ---------------------------------------------------------------------------

def test_preemption_shared_prefix_pages_only_decref():
    """Eviction under page pressure used to assume the victim owned its
    pages exclusively and returned them all to the free list; a victim
    whose leading pages are radix-cache mappings shared with the trie and
    a live peer must only DROP ITS REFERENCES — the peer keeps decoding
    from the same physical pages and the cache stays intact."""
    kv = BlockPoolKV(_kvcfg(num_slots=3, num_pages=17))
    pc = RadixPrefixCache(kv)
    prefix = list(range(16))                      # two full pages
    kv.ensure(0, 16)
    kv.advance(0, 16)
    pc.insert(prefix, kv.slot_pages(0), 16)
    kv.free_slot(0)

    sched = PhaseScheduler(SchedulerConfig(num_slots=3))
    r1 = Request(rid=1, prompt=np.asarray(prefix + [7, 8], np.int32),
                 arrival=0, max_new_tokens=4)
    r2 = Request(rid=2, prompt=np.asarray(prefix + [9], np.int32),
                 arrival=1, max_new_tokens=4)
    sched.submit(r1)
    sched.submit(r2)
    assert len(sched.admit(kv, prefix=pc)) == 2
    shared = [int(p) for p in kv.slot_pages(r1.slot)[:2]]
    assert shared == [int(p) for p in kv.slot_pages(r2.slot)[:2]]
    assert all(kv.refcount[p] == 3 for p in shared)   # trie + r1 + r2
    r2_pages = kv.slot_pages(r2.slot)
    free_before = kv.free_pages

    sched._evict(kv, r1)                          # preempt the sharer
    # ONLY r1's references dropped: shared pages never hit the free list
    assert all(kv.refcount[p] == 2 for p in shared)
    assert kv.slot_pages(r2.slot) == r2_pages     # peer untouched
    # exactly r1's PRIVATE pages came back (prompt 18 tokens -> 3 pages
    # + 1 headroom, minus the 2 shared)
    assert kv.free_pages == free_before + 2
    assert pc.match(prefix + [55]).matched_full == 16   # cache intact
    pc.check_invariants()
    # drain: peer finishes, trie evicts -> pool returns to empty
    sched.finish(kv, r2)
    assert all(kv.refcount[p] == 1 for p in shared)
    pc.evict(100)
    assert kv.free_pages == kv.cfg.total_pages - 1


def test_deadline_eviction_shared_prefix_pages_only_decref():
    """The deadline-expiry path must obey the same sharing contract as
    preemption: a timed-out request whose leading pages are radix-cache
    mappings shared with a live peer only DROPS ITS REFERENCES — exactly
    its private pages return to the free list, the peer's mapping and the
    trie are untouched, and a waiting expiree releases nothing (it never
    held pages)."""
    kv = BlockPoolKV(_kvcfg(num_slots=2, num_pages=17))
    pc = RadixPrefixCache(kv)
    prefix = list(range(16))                      # two full shared pages
    kv.ensure(0, 16)
    kv.advance(0, 16)
    pc.insert(prefix, kv.slot_pages(0), 16)
    kv.free_slot(0)

    sched = PhaseScheduler(SchedulerConfig(num_slots=2))
    doomed = Request(rid=1, prompt=np.asarray(prefix + [7, 8], np.int32),
                     arrival=0, max_new_tokens=4, deadline_tick=5)
    peer = Request(rid=2, prompt=np.asarray(prefix + [9], np.int32),
                   arrival=1, max_new_tokens=4)
    queued = Request(rid=3, prompt=np.asarray(prefix + [4], np.int32),
                     arrival=2, max_new_tokens=4, deadline_tick=5)
    sched.submit(doomed)
    sched.submit(peer)
    sched.submit(queued)                          # both slots taken: waits
    assert len(sched.admit(kv, prefix=pc)) == 2
    shared = [int(p) for p in kv.slot_pages(doomed.slot)[:2]]
    assert shared == [int(p) for p in kv.slot_pages(peer.slot)[:2]]
    assert all(kv.refcount[p] == 3 for p in shared)   # trie + both slots
    peer_pages = kv.slot_pages(peer.slot)
    free_before = kv.free_pages

    expired = sched.expire_deadlines(kv, now=6)
    assert sorted(r.rid for r in expired) == [1, 3]
    # ONLY the expiree's references dropped; the shared pages never hit
    # the free list and the peer decodes on from the same physical pages
    assert all(kv.refcount[p] == 2 for p in shared)
    assert kv.slot_pages(peer.slot) == peer_pages
    # doomed's 18-token prompt mapped 3 pages + 1 headroom; 2 were shared,
    # so exactly its 2 PRIVATE pages come back (the waiting expiree adds 0)
    assert kv.free_pages == free_before + 2
    assert pc.match(prefix + [55]).matched_full == 16   # cache intact
    pc.check_invariants()
    sched.finish(kv, peer)
    pc.evict(100)
    assert kv.free_pages == kv.cfg.total_pages - 1


# ---------------------------------------------------------------------------
# scheduler fuzz: random arrival/length/priority streams
# ---------------------------------------------------------------------------

def _fuzz_scheduler_trace(seed, n_requests=None, ticks_cap=4000):
    """Host-level lifecycle sim mirroring the engine's tick loop (no jax):
    random arrivals/lengths/priorities/deadlines with the prefix cache in
    the loop, invariant-checked every tick.  Returns outcome counts."""
    rng = np.random.default_rng(seed)
    num_pages = int(rng.integers(10, 22))
    kv = BlockPoolKV(PagedKVConfig(num_slots=3, max_len=48, page_size=8,
                                   num_pages=num_pages))
    pc = RadixPrefixCache(kv)
    sched = PhaseScheduler(SchedulerConfig(
        num_slots=3, prefill_chunk=8, prefill_token_budget=16,
        max_admission_retries=int(rng.integers(0, 3)),
        admission_backoff=int(rng.integers(0, 3))))
    n_requests = n_requests or int(rng.integers(4, 14))
    common = rng.integers(0, 4, 12).tolist()      # tiny vocab: collisions
    pending = []
    for rid in range(n_requests):
        plen = int(rng.integers(2, 20))
        prompt = rng.integers(0, 4, plen).tolist()
        if rng.random() < 0.5:                    # half share a prefix
            k = min(plen - 1, int(rng.integers(1, 13)))
            prompt[:k] = common[:k]
        pending.append((int(rng.integers(0, 12)), Request(
            rid=rid, prompt=np.asarray(prompt, np.int32),
            priority=int(rng.integers(0, 3)), arrival=rid,
            max_new_tokens=int(rng.integers(1, 7)),
            deadline_tick=None if rng.random() < 0.7
            else int(rng.integers(4, 40)))))
    outcomes = {}

    def finish(req):
        n = int(kv.lengths[req.slot])
        seq = list(req.prompt) + req.generated
        pc.insert(seq[:n], kv.slot_pages(req.slot), n)
        outcomes[req.rid] = "ok"
        sched.finish(kv, req)

    tick = 0
    while pending or sched.has_work:
        tick += 1
        assert tick < ticks_cap, "scheduler starved a request"
        while pending and pending[0][0] <= tick:
            sched.submit(pending.pop(0)[1])
        for req in sched.expire_deadlines(kv, tick):
            outcomes[req.rid] = "timeout"
        admitted = sched.admit(kv, now=tick, prefix=pc)
        for req in admitted:
            PhaseScheduler._drop_cow(kv, req)     # "engine" copies at once
        for req in sched.drain_shed():
            outcomes[req.rid] = "shed"
        sched.ensure_decode_pages(kv)
        decoding = sched.decoding()
        for job in sched.prefill_jobs():
            kv.advance(job.req.slot, job.count)
            sched.finish_prefill_chunk(job.req, job.count)
            if job.req.phase is Phase.DECODE:
                job.req.generated.append(int(rng.integers(0, 4)))
                if job.req.n_generated >= job.req.max_new_tokens:
                    finish(job.req)
        for req in decoding:
            if req.slot < 0 or sched._active.get(req.slot) is not req:
                continue                          # evicted this tick
            kv.advance(req.slot, 1)
            req.generated.append(int(rng.integers(0, 4)))
            if req.n_generated >= req.max_new_tokens:
                finish(req)
        pc.check_invariants()
    # accounting: every submitted request reached exactly one outcome
    assert sorted(outcomes) == list(range(n_requests))
    # drain the cache: every page accounted for, none leaked
    pc.evict(10 ** 6)
    assert kv.free_pages == kv.cfg.total_pages - 1
    return outcomes


def test_scheduler_fuzz_seeded_sweep():
    for seed in range(60):
        _fuzz_scheduler_trace(seed)


def test_scheduler_fuzz_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=200, deadline=None)
    @hyp.given(seed=st.integers(0, 2 ** 31 - 1), n=st.integers(1, 14))
    def drive(seed, n):
        _fuzz_scheduler_trace(seed, n_requests=n)

    drive()


def test_engine_fuzz_outcomes_account_for_every_request():
    """End-to-end randomized run on the real engine: arrivals with mixed
    priorities/deadlines under a small pool — `engine.outcomes` must cover
    every submitted rid exactly once and pool+trie invariants must hold."""
    from repro.launch.serve import build_engine
    engine, vocab = build_engine(
        "qwen3-4b", slots=2, max_len=48, max_new=4, kv_mode="paged",
        page_size=8, num_pages=11, max_admission_retries=3,
        admission_backoff=1)
    rng = np.random.default_rng(17)
    rids = []
    for i in range(6):
        rids.append(engine.submit(
            rng.integers(0, vocab, int(rng.integers(3, 14))).astype(np.int32),
            priority=int(rng.integers(0, 3)),
            deadline=None if i % 3 else 60))
    res = engine.run()
    assert sorted(engine.outcomes) == sorted(rids)
    counts = engine.degradation_stats()
    assert counts["ok"] + counts["timeout"] + counts["shed"] == len(rids)
    assert sorted(res) == sorted(rids)
    assert all(len(res[r]) <= 4 for r in rids)
    engine.check_kv()
