"""Fault-tolerant serving fleet: page-ownership directory, KV page
migration, and chaos-driven request recovery.

Covers the acceptance checklist of the serving-fleet PR: the wire frame's
CRC (round-trip, flipped byte, truncation), directory ownership rules
(first-live-publisher-wins, tombstones, revive, transfer), the migration
drill (pages MOVE over the exchange — ``page_exchange_bytes`` > 0 and a
directory hit rate > 0 in the metrics registry — instead of being
re-prefilled), and the differential property under chaos: for every
request the fleet completes, its greedy tokens equal the single-engine
baseline's — with hosts dying, the migration channel netsplit, or pages
corrupted in flight.
"""
import functools
import json
import os
import socket
import struct
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.obs import Telemetry
from repro.obs.metrics import MetricsRegistry
from repro.runtime.chaos import ChaosInjector
from repro.runtime.fleet import (LocalPageExchange, PageCorruptError,
                                 PageExchangeTimeout, StripeExchangeTimeout,
                                 TcpPageExchange, TcpStripeExchange,
                                 allocate_ports, decode_page_frame,
                                 encode_page_frame, flip_frame_byte)
from repro.serving import (DirectoryMatch, FleetConfig, LocalFleet,
                           PageOwnershipDirectory)

PAGE = 8


# ---------------------------------------------------------------------------
# page frames: CRC round-trip + corruption detection (jax-free)
# ---------------------------------------------------------------------------

def _frame(seed=0):
    rng = np.random.default_rng(seed)
    arrays = {"k": rng.normal(size=(2, PAGE, 2, 4)).astype(np.float32),
              "v": rng.integers(-127, 127, (2, PAGE, 2, 4)).astype(np.int8)}
    tokens = tuple(int(t) for t in rng.integers(0, 999, PAGE))
    return tokens, arrays


def test_page_frame_round_trip_preserves_dtype_and_shape():
    tokens, arrays = _frame()
    got_tokens, got = decode_page_frame(encode_page_frame(tokens, arrays))
    assert got_tokens == tokens
    assert set(got) == set(arrays)
    for k in arrays:
        assert got[k].dtype == arrays[k].dtype
        np.testing.assert_array_equal(got[k], arrays[k])


def test_page_frame_crc_rejects_flip_truncation_and_bad_magic():
    tokens, arrays = _frame()
    frame = encode_page_frame(tokens, arrays)
    with pytest.raises(PageCorruptError, match="CRC"):
        decode_page_frame(flip_frame_byte(frame))
    with pytest.raises(PageCorruptError):
        decode_page_frame(frame[:len(frame) // 2])
    with pytest.raises(PageCorruptError, match="magic"):
        decode_page_frame(b"NOPE" + frame[4:])
    # timeouts and corruption are DIFFERENT failures: one retries, the
    # other must never enter a pool
    assert issubclass(PageExchangeTimeout, TimeoutError)
    assert not issubclass(PageCorruptError, TimeoutError)


def test_local_page_exchange_netsplit_and_corrupt_hooks():
    tokens, arrays = _frame()
    frame = encode_page_frame(tokens, arrays)
    ex = LocalPageExchange()
    out = ex.transfer(0, 1, [frame])
    assert out[0][0] == tokens and ex.bytes_sent == len(frame)
    ex.blackout = lambda h: h == 1
    with pytest.raises(PageExchangeTimeout, match="netsplit"):
        ex.transfer(0, 1, [frame])
    ex.blackout = None
    ex.corrupt_hook = lambda: True
    with pytest.raises(PageCorruptError):
        ex.transfer(0, 1, [frame])


def test_tcp_page_exchange_publish_fetch():
    tokens, arrays = _frame()
    frames = [encode_page_frame(tokens, arrays),
              encode_page_frame(tokens[:4], {"k": arrays["k"]})]
    ports = allocate_ports(2)
    exs = [TcpPageExchange(r, ports, timeout_s=20) for r in range(2)]
    try:
        exs[0].publish("mig:0", frames)
        got = exs[1].fetch(0, "mig:0")
        assert [g[0] for g in got] == [tokens, tokens[:4]]
        np.testing.assert_array_equal(got[0][1]["v"], arrays["v"])
        assert exs[1].frames_sent == 2
        with pytest.raises(PageExchangeTimeout):
            exs[1].fetch(0, "never-published", timeout_s=0.3)
    finally:
        for ex in exs:
            ex.close()


# ---------------------------------------------------------------------------
# stripe exchange: bounded reconnect on connection reset
# ---------------------------------------------------------------------------

def _flaky_peer(port, payload, n_resets, stop):
    """A fake peer that RST-closes the first ``n_resets`` connections,
    then serves ``payload`` under any key — a supervisor-bounced rank."""
    srv = socket.socket()
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind(("127.0.0.1", port))
    srv.listen(8)
    srv.settimeout(0.1)
    resets = 0
    while not stop.is_set():
        try:
            conn, _ = srv.accept()
        except socket.timeout:
            continue
        if resets < n_resets:
            resets += 1
            conn.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                            struct.pack("ii", 1, 0))   # close -> RST
            conn.close()
            continue
        buf = b""
        while not buf.endswith(b"\n"):
            buf += conn.recv(256)
        conn.sendall(struct.pack(">Q", len(payload)) + payload)
        conn.close()
        break
    srv.close()


def _with_flaky_peer(n_resets, timeout_s):
    ports = allocate_ports(2)
    ex = TcpStripeExchange(0, ports, timeout_s=timeout_s)
    stop = threading.Event()
    t = threading.Thread(target=_flaky_peer,
                         args=(ports[1], b"peer-bytes", n_resets, stop),
                         daemon=True)
    t.start()
    try:
        return ex, ex.allgather("k", 0, 2, b"mine")
    finally:
        stop.set()
        t.join(timeout=5)
        ex.close()


def test_stripe_exchange_reconnects_once_after_reset():
    """A peer that resets ONE connection (restart mid-exchange) costs a
    bounded grace, not a StripeExchangeTimeout."""
    ex, out = _with_flaky_peer(n_resets=1, timeout_s=10)
    assert out == [b"mine", b"peer-bytes"]
    assert ex.reconnects == 1


def test_stripe_exchange_reset_grace_is_granted_once():
    """A peer that NEVER stops resetting still times out — the grace is
    one bounded extension, not a retry loop."""
    t0 = time.monotonic()
    with pytest.raises(StripeExchangeTimeout):
        _with_flaky_peer(n_resets=10_000, timeout_s=0.4)
    # one grace of min(RECONNECT_GRACE_S, timeout_s): well under 5s total
    assert time.monotonic() - t0 < 5.0


# ---------------------------------------------------------------------------
# page-ownership directory (jax-free)
# ---------------------------------------------------------------------------

def _toks(n, base=0):
    return list(range(base, base + n))


def test_directory_first_live_publisher_wins_and_lookup_caps():
    d = PageOwnershipDirectory(PAGE)
    assert d.publish(_toks(2 * PAGE), host=0) == 2
    assert d.publish(_toks(2 * PAGE), host=1) == 0   # owned once
    # last token always recomputed: exactly 2*PAGE tokens match only one
    # full page (same len-1 rule as the local trie)
    m = d.lookup(_toks(2 * PAGE))
    assert m.hit and m.owners == (0,) and m.matched == PAGE
    m3 = d.lookup(_toks(3 * PAGE))
    assert m3.matched == 2 * PAGE
    assert d.lookup(_toks(PAGE, base=500)).hit is False
    assert d.stats()["hit_rate"] == pytest.approx(2 / 3)


def test_directory_tombstone_stops_lookup_at_surviving_ancestor():
    d = PageOwnershipDirectory(PAGE)
    seq = _toks(3 * PAGE + 1)
    d.publish(seq[:PAGE], host=0)
    d.publish(seq, host=1)          # host 1 owns pages 2..3
    assert d.tombstone_host(1) == 2
    m = d.lookup(seq)
    assert m.owners == (0,) and m.matched == PAGE   # survivor's page only
    # a survivor recomputing the prefix revives the dead entries
    assert d.publish(seq, host=2) == 2
    assert d.lookup(seq).owners == (0, 2, 2)
    assert d.stats()["revived_pages"] == 2
    with pytest.raises(ValueError, match="tombstoned"):
        d.publish(seq, host=1)


def test_directory_transfer_moves_ownership():
    d = PageOwnershipDirectory(PAGE)
    seq = _toks(2 * PAGE + 1)
    d.publish(seq, host=0)
    assert d.transfer(seq, 2 * PAGE, new_host=3) == 2
    assert d.lookup(seq).owners == (3, 3)
    assert d.owners() == {3: 2}
    assert d.stats()["transferred_pages"] == 2


def test_directory_match_defaults_are_a_miss():
    m = DirectoryMatch()
    assert not m.hit and m.matched == 0


# ---------------------------------------------------------------------------
# fleet fixtures: engines sharing one bundle + params
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _shared_model():
    import jax

    from repro.configs import get_bundle
    from repro.launch.serve import _BundleAdapter
    bundle = get_bundle("qwen3-4b", smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return _BundleAdapter(bundle, {}), params, bundle.cfg.vocab


def _mk_engines(n, **kw):
    from repro.serving import ServeConfig, ServingEngine
    adapter, params, _ = _shared_model()
    base = dict(batch=2, max_len=64, max_new_tokens=4,
                kv_mode="paged", page_size=PAGE)
    base.update(kw)
    return [ServingEngine(adapter, params, ServeConfig(**base))
            for _ in range(n)]


@functools.lru_cache(maxsize=1)
def _canonical():
    """4 prompts sharing a 3-page prefix + the single-engine baseline."""
    _, _, vocab = _shared_model()
    rng = np.random.default_rng(0)
    shared = rng.integers(1, vocab, 3 * PAGE)
    prompts = tuple(
        tuple(int(t) for t in np.concatenate(
            [shared, rng.integers(1, vocab, 6)]))
        for _ in range(4))
    (engine,) = _mk_engines(1)
    rids = [engine.submit(np.asarray(p, np.int32)) for p in prompts]
    engine.run()
    baseline = {i: engine.results[r] for i, r in enumerate(rids)}
    return prompts, baseline


def _fleet(n_hosts=2, chaos=None, registry=None, **cfg_kw):
    cfg_kw.setdefault("placement", "round_robin")
    tel = Telemetry(enabled=True,
                    registry=registry if registry is not None
                    else MetricsRegistry())
    return LocalFleet(_mk_engines(n_hosts), FleetConfig(**cfg_kw),
                      chaos=chaos, telemetry=tel)


def _submit_in_waves(fleet, prompts, wave=2, settle_ticks=None):
    """Arrivals over time — the first wave publishes its prefix to the
    directory before the second wave's placement consults it (a same-tick
    burst would find an empty directory and never migrate)."""
    rids = []
    for i in range(0, len(prompts), wave):
        if rids:
            if settle_ticks is None:
                fleet.run()
            else:
                for _ in range(settle_ticks):
                    fleet.step()
        rids += [fleet.submit(p) for p in prompts[i:i + wave]]
    fleet.run()
    return rids


# ---------------------------------------------------------------------------
# the migration drill + the differential property
# ---------------------------------------------------------------------------

def test_fleet_migrates_pages_instead_of_reprefilling():
    """Seeded drill: the second wave lands on the OTHER host, its shared
    prefix MOVES over the exchange (bytes on the wire, ownership
    transferred, source path dropped), and every request's tokens still
    equal the single-engine baseline's."""
    prompts, baseline = _canonical()
    reg = MetricsRegistry()
    fleet = _fleet(2, chaos=ChaosInjector([], seed=0), registry=reg)
    rids = _submit_in_waves(fleet, prompts)
    for i, r in enumerate(rids):
        assert fleet.outcomes[r] == "ok"
        assert fleet.results[r] == baseline[i], i
    st = fleet.stats()
    assert st["migrations"]["ok"] >= 1
    assert st["page_exchange_bytes"] > 0
    assert st["migrated_pages"] >= 1
    assert st["directory"]["hit_rate"] > 0
    assert st["directory"]["transferred_pages"] >= 1
    # the acceptance criterion reads these from the obs registry
    snap = fleet.telemetry() and reg.snapshot()
    assert snap["counters"]["page_exchange_bytes"] > 0
    assert snap["counters"]["fleet_migrations{outcome=ok}"] >= 1
    assert snap["gauges"]["fleet.directory.hit_rate"] > 0
    assert snap["gauges"]["fleet.page_exchange_bytes"] > 0
    assert "fleet_migration_s" in snap["histograms"]
    for eng in fleet.engines:     # pools + tries intact after migration
        eng.check_kv()


def test_fleet_die_chaos_differential():
    """Host 0 dies mid-serve: its directory entries tombstone, its
    in-flight requests re-admit on the survivor, and every COMPLETED
    request still matches the baseline token-for-token."""
    prompts, baseline = _canonical()
    chaos = ChaosInjector(["die@3:host=0"], seed=0)
    fleet = _fleet(2, chaos=chaos)
    rids = _submit_in_waves(fleet, prompts, settle_ticks=2)
    assert "die@3:host=0" in chaos.fired
    st = fleet.stats()
    assert st["deaths"] == 1 and st["live_hosts"] == 1
    assert st["directory"]["tombstoned_pages"] >= 0
    done = 0
    for i, r in enumerate(rids):
        if fleet.outcomes.get(r) == "ok":
            assert fleet.results[r] == baseline[i], i
            done += 1
    assert done >= 1                       # the survivor kept serving
    assert st["retries"] >= 1              # orphans were re-admitted
    # recovery latency was measured for the re-admitted requests
    snap = fleet.telemetry() and fleet.metrics.snapshot()
    assert snap["histograms"]["fleet_recovery_ticks"]["count"] >= 1
    fleet.engines[1].check_kv()


def test_fleet_netsplit_degrades_migration_to_recompute():
    """A netsplit across the dispatch window blacks out the page channel:
    migrations time out (never PageCorruptError), the router recomputes
    the prefix locally, and the tokens are still right."""
    prompts, baseline = _canonical()
    chaos = ChaosInjector(["netsplit@1:host=1,duration=200"], seed=0)
    fleet = _fleet(2, chaos=chaos)
    rids = _submit_in_waves(fleet, prompts)
    for i, r in enumerate(rids):
        assert fleet.results[r] == baseline[i], i
    st = fleet.stats()
    assert st["migrations"]["timeout"] >= 1
    assert st["migrations"]["corrupt"] == 0
    assert st["migrations"]["ok"] == 0
    assert st["page_exchange_bytes"] == 0       # nothing crossed the split
    assert any(f.startswith("netsplit@1") for f in chaos.fired)


def test_fleet_pagecorrupt_crc_rejects_and_recomputes():
    """A frame corrupted in flight is rejected by the receiver's CRC —
    the damaged page never enters the pool, the request recomputes and
    still matches the baseline."""
    prompts, baseline = _canonical()
    chaos = ChaosInjector(["pagecorrupt@1"], seed=0)
    fleet = _fleet(2, chaos=chaos)
    rids = _submit_in_waves(fleet, prompts)
    for i, r in enumerate(rids):
        assert fleet.results[r] == baseline[i], i
    st = fleet.stats()
    assert st["migrations"]["corrupt"] >= 1
    assert "pagecorrupt@1" in chaos.fired
    for eng in fleet.engines:
        eng.check_kv()          # the rejected page left no pool damage


def test_fleet_hedged_twin_first_writer_wins():
    """With an aggressive hedge deadline every request gets a twin on the
    other host; exactly one copy's tokens surface and the loser is
    cancelled (its pages released)."""
    prompts, baseline = _canonical()
    fleet = _fleet(2, hedge_after=1, migrate=False)
    rids = [fleet.submit(p) for p in prompts]
    fleet.run()
    assert fleet.stats()["hedges"] >= 1
    for i, r in enumerate(rids):
        assert fleet.outcomes[r] == "ok"
        assert fleet.results[r] == baseline[i], i
    for eng in fleet.engines:
        eng.check_kv()
        assert "cancelled" not in fleet.outcomes.values()


def test_fleet_retry_budget_exhausted_fails_closed():
    """Every host dies and the retry budget is zero: the orphaned
    requests fail CLOSED (outcome ``failed``, empty tokens) instead of
    hanging the router."""
    prompts, _ = _canonical()
    chaos = ChaosInjector(["die@2:host=0", "die@2:host=1"], seed=0)
    fleet = _fleet(2, chaos=chaos, max_retries=0)
    rids = [fleet.submit(p) for p in prompts]
    fleet.run()
    assert fleet.stats()["live_hosts"] == 0
    for r in rids:
        assert fleet.outcomes[r] == "failed"
        assert fleet.results[r] == []
    assert fleet.stats()["outcomes"]["failed"] == len(rids)


def test_fleet_rejects_dense_engines_and_mismatched_pages():
    from repro.serving import ServeConfig, ServingEngine
    adapter, params, _ = _shared_model()
    dense = ServingEngine(adapter, params,
                          ServeConfig(batch=2, max_len=64,
                                      max_new_tokens=2, kv_mode="dense"))
    with pytest.raises(ValueError, match="paged"):
        LocalFleet([dense])
    with pytest.raises(ValueError, match="page_size"):
        LocalFleet(_mk_engines(1) + _mk_engines(1, page_size=16))
    with pytest.raises(ValueError, match="placement"):
        LocalFleet(_mk_engines(1), FleetConfig(placement="nope"))


def test_engine_export_import_round_trip():
    """The engine-level migration surface: pages exported from one host's
    trie and imported into another's give the importer a REAL prefix hit
    (no prefill of the shared tokens) with byte-identical results."""
    prompts, baseline = _canonical()
    src, dst = _mk_engines(2)
    rid = src.submit(np.asarray(prompts[0], np.int32))
    src.run()
    assert src.results[rid] == baseline[0]
    exported = src.export_prefix_pages(np.asarray(prompts[0], np.int32),
                                       3 * PAGE)
    assert len(exported) == 3
    frames = [encode_page_frame(t, a) for t, a in exported]
    decoded = [decode_page_frame(f) for f in frames]
    assert dst.import_prefix_pages(decoded) == 3 * PAGE
    before = dst.prefix_stats()["matched_tokens"]
    rid2 = dst.submit(np.asarray(prompts[1], np.int32))
    dst.run()
    assert dst.results[rid2] == baseline[1]
    assert dst.prefix_stats()["matched_tokens"] - before >= 3 * PAGE - 1
    src.check_kv()
    dst.check_kv()


# ---------------------------------------------------------------------------
# the real-process fleet CLI (supervisor + serve workers)
# ---------------------------------------------------------------------------

def test_serve_fleet_cli_survives_worker_death(tmp_path):
    """``--fleet 2`` with die chaos: the targeted worker exits 43, the
    supervisor restarts it without chaos, and the merged results cover
    every request."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-4b",
         "--fleet", "2", "--requests", "4", "--kv-mode", "paged",
         "--page-size", "8", "--slots", "2", "--max-new", "4",
         "--prefix-share", "0.5", "--chaos", "die@2:host=1",
         "--fleet-dir", str(tmp_path), "--max-wall-s", "300"],
        env=env, capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "outcome=completed" in out.stdout
    assert "served=4/4" in out.stdout
    merged = {}
    for tag in range(2):
        with open(tmp_path / "results" / f"rank_{tag}.json") as f:
            merged.update(json.load(f)["results"])
    assert sorted(merged) == ["0", "1", "2", "3"]
    assert all(len(v) == 4 for v in merged.values())
