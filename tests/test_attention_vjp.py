"""Trainable fused flash attention: Pallas VJP vs the XLA reference
(interpret mode), the pruned pair-table schedule, the flash policy, and
the default-path dispatch (the kernel appears in the jaxpr iff the policy
says so)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import (DEFAULT_FLASH_POLICY, FlashAttnPolicy,
                                decide_flash, flash_attn_policy)
from repro.kernels import attention as katt
from repro.kernels import ops, ref
from repro.models import layers

RNG = np.random.default_rng(7)


def _arr(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32), dtype)


def _ref_loss(q, k, v, *, causal, window):
    B, H, S, Dh = q.shape
    Hkv = k.shape[1]
    o = ref.attention_ref(q.reshape(B * H, S, Dh),
                          k.reshape(B * Hkv, S, Dh),
                          v.reshape(B * Hkv, S, Dh),
                          causal=causal, window=window)
    return (o.astype(jnp.float32) ** 2).sum()


def _pal_loss(q, k, v, *, causal, window, block=8):
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            block_q=block, block_k=block)
    return (o.astype(jnp.float32) ** 2).sum()


# ---------------------------------------------------------------------------
# Grad equality: Pallas VJP dq/dk/dv vs XLA autodiff of the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 8), (False, 8)])
def test_vjp_grads_match_xla(causal, window):
    B, H, Hkv, S, Dh = 2, 4, 4, 24, 16   # ragged S: exercises padding
    q, k, v = _arr((B, H, S, Dh)), _arr((B, Hkv, S, Dh)), _arr((B, Hkv, S,
                                                                Dh))
    gr = jax.grad(lambda *a: _ref_loss(*a, causal=causal, window=window),
                  argnums=(0, 1, 2))(q, k, v)
    gp = jax.grad(lambda *a: _pal_loss(*a, causal=causal, window=window),
                  argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("q k v".split(), gr, gp):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-4,
                                   atol=2e-4, err_msg=f"d{name}")


def test_vjp_grads_match_xla_gqa():
    B, H, Hkv, S, Dh = 2, 8, 2, 32, 16
    q, k, v = _arr((B, H, S, Dh)), _arr((B, Hkv, S, Dh)), _arr((B, Hkv, S,
                                                                Dh))
    gr = jax.grad(lambda *a: _ref_loss(*a, causal=True, window=None),
                  argnums=(0, 1, 2))(q, k, v)
    gp = jax.grad(lambda *a: _pal_loss(*a, causal=True, window=None),
                  argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("q k v".split(), gr, gp):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-4,
                                   atol=2e-4, err_msg=f"d{name}")


def test_fwd_saves_only_o_and_lse():
    """The VJP residual contract: no (Sq, Sk) score buffer survives the
    forward — residuals are q/k/v plus (o, lse) only."""
    B, H, S, Dh = 1, 2, 32, 8
    q, k, v = _arr((B, H, S, Dh)), _arr((B, H, S, Dh)), _arr((B, H, S, Dh))

    def loss(q, k, v):
        return _pal_loss(q, k, v, causal=True, window=None)

    # linearize runs the custom-vjp fwd rule; its jaxpr must not carry a
    # (BH, S, S)-sized tensor
    jaxpr = jax.make_jaxpr(lambda *a: jax.linearize(loss, *a)[0])(q, k, v)
    big = B * H * S * S
    for eqn_var in jaxpr.jaxpr.outvars:
        assert np.prod(eqn_var.aval.shape, initial=1) < big


# ---------------------------------------------------------------------------
# Pair-table schedule (the pruned grid)
# ---------------------------------------------------------------------------

def test_causal_pruning_halves_schedule():
    real, dense = katt.scheduled_block_counts(
        4096, 4096, block_q=128, block_k=128, causal=True, window=None)
    nq = 4096 // 128
    assert real == nq * (nq + 1) // 2          # exact lower triangle
    assert dense / real > 1.9                  # ~2x at long S


def test_window_pruning_is_banded():
    real, dense = katt.scheduled_block_counts(
        8192, 8192, block_q=128, block_k=128, causal=True, window=1024)
    # each row touches at most ceil(window/bk)+1 columns (+ the diagonal)
    assert real <= (8192 // 128) * (1024 // 128 + 2)
    assert dense / real > 6


def test_padded_kv_blocks_never_scheduled():
    # kv_len masks the padded tail: blocks wholly past kv_len drop out
    tbl, real = katt._pair_schedule(4, 4, 8, 8, False, None, 17, 32, "row")
    assert real == 4 * 3                       # k blocks 0..2 only
    assert int(tbl[:, 1].max()) == 2


def test_nonzero_offsets_fall_back_to_dense_schedule():
    """The pruned schedule is built in LOCAL positions: a nonzero static
    offset shifts the band, so pruning must disable itself (review
    regression — pruning with q_offset once dropped live k-blocks)."""
    B, H, S, Dh = 1, 2, 64, 8
    q = _arr((B * H, S, Dh))
    k, v = _arr((B * H, S, Dh)), _arr((B * H, S, Dh))
    # q rows globally at [64, 128): with causal they attend ALL 64 keys
    o_p, _ = katt.flash_attention_fwd_pallas(
        q, k, v, causal=True, block_q=8, block_k=8, q_offset=S, k_offset=0,
        prune=True, interpret=True)
    o_d, _ = katt.flash_attention_fwd_pallas(
        q, k, v, causal=True, block_q=8, block_k=8, q_offset=S, k_offset=0,
        prune=False, interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_d), rtol=1e-6,
                               atol=1e-6)
    r = ref.attention_ref(q, k, v, causal=False)   # all keys visible
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(r), rtol=2e-4,
                               atol=2e-4)


def test_attention_q_offset_stays_on_xla_path(monkeypatch):
    """layers.attention with a nonzero q_offset must not dispatch to the
    kernel (its wrapper masks in local positions)."""
    B, S, H, Dh = 1, 16, 2, 8
    q, k, v = _arr((B, S, H, Dh)), _arr((B, S, H, Dh)), _arr((B, S, H, Dh))
    monkeypatch.setenv("REPRO_FLASH_ATTN", "pallas")
    jx = str(jax.make_jaxpr(
        lambda q, k, v: layers.attention(q, k, v, causal=True,
                                         q_offset=32))(q, k, v))
    assert "pallas_call" not in jx


def test_pruned_vs_dense_same_numbers():
    B, H, S, Dh = 1, 2, 40, 8
    q, k, v = _arr((B, H, S, Dh)), _arr((B, H, S, Dh)), _arr((B, H, S, Dh))
    o_p = ops.flash_attention(q, k, v, causal=True, window=8, block_q=8,
                              block_k=8, prune=True)
    o_d = ops.flash_attention(q, k, v, causal=True, window=8, block_q=8,
                              block_k=8, prune=False)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_d), rtol=1e-6,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# Policy + default-path dispatch
# ---------------------------------------------------------------------------

def test_flash_policy_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_FLASH_ATTN", raising=False)
    assert flash_attn_policy().mode == "auto"
    monkeypatch.setenv("REPRO_FLASH_ATTN", "pallas")
    assert flash_attn_policy().mode == "pallas"
    assert flash_attn_policy("xla").mode == "xla"   # explicit beats env
    monkeypatch.setenv("REPRO_FLASH_ATTN_MIN_SEQ", "64")
    assert flash_attn_policy("auto").min_seq == 64
    monkeypatch.setenv("REPRO_FLASH_ATTN", "bogus")
    with pytest.raises(ValueError):
        flash_attn_policy()


def test_decide_flash_auto():
    pol = DEFAULT_FLASH_POLICY
    assert decide_flash(pol, seq_len=4096, kv_len=4096, on_tpu=True) == \
        "pallas"
    # CPU backend: interpret mode is an emulator, not a fast path
    assert decide_flash(pol, seq_len=4096, kv_len=4096, on_tpu=False) == \
        "xla"
    # short sequences don't amortize the launch
    assert decide_flash(pol, seq_len=256, kv_len=256, on_tpu=True) == "xla"
    assert decide_flash(FlashAttnPolicy(mode="pallas"), seq_len=8,
                        kv_len=8, on_tpu=False) == "pallas"


def _attn_jaxpr(q, k, v, impl):
    return str(jax.make_jaxpr(
        lambda q, k, v: layers.attention(q, k, v, causal=True, impl=impl))(
            q, k, v))


def test_default_path_dispatches_iff_policy(monkeypatch):
    """The kernel shows up in the lowered jaxpr exactly when the policy
    picks it: env force-on, env force-off, and per-call override."""
    B, S, H, Dh = 1, 32, 2, 8
    q = _arr((B, S, H, Dh))
    k, v = _arr((B, S, H, Dh)), _arr((B, S, H, Dh))
    monkeypatch.setenv("REPRO_FLASH_ATTN", "pallas")
    assert "pallas_call" in _attn_jaxpr(q, k, v, None)
    monkeypatch.setenv("REPRO_FLASH_ATTN", "xla")
    assert "pallas_call" not in _attn_jaxpr(q, k, v, None)
    # explicit impl overrides the env in both directions
    assert "pallas_call" in _attn_jaxpr(q, k, v, "pallas")
    monkeypatch.setenv("REPRO_FLASH_ATTN", "pallas")
    assert "pallas_call" not in _attn_jaxpr(q, k, v, "xla")
    # auto on the CPU container resolves to the XLA paths
    monkeypatch.delenv("REPRO_FLASH_ATTN", raising=False)
    assert "pallas_call" not in _attn_jaxpr(q, k, v, None)


def test_policy_path_values_and_grads_match(monkeypatch):
    B, S, H, Dh = 2, 32, 4, 16
    q, k, v = _arr((B, S, H, Dh)), _arr((B, S, H, Dh)), _arr((B, S, H, Dh))

    def loss(q, k, v):
        return (layers.attention(q, k, v, causal=True).astype(jnp.float32)
                ** 2).sum()

    monkeypatch.setenv("REPRO_FLASH_ATTN", "xla")
    vx, gx = jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)
    monkeypatch.setenv("REPRO_FLASH_ATTN", "pallas")
    vp, gp = jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(float(vp), float(vx), rtol=1e-5)
    for a, b in zip(gx, gp):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-4,
                                   atol=2e-4)


# ---------------------------------------------------------------------------
# Satellite: flash_decode must survive caches that don't divide block_k
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,block_k", [(100, 512), (37, 64), (300, 512)])
def test_flash_decode_short_cache(S, block_k):
    B, G, Dh = 3, 4, 16
    q = _arr((B, G, Dh))
    kc, vc = _arr((B, S, Dh)), _arr((B, S, Dh))
    lens = jnp.asarray([S, max(1, S // 3), 1], jnp.int32)
    out = katt.flash_decode_pallas(q, kc, vc, lens, block_k=block_k,
                                   interpret=True)
    s = jnp.einsum("bgd,bsd->bgs", q.astype(jnp.float32),
                   kc.astype(jnp.float32)) / np.sqrt(Dh)
    mask = jnp.arange(S)[None, None, :] < lens[:, None, None]
    p = jax.nn.softmax(jnp.where(mask, s, -jnp.inf), axis=-1)
    r = jnp.einsum("bgs,bsd->bgd", p, vc.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=2e-4,
                               atol=2e-4)


def test_ops_flash_decode_ragged_cache():
    B, H, Hkv, S, Dh = 2, 4, 2, 100, 16
    q = _arr((B, H, Dh))
    kc, vc = _arr((B, Hkv, S, Dh)), _arr((B, Hkv, S, Dh))
    lens = jnp.asarray([100, 55], jnp.int32)
    out = ops.flash_decode(q, kc, vc, lens)    # default block_k=512 > S
    assert out.shape == (B, H, Dh)
    assert bool(jnp.all(jnp.isfinite(out)))
