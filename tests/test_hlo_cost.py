"""Scan-aware HLO cost parser vs known ground truth."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import module_cost
from repro.runtime import compat


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_plain_matmul_matches_xla():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    comp = _compile(lambda a, b: a @ b, a, b)
    mine = module_cost(comp.as_text())
    assert mine.flops == pytest.approx(compat.cost_analysis(comp)["flops"])
    assert mine.flops == pytest.approx(2 * 256 * 512 * 128)


def test_scan_multiplies_trip_count():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        out, _ = jax.lax.scan(body, x, None, length=48)
        return out
    comp = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    mine = module_cost(comp.as_text())
    assert mine.flops == pytest.approx(48 * 2 * 128 ** 3, rel=0.01)
    # XLA's own counter misses the trip count
    assert compat.cost_analysis(comp)["flops"] < mine.flops / 10


def test_nested_scans_multiply():
    def f(x):
        def inner(c, _):
            return c @ c, None
        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=4)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=6)
        return out
    comp = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    mine = module_cost(comp.as_text())
    assert mine.flops == pytest.approx(24 * 2 * 64 ** 3, rel=0.01)


def test_bytes_reasonable_for_elementwise():
    comp = _compile(lambda x: x + 1.0,
                    jax.ShapeDtypeStruct((1024, 1024), jnp.float32))
    mine = module_cost(comp.as_text())
    nbytes = 1024 * 1024 * 4
    assert nbytes <= mine.bytes <= 4 * nbytes
