"""Unified telemetry subsystem: metrics registry, span tracer, Chrome
trace export, serve/train wiring, and the live roofline accountant.

Covers the observability PR's acceptance checklist: span nesting +
thread-safety, Chrome trace-event schema validity (perfetto-required
fields), metrics snapshot determinism under chaos virtual-clock replay,
serve spans covering admission -> prefill -> decode -> completion,
``engine.telemetry()`` contents, and observed-vs-predicted roofline rows
for one conv2d and one paged-decode workload within the documented
tolerances."""
import json
import threading

import numpy as np
import pytest

import repro.obs as obs
from repro.obs import MetricsRegistry, SpanTracer, Telemetry
from repro.obs.roofline_live import (TOLERANCES, TrafficRow,
                                     paged_decode_rows,
                                     predict_paged_decode_traffic)


@pytest.fixture(autouse=True)
def _isolate_global_telemetry():
    """Every test leaves the process-global telemetry disabled and the
    global registry as it found it (other test files must not inherit an
    enabled tracer)."""
    prev = obs.get_telemetry()
    yield
    obs.set_telemetry(prev if prev is not obs._DISABLED else None)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counters_gauges_hists():
    m = MetricsRegistry()
    m.counter("reqs", outcome="ok")
    m.counter("reqs", 2, outcome="ok")
    m.counter("reqs", outcome="shed")
    m.gauge("util", 0.25)
    m.gauge("util", 0.83)                      # last write wins
    for v in (1.0, 3.0, 2.0):
        m.observe("lat_s", v)
    snap = m.snapshot()
    assert snap["counters"] == {"reqs{outcome=ok}": 3,
                                "reqs{outcome=shed}": 1}
    assert snap["gauges"] == {"util": 0.83}
    h = snap["histograms"]["lat_s"]
    assert (h["count"], h["sum"], h["min"], h["max"]) == (3, 6.0, 1.0, 3.0)
    assert h["mean"] == 2.0 and h["p50"] == 2.0
    assert m.get_counter("reqs", outcome="ok") == 3
    assert m.get_counter("missing") == 0


def test_registry_label_order_is_canonical():
    m = MetricsRegistry()
    m.counter("x", a=1, b=2)
    m.counter("x", b=2, a=1)                   # same series, any kw order
    assert m.snapshot()["counters"] == {"x{a=1,b=2}": 2}


def test_registry_absorb_flattens_nested_stats():
    m = MetricsRegistry()
    m.absorb({"hits": 3, "hit": True, "name": "skipme",
              "nested": {"depth": 2.5}}, prefix="kv.", mode="paged")
    g = m.snapshot()["gauges"]
    assert g["kv.hits{mode=paged}"] == 3.0
    assert g["kv.hit{mode=paged}"] == 1.0
    assert g["kv.nested.depth{mode=paged}"] == 2.5
    assert not any("name" in k for k in g)     # non-numeric skipped


def test_registry_reset_by_name():
    m = MetricsRegistry()
    m.counter("keep")
    m.counter("drop", lbl="x")
    m.reset(["drop"])
    assert m.snapshot()["counters"] == {"keep": 1}
    m.reset()
    assert m.snapshot()["counters"] == {}


def test_registry_thread_safety():
    m = MetricsRegistry()
    N, PER = 8, 500

    def work(tid):
        for i in range(PER):
            m.counter("ops", worker=tid % 2)
            m.observe("v", float(i))

    ts = [threading.Thread(target=work, args=(t,)) for t in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    snap = m.snapshot()
    assert sum(snap["counters"].values()) == N * PER
    assert snap["histograms"]["v"]["count"] == N * PER


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def _vclock_tracer():
    clk = [0.0]

    def tick(dt=1.0):
        clk[0] += dt

    return SpanTracer(clock=lambda: clk[0], process_name="test"), tick


def test_span_nesting_and_ordering():
    tr, tick = _vclock_tracer()
    with tr.span("outer", phase="a"):
        tick()
        with tr.span("inner"):
            tick()
        tick()
    evs = tr.spans()
    # completion order: inner closes before outer
    assert [e["name"] for e in evs] == ["inner", "outer"]
    inner, outer = evs
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert outer["args"] == {"phase": "a"}


def test_begin_finish_force_closes_dangling_children():
    tr, tick = _vclock_tracer()
    run = tr.begin("RUN", step=0)
    tick()
    tr.begin("dangling")                       # never finished explicitly
    tick()
    tr.finish(run, end_step=5)
    names = [e["name"] for e in tr.spans()]
    assert names == ["dangling", "RUN"]
    assert tr.spans("RUN")[0]["args"] == {"step": 0, "end_step": 5}
    tr.finish(run)                             # idempotent
    assert len(tr.spans("RUN")) == 1


def test_tracer_decorator_and_instants():
    tr, tick = _vclock_tracer()

    @tr.trace("step")
    def step():
        tick()
        tr.instant("fault", cat="chaos", host=1)
        return 7

    assert step() == 7
    assert len(tr.spans("step")) == 1
    (inst,) = [e for e in tr.events() if e["ph"] == "i"]
    assert inst["name"] == "fault" and inst["args"] == {"host": 1}


def test_tracer_threads_interleave_without_corruption():
    tr, _ = _vclock_tracer()
    N, PER = 4, 50

    def work():
        for i in range(PER):
            with tr.span("w"):
                with tr.span("wi"):
                    pass

    ts = [threading.Thread(target=work) for _ in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(tr.spans("w")) == N * PER
    assert len(tr.spans("wi")) == N * PER
    assert tr.dropped == 0


def test_tracer_bounded_buffer_drops_oldest():
    tr = SpanTracer(clock=lambda: 0.0, max_events=10)
    for i in range(25):
        tr.instant(f"e{i}")
    assert len(tr.events()) == 10
    assert tr.dropped == 15
    assert tr.events()[0]["name"] == "e15"     # oldest dropped first


def test_chrome_trace_schema(tmp_path):
    tr, tick = _vclock_tracer()
    with tr.span("outer"):
        tick(0.5)
        tr.instant("mark")
    path = tr.write_chrome_trace(str(tmp_path / "sub" / "trace.json"))
    with open(path) as f:
        doc = json.load(f)                     # valid JSON round-trip
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    for e in evs:                              # perfetto-required fields
        for field in ("name", "ph", "ts", "pid", "tid"):
            assert field in e, (field, e)
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    phs = {e["ph"] for e in evs}
    assert {"M", "X", "i"} <= phs
    meta = [e for e in evs if e["ph"] == "M"]
    assert any(m["name"] == "process_name" and
               m["args"]["name"] == "test" for m in meta)
    assert any(m["name"] == "thread_name" for m in meta)
    (x,) = [e for e in evs if e["ph"] == "X"]
    assert x["dur"] == pytest.approx(0.5e6)    # seconds -> microseconds
    (i,) = [e for e in evs if e["ph"] == "i"]
    assert i["s"] == "t"


# ---------------------------------------------------------------------------
# Telemetry facade
# ---------------------------------------------------------------------------

def test_disabled_telemetry_is_inert():
    t = Telemetry(enabled=False, registry=MetricsRegistry())
    with t.span("s") as h:
        assert h is None
    assert t.begin("b") is None
    t.finish(None)                             # no-op, no raise
    t.instant("i")
    t.counter("c")
    assert t.tracer.events() == []
    assert t.snapshot()["counters"] == {}


def test_enable_installs_and_restores_global():
    assert obs.get_telemetry().enabled is False
    t = obs.enable(process_name="unit")
    assert obs.get_telemetry() is t and t.enabled
    obs.set_telemetry(None)
    assert obs.get_telemetry().enabled is False


def test_write_metrics_artifact(tmp_path):
    t = Telemetry(registry=MetricsRegistry())
    t.counter("c", kind="x")
    p = t.write_metrics(str(tmp_path / "m.json"), extra={"serve": {"n": 1}})
    with open(p) as f:
        doc = json.load(f)
    assert doc["counters"] == {"c{kind=x}": 1}
    assert doc["serve"] == {"n": 1}


# ---------------------------------------------------------------------------
# serving engine wiring: spans + telemetry() + observed traffic
# ---------------------------------------------------------------------------

def _serve_traced(*, n_requests=3, prompt_len=11, max_new=5, page_size=8,
                  prefill_chunk=8, prefix_cache=False, prefix_share=0.0,
                  seed=0):
    from repro.launch.serve import build_engine
    tel = Telemetry(enabled=True, registry=MetricsRegistry())
    engine, vocab = build_engine(
        "qwen3-4b", slots=3, max_len=64, max_new=max_new, kv_mode="paged",
        page_size=page_size, prefill_chunk=prefill_chunk,
        prefix_cache=prefix_cache, seed=seed, telemetry=tel)
    rng = np.random.default_rng(seed)
    prompts = []
    common = rng.integers(0, vocab, size=prompt_len // 2)
    for i in range(n_requests):
        p = rng.integers(0, vocab, size=prompt_len).astype(np.int32)
        if prefix_share > 0 and i % max(1, round(1 / prefix_share)) == 0:
            p[:len(common)] = common
        prompts.append(p)
        engine.submit(p)
    results = engine.run()
    return engine, tel, prompts, results


def test_serve_spans_cover_request_lifecycle():
    engine, tel, prompts, results = _serve_traced()
    assert len(results) == 3 and all(len(v) == 5 for v in results.values())
    names = {e["name"] for e in tel.tracer.events()}
    assert {"admission", "prefill", "decode", "admit", "complete"} <= names
    # every request admitted and completed exactly once
    admits = [e for e in tel.tracer.events() if e["name"] == "admit"]
    completes = [e for e in tel.tracer.events()
                 if e["name"] == "complete"]
    assert sorted(e["args"]["rid"] for e in admits) == [0, 1, 2]
    assert sorted(e["args"]["rid"] for e in completes) == [0, 1, 2]
    # lifecycle ordering per request: admit before its completion
    t_admit = {e["args"]["rid"]: e["ts"] for e in admits}
    t_done = {e["args"]["rid"]: e["ts"] for e in completes}
    assert all(t_admit[r] <= t_done[r] for r in t_admit)
    # prefill spans precede the first pure-decode span
    prefills = tel.tracer.spans("prefill")
    decodes = tel.tracer.spans("decode")
    assert prefills and decodes
    assert min(s["ts"] for s in prefills) <= min(s["ts"] for s in decodes)


def test_engine_telemetry_snapshot_contents():
    engine, tel, _, results = _serve_traced(n_requests=5,
                                            prefix_cache=True,
                                            prefix_share=0.5)
    snap = engine.telemetry()
    assert snap["mode"] == "paged"
    assert snap["ticks"] > 0
    assert snap["outcomes"]["ok"] == len(results)
    kv = snap["kv"]
    assert {"bytes_resident", "pages_total", "pages_used",
            "utilization"} <= set(kv)
    assert 0.0 <= kv["utilization"] <= 1.0
    pf = snap["prefix"]
    assert pf["lookups"] >= 5 and pf["hits"] >= 1     # shared prefix hit
    tr = snap["traffic"]
    assert tr["gb_read_bytes"] > 0 and tr["written_bytes"] > 0
    assert tr["dram_read_bytes"] >= tr["gb_read_bytes"]  # page rounding
    # the pull half landed in the registry as serve.* gauges
    g = tel.snapshot()["gauges"]
    assert g["serve.outcomes.ok"] == float(len(results))
    assert "serve.kv.utilization" in g
    assert "serve.traffic.gb_read_bytes" in g


def test_serve_counters_count_outcomes():
    engine, tel, _, results = _serve_traced()
    m = tel.metrics
    assert m.get_counter("serve_requests", outcome="ok") == len(results)


# ---------------------------------------------------------------------------
# live roofline: observed vs predicted
# ---------------------------------------------------------------------------

def test_paged_decode_traffic_matches_prediction():
    prompt_lens, max_new, page, chunk = [11, 11, 11], 5, 8, 8
    engine, tel, prompts, _ = _serve_traced(
        n_requests=3, prompt_len=11, max_new=max_new, page_size=page,
        prefill_chunk=chunk, prefix_cache=False)
    observed = engine.telemetry()["traffic"]
    predicted = predict_paged_decode_traffic(
        prompt_lens, max_new, page_size=page,
        page_bytes=engine.kv.cfg.page_bytes, prefill_chunk=chunk)
    rows = paged_decode_rows(observed, predicted)
    levels = [r.level for r in rows]
    assert "gb" in levels and "dram" in levels
    for r in rows:
        assert r.within, r.row()
    # gb is token-exact on both sides: the two independent derivations
    # must agree exactly, not merely within tolerance
    gb = [r for r in rows if r.level == "gb" and r.unit == "bytes"][0]
    assert gb.ratio == pytest.approx(1.0)
    dram = [r for r in rows if r.level == "dram"][0]
    assert dram.observed >= gb.observed        # page rounding only adds


def test_paged_decode_prediction_accounts_prefix_hits():
    page, chunk, max_new = 8, 8, 5
    cold = predict_paged_decode_traffic(
        [16], max_new, page_size=page, page_bytes=page * 4,
        prefill_chunk=chunk)
    warm = predict_paged_decode_traffic(
        [16], max_new, page_size=page, page_bytes=page * 4,
        prefill_chunk=chunk, matched=[8])
    assert warm["gb_read_bytes"] < cold["gb_read_bytes"]
    assert warm["written_tokens"] == cold["written_tokens"] - 8


def test_conv2d_observed_vs_predicted_rows():
    from repro.obs.roofline_live import conv2d_rows
    rows = conv2d_rows(1, 16, 16, 8, 16, 3, 3)
    by_level = {r.level: r for r in rows}
    assert {"hlo_flops", "hlo_bytes", "gb"} <= set(by_level)
    for r in rows:
        assert r.predicted > 0
        assert r.within, r.row()
    # XLA must count the same MACs the analytic model does
    assert by_level["hlo_flops"].ratio == pytest.approx(1.0, rel=0.25)
    # the scheduler's fetch plan never exceeds the refetch-everything bound
    assert by_level["gb"].observed <= by_level["gb"].predicted * (1 + 1e-9)


def test_traffic_report_mirrors_gauges():
    from repro.obs.roofline_live import report
    m = MetricsRegistry()
    rows = [TrafficRow("w", "gb", 100.0, 100.0)]
    out = report(rows, registry=m)
    assert out[0]["within"] is True and out[0]["ratio"] == 1.0
    g = m.snapshot()["gauges"]
    assert g["traffic_observed{level=gb,unit=bytes,workload=w}"] == 100.0
    assert g["traffic_ratio{level=gb,unit=bytes,workload=w}"] == 1.0


def test_tolerances_documented_for_asserted_levels():
    assert TOLERANCES["gb"] <= 1.05            # near-exact invariant
    assert TOLERANCES["dram"] < 2.0            # bounded paging overhead


# ---------------------------------------------------------------------------
# train-loop wiring: chaos virtual-clock replay determinism
# ---------------------------------------------------------------------------

def _train_chaos(tmp_path, tag):
    from repro.launch.train import run
    obs.REGISTRY.reset()
    trace = tmp_path / f"trace_{tag}.json"
    out = run("qwen3-4b", steps=8, seq_len=16, global_batch=4,
              ckpt_dir=str(tmp_path / f"ckpt_{tag}"), ckpt_every=4,
              chaos=["nan@3"], trace_out=str(trace),
              metrics_out=str(tmp_path / f"m_{tag}.json"))
    obs.set_telemetry(None)
    with open(trace) as f:
        doc = json.load(f)
    return out, doc


def test_chaos_replay_metrics_and_trace_deterministic(tmp_path):
    """Two identical chaos runs on the virtual clock produce the same
    counter section and the same trace timeline (timestamps included —
    spans are clocked on the per-step virtual clock, not wall time)."""
    out1, doc1 = _train_chaos(tmp_path, "a")
    out2, doc2 = _train_chaos(tmp_path, "b")
    assert out1["telemetry"]["counters"] == out2["telemetry"]["counters"]
    assert out1["telemetry"]["counters"], "expected recorded events"

    def timeline(doc):
        return [(e["name"], e["ph"], e["ts"], e.get("dur"),
                 json.dumps(e["args"], sort_keys=True))
                for e in doc["traceEvents"] if e["ph"] in ("X", "i")]

    assert timeline(doc1) == timeline(doc2)
    names = {e["name"] for e in doc1["traceEvents"]}
    assert "RUN" in names and "chaos" in names and "guard_skip" in names


def test_gradguard_events_reach_registry(tmp_path):
    out, doc = _train_chaos(tmp_path, "g")
    c = out["telemetry"]["counters"]
    assert c.get("gradguard_events{kind=skip,trigger=nonfinite}", 0) >= 1
    assert c.get("checkpoint_ops{op=save}", 0) >= 1
