"""Scheduler engine (repro.core.autotune): equivalence vs brute force,
pruning correctness, memoization keying, and the on-disk cache tier."""
import dataclasses
import os

import pytest

from repro.core import (TEU_BUFFER, BufferSpec, attention_scores_op,
                        cache_stats, clear_cache, conv2d_op, correlation_op,
                        depthwise_conv2d_op, matmul_op, op_signature,
                        order_grid_for_sharing,
                        order_grid_for_sharing_reference, plan_mesh_exchange,
                        plan_mesh_exchange_reference, search_tiles,
                        search_tiles_reference)

FAMILIES = [
    ("matmul", lambda: matmul_op(256, 192, 320)),
    ("conv2d", lambda: conv2d_op(64, 32, 28, 28, 3, 3)),
    ("conv2d_strided", lambda: conv2d_op(8, 4, 10, 10, 3, 3,
                                         stride=2, dilation=2)),
    ("depthwise", lambda: depthwise_conv2d_op(64, 28, 28, 3, 3)),
    ("correlation", lambda: correlation_op(9, 9, 16, 16, 32)),
    ("attention", lambda: attention_scores_op(8, 128, 128, 64)),
]

BUFFERS = [
    TEU_BUFFER,
    BufferSpec(input_bytes=4 * 1024 * 1024, psum_bytes=1024 * 1024),
    BufferSpec(input_bytes=2048, psum_bytes=512),
]


@pytest.fixture(autouse=True)
def _fresh_cache():
    clear_cache()
    yield
    clear_cache()


@pytest.mark.parametrize("fam", [f[0] for f in FAMILIES])
def test_search_equivalent_to_reference(fam):
    """Engine returns byte-identical TileSchedules on every op family."""
    op = dict(FAMILIES)[fam]()
    for buf in BUFFERS:
        try:
            ref = search_tiles_reference(op, buf)
        except ValueError:
            with pytest.raises(ValueError):
                search_tiles(op, buf)
            continue
        eng = search_tiles(op, buf)
        assert eng == ref          # full dataclass: tile, bytes/MAC, grid, ...
        assert (eng.tile, eng.bytes_per_mac, eng.num_tiles) == \
               (ref.tile, ref.bytes_per_mac, ref.num_tiles)


@pytest.mark.parametrize("fam", [f[0] for f in FAMILIES])
def test_search_equivalent_with_caps_and_prefer_small(fam):
    op = dict(FAMILIES)[fam]()
    caps = {op.dims[0].name: max(1, op.dims[0].size // 4)}
    ref = search_tiles_reference(op, TEU_BUFFER, caps=caps, prefer_large=False)
    assert search_tiles(op, TEU_BUFFER, caps=caps, prefer_large=False) == ref


def test_search_equivalent_with_alignment():
    op = matmul_op(512, 512, 512)
    buf = BufferSpec(input_bytes=8 * 1024 * 1024, psum_bytes=4 * 1024 * 1024,
                     align={"i": 128, "j": 128})
    assert search_tiles(op, buf) == search_tiles_reference(op, buf)


@pytest.mark.parametrize("fam", [f[0] for f in FAMILIES])
def test_grid_order_equivalent(fam):
    op = dict(FAMILIES)[fam]()
    tile = search_tiles_reference(op, TEU_BUFFER).tile
    assert order_grid_for_sharing(op, tile) == \
        order_grid_for_sharing_reference(op, tile)


@pytest.mark.parametrize("fam", [f[0] for f in FAMILIES])
def test_mesh_exchange_equivalent(fam):
    op = dict(FAMILIES)[fam]()
    tile = search_tiles_reference(op, TEU_BUFFER).tile
    for mesh in ((2, 2), (4, 4), (8, 2)):
        assert plan_mesh_exchange(op, tile, mesh) == \
            plan_mesh_exchange_reference(op, tile, mesh)
    assert plan_mesh_exchange(op, tile, (4, 4), share_cols=False,
                              col_span_cap=3) == \
        plan_mesh_exchange_reference(op, tile, (4, 4), share_cols=False,
                                     col_span_cap=3)


def test_structural_twins_share_cache_entry():
    """Two structurally-identical ops built separately hit one entry."""
    a = conv2d_op(32, 16, 14, 14, 3, 3)
    b = conv2d_op(32, 16, 14, 14, 3, 3, name="other_conv")
    assert op_signature(a) == op_signature(b)
    s1 = search_tiles(a, TEU_BUFFER)
    misses = cache_stats["misses"]
    s2 = search_tiles(b, TEU_BUFFER)
    assert cache_stats["misses"] == misses     # second call: pure cache hit
    assert cache_stats["hits"] >= 1
    assert s2.tile == s1.tile
    # the cached schedule is re-labelled with the caller's op name
    assert s1.op_name == "conv2d" and s2.op_name == "other_conv"


def test_different_structure_different_entry():
    s1 = search_tiles(matmul_op(128, 128, 128), TEU_BUFFER)
    misses = cache_stats["misses"]
    s2 = search_tiles(matmul_op(128, 128, 256), TEU_BUFFER)
    assert cache_stats["misses"] == misses + 1
    assert s1.tile != s2.tile or s1.num_tiles != s2.num_tiles


def test_buffer_and_caps_in_cache_key():
    op = matmul_op(256, 256, 256)
    search_tiles(op, TEU_BUFFER)
    misses = cache_stats["misses"]
    search_tiles(op, BufferSpec(input_bytes=1 << 20, psum_bytes=1 << 18))
    search_tiles(op, TEU_BUFFER, caps={"i": 16})
    assert cache_stats["misses"] == misses + 2


def test_disk_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SCHED_DISK_CACHE", "1")
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    op = conv2d_op(32, 16, 14, 14, 3, 3)
    s1 = search_tiles(op, TEU_BUFFER)
    assert any(p.suffix == ".json" for p in tmp_path.iterdir())
    clear_cache()                      # drop the LRU, keep the disk tier
    s2 = search_tiles(op, TEU_BUFFER)
    assert s2 == s1
    assert cache_stats["disk_hits"] == 1
    from repro.core.autotune import clear_cache as cc
    cc(disk=True)
    assert not any(p.suffix == ".json" for p in tmp_path.iterdir())


def test_engine_infeasible_raises_like_reference():
    op = matmul_op(8, 8, 8)
    with pytest.raises(ValueError):
        search_tiles(op, BufferSpec(input_bytes=4, psum_bytes=1))


def test_schedule_is_plain_dataclass_roundtrip():
    """Disk serialization preserves every TileSchedule field exactly."""
    from repro.core.autotune import _schedule_from_json, _schedule_to_json
    s = search_tiles(conv2d_op(16, 8, 12, 12, 3, 3), TEU_BUFFER)
    assert _schedule_from_json(_schedule_to_json(s)) == s
